"""Runtime telemetry (repro.obs): span tracer, typed round records,
JSONL sinks + cross-process merge, and the driver integration contract.

The load-bearing guarantees pinned here:

* disabled telemetry is genuinely free — no files, no events, one
  shared null span object, and the drivers' histories are numerically
  IDENTICAL with telemetry on vs off;
* both driver paths emit the exact typed key set
  (``metrics.ROUND_KEYS``) — schema drift between the loop and sharded
  drivers is what this PR killed;
* enabling telemetry does not change the sharded driver's traced round
  program (jaxpr equality) — the once-per-round host-sync contract
  cannot regress via observability;
* the per-process JSONL logs round-trip, merge in global ``(t, proc,
  seq)`` order, and tolerate a truncated tail (a SIGKILL'd host).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import dials, influence
from repro.envs import registry
from repro.marl import policy as policy_mod, ppo as ppo_mod
from repro.obs import metrics, sinks, trace


# ---------------------------------------------------------------------------
# trace: spans, nesting, fencing, disabled mode
# ---------------------------------------------------------------------------
def test_tracer_records_nested_spans_with_depth():
    clock = iter(range(100)).__next__
    tr = trace.Tracer(clock=lambda: float(clock()))
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    # children are appended at exit, before their parent
    assert [e["name"] for e in tr.events] == ["inner", "outer"]
    assert [e["depth"] for e in tr.events] == [1, 0]
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["inner"]["t0"] > by_name["outer"]["t0"]
    assert by_name["outer"]["dur_s"] > by_name["inner"]["dur_s"]


def test_phase_seconds_sums_per_name_and_resets():
    ticks = iter([0.0, 1.0, 10.0, 13.0, 20.0, 25.0]).__next__
    tr = trace.Tracer(clock=ticks)
    for _ in range(2):
        with tr.span("collect"):
            pass
    with tr.span("train"):
        pass
    phases = tr.phase_seconds()
    assert phases == {"collect": 4.0, "train": 5.0}
    tr.reset()
    assert tr.events == [] and tr.phase_seconds() == {}


def test_span_fence_only_blocks_when_tracer_fenced():
    fenced = trace.Tracer(fenced=True)
    x = jax.numpy.ones((4,))
    with fenced.span("s") as sp:
        assert sp.fence(x) is x           # returns the value either way
    unfenced = trace.Tracer()
    with unfenced.span("s") as sp:
        assert sp.fence(x) is x
    assert fenced.fenced and not unfenced.fenced


def test_null_tracer_allocates_nothing():
    tr = trace.NULL_TRACER
    assert not tr.enabled
    s1 = tr.span("a")
    s2 = tr.span("b")
    assert s1 is s2                       # one shared no-op span
    with s1 as sp:
        assert sp.fence(123) == 123
    assert tr.events == [] and tr.phase_seconds() == {}


def test_profile_none_is_noop():
    with trace.profile(None):
        pass
    with trace.annotate("named"):          # named_scope pass-through
        _ = jax.numpy.zeros(())


# ---------------------------------------------------------------------------
# metrics: the typed round record
# ---------------------------------------------------------------------------
def _full_record(**over):
    base = dict(round=0, gs_return=np.float32(1.5), ials_reward=0.25,
                aip_ce_before=0.7, aip_ce_after=0.6, data_round=0,
                forced_sync=True, stale_forced=0, staleness_min=0,
                staleness_mean=0.0, staleness_max=0, n_shards=1,
                reassigned=0, dead_hosts=[], kernels="policy=oracle",
                collect_s=0.1, env_steps_per_s=None, aip_s=None,
                inner_s=None, eval_s=None, mirror_s=None, round_s=0.5,
                wall_s=0.5)
    base.update(over)
    return base


def test_round_record_coerces_to_host_scalars():
    rec = metrics.round_record(**_full_record(
        round=np.int64(3), gs_return=jax.numpy.asarray(2.0),
        staleness_max=jax.numpy.asarray(1, jax.numpy.int32),
        dead_hosts=[np.int64(1)]))
    assert set(rec) == set(metrics.ROUND_KEYS)
    assert rec["round"] == 3 and type(rec["round"]) is int
    assert rec["gs_return"] == 2.0 and type(rec["gs_return"]) is float
    assert rec["dead_hosts"] == [1] and type(rec["dead_hosts"][0]) is int
    assert rec["aip_s"] is None           # explicit null, key present
    json.dumps(rec)                       # JSON-serializable as built


def test_round_record_rejects_drift():
    with pytest.raises(TypeError, match="unknown"):
        metrics.round_record(**_full_record(), extra_key=1)
    partial = _full_record()
    partial.pop("gs_return")
    with pytest.raises(TypeError, match="missing"):
        metrics.round_record(**partial)
    with pytest.raises(TypeError, match="not.*nullable"):
        metrics.round_record(**_full_record(gs_return=None))
    # nullable fields accept None
    rec = metrics.round_record(**_full_record(ials_reward=None))
    assert rec["ials_reward"] is None


def test_validate_round_catches_type_and_key_problems():
    good = metrics.round_record(**_full_record())
    assert metrics.validate_round(good) == []
    # envelope fields are ignored
    assert metrics.validate_round({**good, "event": "round", "proc": 0,
                                   "seq": 1, "t": 0.0}) == []
    bad = dict(good)
    bad["round"] = True                   # bool is not an int here
    bad["gs_return"] = "high"
    bad.pop("n_shards")
    bad["surprise"] = 1
    problems = "\n".join(metrics.validate_round(bad))
    assert "'round'" in problems and "'gs_return'" in problems
    assert "missing field 'n_shards'" in problems
    assert "unknown field 'surprise'" in problems


def test_staleness_stats_traces_under_jit():
    reports = jax.numpy.asarray([3, 1, 2], jax.numpy.int32)
    stats = jax.jit(lambda r: metrics.staleness_stats(r, 3))(reports)
    assert int(stats["staleness_min"]) == 0
    assert int(stats["staleness_max"]) == 2
    np.testing.assert_allclose(float(stats["staleness_mean"]), 1.0)


def test_kernel_summary_resolves_dispatch():
    pc = policy_mod.PolicyConfig(obs_dim=2, n_actions=2)
    ac = influence.AIPConfig(in_dim=2, n_sources=1)
    ppo_cfg = ppo_mod.PPOConfig()
    s = metrics.kernel_summary(pc, ac, ppo_cfg)
    parts = dict(p.split("=") for p in s.split(","))
    assert set(parts) == {"policy", "aip", "ppo"}
    assert all(v in ("oracle", "pallas", "pallas-interpret")
               for v in parts.values())


def test_validate_bench_row_scaling_and_kernels():
    row = {"label": "t-s2", "scenario": "t", "n_agents": 4, "shards": 2,
           "processes": 1, "streams": 4, "fused": True, "round_s": 1.0,
           "round_s_async": 0.8, "overlap_speedup": 1.25,
           "inner_steps_per_s": 100.0, "inner_steps_per_s_async": 125.0,
           "total_wall_s": 5.0, "total_wall_s_async": 4.0,
           "collect_s": 0.2, "env_steps_per_s": 640.0,
           "collect_s_sharded_gs": None, "gs_speedup": None}
    assert metrics.validate_bench_row(row, metrics.SCALING_ROW_SCHEMA) == []
    bad = {**row, "shards": "2", "mystery": 1, "round_s": None}
    probs = "\n".join(metrics.validate_bench_row(
        bad, metrics.SCALING_ROW_SCHEMA))
    assert "'shards'" in probs and "'mystery'" in probs
    assert "'round_s' is null" in probs
    # gae micro rows legitimately lack the in/H columns
    gae = {"kernel": "gae", "label": "x", "B": 4, "T": 8,
           "fwd_oracle_s": 1e-4, "fwd_kernel_s": 1e-4,
           "fwdbwd_oracle_s": 1e-4, "fwdbwd_kernel_s": 1e-4,
           "speedup_fwd": 1.0, "speedup_fwdbwd": 1.0,
           "roofline_fwd": {}, "roofline_fwdbwd": {}}
    assert metrics.validate_bench_row(
        gae, metrics.KERNELS_MICRO_SCHEMA) == []
    assert metrics.validate_bench_row(
        {"program": "train_aip", "label": "w", "oracle_s": 1.0,
         "kernel_s": 0.5, "speedup": 2.0},
        metrics.KERNELS_E2E_SCHEMA) == []


def test_phase_breakdown_renders_phase_columns():
    row = {"program": "p", "label": "l", "oracle_s": 0.125,
           "kernel_s": None, "speedup": 2.0}
    out = metrics.phase_breakdown(row, metrics.KERNELS_E2E_SCHEMA)
    assert out == "oracle_s=0.125 kernel_s=None"


# ---------------------------------------------------------------------------
# sinks: JSONL round-trip, merge order, truncation tolerance
# ---------------------------------------------------------------------------
def test_jsonl_roundtrip_and_merge_order(tmp_path):
    d = str(tmp_path)
    t0 = obs.Telemetry(d, process_id=0, tracer=trace.Tracer())
    t1 = obs.Telemetry(d, process_id=1, tracer=trace.Tracer())
    # interleave out of file order; merge must re-order globally by
    # (t, proc, seq)
    t1.emit("round", **metrics.round_record(**_full_record(round=0)))
    t0.emit("run_start", path="loop")
    t0.emit("round", **metrics.round_record(**_full_record(round=0)))
    t1.emit("round", **metrics.round_record(**_full_record(round=1)))
    t0.close()
    t1.close()
    merged = sinks.merge_dir(d)
    assert merged == os.path.join(d, sinks.MERGED_NAME)
    events = sinks.read_jsonl(merged)
    assert len(events) == 4
    keys = [(e["t"], e["proc"], e["seq"]) for e in events]
    assert keys == sorted(keys)
    # per-proc seq is monotone from 0
    assert [e["seq"] for e in events if e["proc"] == 0] == [0, 1]
    # a second merge is idempotent (the merged file is not re-ingested)
    events2 = sinks.read_jsonl(sinks.merge_dir(d))
    assert events2 == events


def test_read_jsonl_tolerates_truncated_tail(tmp_path):
    path = str(tmp_path / "telemetry-p0.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"event": "round", "seq": 0}) + "\n")
        f.write(json.dumps({"event": "round", "seq": 1}) + "\n")
        f.write('{"event": "round", "se')        # SIGKILL mid-write
    events = sinks.read_jsonl(path)
    assert [e["seq"] for e in events] == [0, 1]


def test_csv_sink_renders_rounds_only(tmp_path):
    path = str(tmp_path / "rounds.csv")
    sink = sinks.CsvSink(path)
    sink.write({"event": "run_start", "proc": 0})
    sink.write({"event": "round", "proc": 0,
                **metrics.round_record(**_full_record(dead_hosts=[1, 2]))})
    sink.close()
    lines = open(path).read().strip().splitlines()
    assert len(lines) == 2                 # header + one round
    assert lines[0].split(",") == ["proc"] + list(metrics.ROUND_KEYS)
    assert "1;2" in lines[1]               # list serialization


def test_terminal_sink_smoke(capsys):
    sink = sinks.TerminalSink()
    sink.write({"event": "round", "proc": 0,
                **metrics.round_record(**_full_record())})
    sink.write({"event": "host_death", "proc": 0, "round": 2,
                "dead_hosts": [1]})
    sink.write({"event": "elastic_reassign", "proc": 0, "old_shards": 4,
                "new_shards": 2, "moved": {"2": 1}})
    out = capsys.readouterr().out
    assert "round 0" in out and "host death" in out and "replan" in out


# ---------------------------------------------------------------------------
# the Telemetry facade + disabled mode
# ---------------------------------------------------------------------------
def test_disabled_telemetry_creates_no_files(tmp_path):
    tel = obs.maybe(None)
    assert tel is obs.DISABLED and not tel.enabled
    assert tel.emit("round", x=1) is None
    assert tel.emit_round({"round": 0}) is None
    with tel.span("phase") as sp:
        assert sp.fence(5) == 5
    assert tel.phase_seconds() == {} and tel.merge() is None
    tel.close()
    assert os.listdir(tmp_path) == []      # really nothing written


def test_telemetry_emit_wraps_envelope(tmp_path):
    tel = obs.Telemetry.create(str(tmp_path), process_id=7)
    r1 = tel.emit("run_start", path="loop")
    r2 = tel.emit("run_end", rounds=3)
    tel.close()
    assert (r1["proc"], r1["seq"]) == (7, 0)
    assert (r2["proc"], r2["seq"]) == (7, 1)
    assert r2["t"] >= r1["t"]
    events = sinks.read_jsonl(sinks.proc_path(str(tmp_path), 7))
    assert [e["event"] for e in events] == ["run_start", "run_end"]


# ---------------------------------------------------------------------------
# telemetry_report: the CLI over a synthetic incident log
# ---------------------------------------------------------------------------
def _incident_events():
    events = []
    for rnd, shards in ((0, 4), (1, 4), (2, 2)):
        rec = metrics.round_record(**_full_record(
            round=rnd, n_shards=shards,
            reassigned=2 if rnd == 2 else 0,
            dead_hosts=[1] if rnd == 2 else [],
            mirror_s=0.01))
        events.append({"event": "round", "proc": 0, "seq": rnd,
                       "t": float(rnd), **rec})
    events.insert(2, {"event": "host_death", "proc": 0, "seq": 10,
                      "t": 1.5, "round": 2, "dead_hosts": [1],
                      "timeout_s": 5.0})
    events.insert(3, {"event": "elastic_reassign", "proc": 0, "seq": 11,
                      "t": 1.6, "old_shards": 4, "new_shards": 2,
                      "dead_blocks": [2, 3], "moved": {"2": 1, "3": 1}})
    return events


def test_report_tables_and_check(tmp_path):
    from tools import telemetry_report
    path = str(tmp_path / "run.jsonl")
    with open(path, "w") as f:
        for e in _incident_events():
            f.write(json.dumps(e) + "\n")
    events = telemetry_report.load_events(path)
    assert telemetry_report.check(events) == []
    table = telemetry_report.round_table(events)
    assert table.count("\n") == 3          # header + 3 rounds
    timeline = telemetry_report.elasticity_timeline(events)
    assert "host_death" in timeline
    assert "4->2" in timeline
    assert "resumed on 2-shard mesh" in timeline
    assert telemetry_report.main([path, "--check"]) == 0
    # a corrupted record makes --check fail
    with open(path, "a") as f:
        f.write(json.dumps({"event": "round", "proc": 0, "seq": 99,
                            "t": 9.0, "round": 3}) + "\n")
    assert telemetry_report.main([path, "--check"]) == 1


def test_report_check_rejects_empty_and_non_monotone(tmp_path):
    from tools import telemetry_report
    assert telemetry_report.check([]) == ["no events"]
    assert "no round events" in telemetry_report.check(
        [{"event": "run_start", "proc": 0, "seq": 0, "t": 0.0}])
    rec = metrics.round_record(**_full_record())
    stream = [{"event": "round", "proc": 0, "seq": 0, "t": 0.0,
               **dict(rec, round=1)},
              {"event": "round", "proc": 0, "seq": 1, "t": 1.0,
               **dict(rec, round=0)}]
    assert any("not monotone" in p for p in telemetry_report.check(stream))


# ---------------------------------------------------------------------------
# driver integration (loop path is cheap enough for tier 1)
# ---------------------------------------------------------------------------
def _build_trainer(**kw):
    env_mod, cfg = registry.make("traffic", horizon=16)
    info = cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, hidden=(16,))
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="fnn",
                             hidden=(16,), epochs=2, batch=16)
    ppo_cfg = ppo_mod.PPOConfig(epochs=1, minibatches=2)
    kw.setdefault("shards", 1)
    kw.setdefault("outer_rounds", 2)
    kw.setdefault("aip_refresh", 2)
    dcfg = dials.DIALSConfig(
        collect_envs=2, collect_steps=16,
        n_envs=2, rollout_steps=8, eval_episodes=2, **kw)
    return dials.DIALSTrainer(env_mod, cfg, pc, ac, ppo_cfg, dcfg)


def test_loop_driver_emits_schema_clean_rounds(tmp_path):
    tel_dir = str(tmp_path / "tel")
    _, h_off = _build_trainer().run(jax.random.PRNGKey(0))
    _, h_on = _build_trainer(telemetry_dir=tel_dir).run(
        jax.random.PRNGKey(0))
    # history keys are exactly the typed schema, telemetry on or off
    for rec in h_off + h_on:
        assert set(rec) == set(metrics.ROUND_KEYS)
        assert metrics.validate_round(rec) == []
    # telemetry is observation only: numerics identical
    assert [r["gs_return"] for r in h_on] == \
        [r["gs_return"] for r in h_off]
    assert [r["aip_ce_after"] for r in h_on] == \
        [r["aip_ce_after"] for r in h_off]
    # the event log: run_start, one round per outer round, run_end
    events = sinks.read_jsonl(sinks.proc_path(tel_dir, 0))
    kinds = [e["event"] for e in events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert kinds.count("round") == 2
    rounds = [e for e in events if e["event"] == "round"]
    assert all(metrics.validate_round(e) == [] for e in rounds)
    # loop path measures real phases
    assert all(e["collect_s"] > 0 and e["inner_s"] > 0 and
               e["eval_s"] > 0 for e in rounds)
    assert all(e["mirror_s"] is None for e in rounds)
    from tools import telemetry_report
    assert telemetry_report.check(events) == []


def test_loop_driver_without_inner_steps_emits_null_reward(tmp_path):
    _, hist = _build_trainer(aip_refresh=0, outer_rounds=1).run(
        jax.random.PRNGKey(0))
    assert hist[0]["ials_reward"] is None
    assert set(hist[0]) == set(metrics.ROUND_KEYS)


# ---------------------------------------------------------------------------
# sharded path (1-shard mesh on the single real CPU device)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_driver_record_parity_and_jaxpr_unchanged(tmp_path):
    """The sharded driver's records carry the same typed key set as the
    loop driver's, and enabling telemetry leaves the traced round
    program structurally identical — observability cannot cost a host
    sync (asserted via the analysis walker's fingerprint plus the
    ScalarSyncBudget contract, not jaxpr string equality)."""
    _, h_loop = _build_trainer().run(jax.random.PRNGKey(0))

    plain = _build_trainer()
    state = plain.restore_or_init(jax.random.PRNGKey(0))
    _, h_plain = plain._run_sharded(state, 1, log=None,
                                    straggler_mask=None)

    tel_dir = str(tmp_path / "tel")
    teled = _build_trainer(telemetry_dir=tel_dir)
    state = teled.restore_or_init(jax.random.PRNGKey(0))
    _, h_tel = teled._run_sharded(state, 1, log=None, straggler_mask=None)

    for rec in h_plain + h_tel:
        assert set(rec) == set(metrics.ROUND_KEYS)
        assert metrics.validate_round(rec) == []
    assert {tuple(sorted(r)) for r in h_loop} == \
        {tuple(sorted(r)) for r in h_plain}          # driver parity
    # telemetry changes nothing the math can see
    assert [r["gs_return"] for r in h_tel] == \
        [r["gs_return"] for r in h_plain]
    # same primitive multiset at every program path — telemetry may not
    # add (or move) a single operation in the traced round
    import jax.numpy as jnp
    from repro.analysis import contracts, walker
    assert walker.fingerprint(teled._sharded.round_jaxpr()) == \
        walker.fingerprint(plain._sharded.round_jaxpr())
    # the once-per-round sync contract: the record half of the round
    # output is scalars from the typed schema, nothing else
    for runner in (plain._sharded, teled._sharded):
        carry = runner._abstract_carry()
        mask = jax.ShapeDtypeStruct(
            (plain.info.n_agents,), jnp.float32)
        prog = contracts.Program(
            name="test/round", roles=("round",), fn=runner.round,
            args=(carry, jax.ShapeDtypeStruct((2,), jnp.uint32),
                  jax.ShapeDtypeStruct((), jnp.int32), mask))
        assert contracts.ScalarSyncBudget().check(prog) == []
    # fused path: phase columns are explicit nulls, staleness on-mesh
    for r in h_plain:
        assert r["collect_s"] is None and r["aip_s"] is None
        assert r["staleness_max"] >= r["staleness_min"] >= 0
    events = sinks.read_jsonl(sinks.proc_path(tel_dir, 0))
    assert [e["event"] for e in events if e["event"] == "round"] != []


@pytest.mark.slow
def test_sharded_async_records_obtain_wait(tmp_path):
    tel_dir = str(tmp_path / "tel")
    tr = _build_trainer(async_collect=True, outer_rounds=3,
                        telemetry_dir=tel_dir)
    state = tr.restore_or_init(jax.random.PRNGKey(0))
    _, hist = tr._run_sharded(state, 1, log=None, straggler_mask=None)
    # async split path: collect_s is the obtain wait, a real number
    assert all(isinstance(r["collect_s"], float) for r in hist)
    events = sinks.read_jsonl(sinks.proc_path(tel_dir, 0))
    obtains = [e for e in events if e["event"] == "collect_obtain"]
    assert len(obtains) == 3
    assert obtains[0]["forced"] is True            # priming round
    assert [e["data_round"] for e in obtains] == [0, 0, 1]
