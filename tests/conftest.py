"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run slow tests (full reduced-arch sweeps)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test, needs --run-slow")
    # registered even when pytest-timeout is absent locally, so the
    # per-test @pytest.mark.timeout overrides never warn
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test timeout (pytest-timeout)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="needs --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
