"""Torn-write worker for ``tests/test_chaos.py``.

Saves a committed step 1, then saves step 2 with a ``writer_crash``
fault scheduled at the phase named on the command line — the
FaultSchedule SIGKILLs this process mid-write, leaving real torn state
on disk (tmp leaf files, an unrenamed slice, or a fully prepared but
uncommitted step, depending on the phase). The parent test then asserts
what a fresh manager makes of the wreckage.

Usage: ``python _chaos_check.py <ckpt_dir> <phase>``
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.checkpoint.distributed import DistributedCheckpointManager  # noqa: E402
from repro.distributed import chaos  # noqa: E402


def main():
    directory, phase = sys.argv[1], sys.argv[2]
    mgr = DistributedCheckpointManager(directory, keep=5,
                                       async_write=False)
    state = {"w": np.arange(12, dtype=np.float32).reshape(4, 3),
             "key": np.zeros((2,), np.uint32)}
    mgr.save(1, {**state, "round": 1},
             extra={"async_round": None, "reports": [0] * 4})
    sched = chaos.FaultSchedule.from_spec(f"crash@2:phase={phase}")
    mgr.hooks = sched.checkpoint_phase
    print("STEP1-COMMITTED", flush=True)
    mgr.save(2, {**{k: v + 1 for k, v in state.items()}, "round": 2},
             extra={"async_round": 1, "reports": [1] * 4})
    # unreachable: the writer_crash SIGKILLs this process mid-save
    print("SURVIVED", flush=True)
    return 1


if __name__ == "__main__":
    sys.exit(main())
