"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch family, run one forward/train step (and a decode step where
the arch has one) on CPU, assert output shapes and no NaNs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes as shapes_mod
from repro.models import api
from repro.optim import adamw

ARCHS = registry.list_archs()


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_brief(arch):
    """The full (non-reduced) config must carry the exact assigned
    hyper-parameters."""
    spec = registry.get(arch)
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    expect = {
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "mamba2-780m": (48, 1536, None, None, 0, 50280),
    }[arch]
    layers, dm, nh, kv, dff, vocab = expect
    if arch == "whisper-tiny":
        # each whisper decoder layer lowers as [self, cross+mlp] = 2 blocks
        layers = 2 * layers
    assert cfg.n_layers == layers
    assert cfg.d_model == dm
    assert cfg.vocab == vocab
    blocks_ = list(cfg.period) + ([cfg.shared] if cfg.shared else [])
    attns = [b.attn for b in blocks_ if b.attn is not None]
    if nh is not None:
        assert attns and attns[0].num_heads == nh
        assert attns[0].num_kv_heads == kv
    if dff:
        ffs = [b.mlp.d_ff for b in blocks_ if b.mlp is not None] + \
              [b.moe.d_ff for b in blocks_ if b.moe is not None]
        assert dff in ffs, (arch, ffs)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    """One loss+grad+optimizer step on the reduced config."""
    spec = registry.get(arch, reduced=True)
    shape = shapes_mod.REDUCED_SHAPES["train_4k"]
    params = api.init(rng, spec)
    batch = registry.concrete_inputs(rng, spec, shape)
    loss_fn = api.loss_fn(spec)

    def scalar_loss(p):
        loss, aux = loss_fn(p, batch)
        return loss, aux

    (loss, aux), grads = jax.value_and_grad(scalar_loss, has_aux=True)(params)
    assert jnp.isfinite(loss), (arch, float(loss))
    assert float(loss) > 0.0
    # grads finite and at least one nonzero
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves)
    assert any(float(jnp.max(jnp.abs(l.astype(jnp.float32)))) > 0
               for l in leaves)
    state = adamw.init(params)
    master, state = adamw.update(grads, state, 1e-4)
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_logits_smoke(arch, rng):
    spec = registry.get(arch, reduced=True)
    shape = shapes_mod.REDUCED_SHAPES["prefill_32k"]
    params = api.init(rng, spec)
    batch = registry.concrete_inputs(rng, spec, shape)
    from repro.models import lm as lm_mod, encdec as encdec_mod
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    if spec.kind == "encdec":
        enc = encdec_mod.encode(params, batch["frames"], spec.cfg)
        x, _ = lm_mod.forward(params["decoder"], batch["tokens"], cfg,
                              cross_kv=enc)
        logits = lm_mod.logits_fn(params["decoder"], x[:, -1:], cfg)
    elif spec.kind == "vlm":
        x, _ = lm_mod.forward(params, batch["tokens"], cfg,
                              cross_kv=batch["patches"])
        logits = lm_mod.logits_fn(params, x[:, -1:], cfg)
    else:
        x, _ = lm_mod.forward(params, batch["tokens"], cfg)
        logits = lm_mod.logits_fn(params, x[:, -1:], cfg)
    assert logits.shape == (shape.global_batch, 1, cfg.vocab)
    assert not jnp.any(jnp.isnan(logits.astype(jnp.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get(a).has_decode])
def test_decode_step_smoke(arch, rng):
    """One-token decode against a small cache on the reduced config."""
    spec = registry.get(arch, reduced=True)
    from repro.models import lm as lm_mod, encdec as encdec_mod
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    params = api.init(rng, spec)
    b, max_len = 2, 32
    binp = {}
    if spec.kind == "encdec":
        binp["frames"] = jnp.zeros((b, spec.n_frames, spec.cfg.d_model),
                                   jnp.bfloat16)
    if spec.kind == "vlm":
        binp["patches"] = jnp.zeros((b, spec.n_patches, spec.vision_dim),
                                    jnp.bfloat16)
    caches = api.init_caches(params, spec, b, max_len, batch_inputs=binp)
    token = jnp.zeros((b, 1), jnp.int32)
    if spec.kind == "encdec":
        logits, caches = encdec_mod.decode_step(
            params, token, caches, jnp.asarray(0, jnp.int32), spec.cfg)
    else:
        logits, caches = lm_mod.decode_step(
            params, token, caches, jnp.asarray(0, jnp.int32), cfg)
    assert logits.shape == (b, 1, cfg.vocab)
    assert not jnp.any(jnp.isnan(logits.astype(jnp.float32)))


def test_cell_support_rules():
    """long_500k only runs for sub-quadratic archs; whisper has decode."""
    for arch in ARCHS:
        spec = registry.get(arch)
        ok, why = registry.cell_supported(
            spec, shapes_mod.SHAPES["long_500k"])
        assert ok == spec.sub_quadratic, (arch, why)
    assert registry.get("mamba2-780m").sub_quadratic
    assert registry.get("zamba2-1.2b").sub_quadratic
    assert registry.get("gemma2-9b").sub_quadratic  # local+global alternation
