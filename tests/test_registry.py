"""Parameterized conformance suite for the environment registry.

Every env registered in ``repro.envs.registry`` must satisfy the fPOSG
module protocol of ``repro.envs.base``: EnvInfo shape consistency,
GS↔LS exactness on the shared per-region transition (the IBA property
the paper rests on), jit/vmap-ability of ``gs_step``/``ls_step``, and
the spatial-decomposition contract behind the sharded GS
(``region_partition`` tiles the agents, ``boundary_influence``
reproduces the replicated ``u``, and the block-decomposed rollout of
``repro.core.gs_sharded`` equals the replicated trajectory bit-for-bit).
A new env added to the registry inherits this whole suite for free."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import registry

ENVS = registry.names()


def _take(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _valid_block_counts(mod, cfg, max_blocks=None):
    from repro.core import gs_sharded
    n = cfg.info().n_agents
    return [b for b in range(1, (max_blocks or n) + 1)
            if gs_sharded.partition_supported(mod, cfg, b)[0]]


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------
def test_builtins_registered():
    assert {"powergrid", "supplychain", "traffic", "warehouse"} <= set(ENVS)
    assert len(ENVS) >= 4


def test_make_applies_sizer_and_overrides():
    _, cfg = registry.make("traffic", side=3, horizon=7)
    assert cfg.n == 3 and cfg.horizon == 7
    _, cfg = registry.make("powergrid", side=3)
    assert cfg.n_agents == 9            # sizer keeps agent counts ~side²
    mod, cfg = registry.make("warehouse")
    assert cfg == registry.get("warehouse").default_cfg
    assert mod is registry.get("warehouse").module


def test_unknown_env_raises():
    with pytest.raises(KeyError, match="unknown env"):
        registry.get("does-not-exist")


def test_clashing_register_raises():
    spec = registry.get("traffic")
    with pytest.raises(ValueError, match="already registered"):
        registry.register("traffic", registry, spec.default_cfg)
    # same-module re-registration (module reload) is idempotent
    registry.register("traffic", spec.module, spec.default_cfg,
                      sizer=spec.sizer)


def test_specs_expose_protocol():
    for name in ENVS:
        mod = registry.get(name).module
        for fn in ("gs_init", "gs_step", "gs_step_given", "gs_exo",
                   "gs_obs", "gs_locals", "exo_locals",
                   "ls_init", "ls_step", "ls_step_given", "ls_obs"):
            assert hasattr(mod, fn), f"{name} lacks {fn}"


# ---------------------------------------------------------------------------
# EnvInfo shape consistency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ENVS)
def test_info_shape_consistency(name):
    mod, cfg = registry.make(name, horizon=10)
    info = cfg.info()
    assert info.name == name
    assert info.alsh_dim == info.obs_dim + info.n_actions
    key = jax.random.PRNGKey(0)
    state = mod.gs_init(key, cfg)
    assert mod.gs_obs(state, cfg).shape == (info.n_agents, info.obs_dim)
    actions = jnp.zeros((info.n_agents,), jnp.int32)
    state2, obs, rew, u, done = mod.gs_step(state, actions, key, cfg)
    assert obs.shape == (info.n_agents, info.obs_dim)
    assert rew.shape == (info.n_agents,)
    assert u.shape == (info.n_agents, info.n_influence)
    assert done.shape == ()
    # influence sources are binary
    assert set(np.unique(np.asarray(u))) <= {0.0, 1.0}
    for leaf in jax.tree.leaves((obs, rew)):
        assert not jnp.any(jnp.isnan(leaf))
    # gs_locals restricts per agent; keys match the LS state (minus t)
    loc = mod.gs_locals(state, cfg)
    local = mod.ls_init(key, cfg)
    assert set(loc) == set(local) - {"t"}
    for k, v in loc.items():
        assert v.shape == (info.n_agents,) + local[k].shape
    # LS step shapes
    new, lobs, lrew, ldone = mod.ls_step(local, actions[0], u[0], key, cfg)
    assert lobs.shape == (info.obs_dim,)
    assert lrew.shape == () and ldone.shape == ()
    assert mod.ls_obs(local, cfg).shape == (info.obs_dim,)


# ---------------------------------------------------------------------------
# GS↔LS exactness (Definition 3, executable, for every env)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("side", [2, 3])
@pytest.mark.parametrize("name", ENVS)
def test_gs_ls_exactness(name, side):
    """Replay each region's GS trajectory through the LS with the same
    (action, u, exogenous draws) and require identical local states and
    rewards. side=3 covers interior regions (3x3 grids, 9-node rings)."""
    mod, cfg = registry.make(name, side=side, horizon=50)
    info = cfg.info()
    n = info.n_agents
    key = jax.random.PRNGKey(1)
    state = mod.gs_init(key, cfg)

    for t in range(15):
        key, ka, kx = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (n,), 0, info.n_actions)
        exo = mod.gs_exo(kx, cfg)
        loc_before = mod.gs_locals(state, cfg)
        state2, _, rew, u, _ = mod.gs_step_given(state, actions, exo, cfg)
        loc_after = mod.gs_locals(state2, cfg)
        exo_loc = mod.exo_locals(exo, cfg)
        for i in range(n):
            local = {**_take(loc_before, i), "t": state["t"]}
            new, _, r, _ = mod.ls_step_given(
                local, actions[i], u[i], _take(exo_loc, i), cfg)
            for k in loc_after:
                np.testing.assert_array_equal(
                    np.asarray(new[k]), np.asarray(loc_after[k][i]),
                    err_msg=f"{name}: agent {i} field {k} at t={t}")
            np.testing.assert_allclose(r, rew[i], atol=1e-6,
                                       err_msg=f"{name}: reward {i} t={t}")
        state = state2


# ---------------------------------------------------------------------------
# jit / vmap-ability (the Large-Batch-Simulation requirement)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ENVS)
def test_gs_ls_jit_vmap(name):
    mod, cfg = registry.make(name, horizon=10)
    info = cfg.info()
    n, n_envs = info.n_agents, 3
    keys = jax.random.split(jax.random.PRNGKey(2), n_envs)

    v_init = jax.jit(jax.vmap(lambda k: mod.gs_init(k, cfg)))
    states = v_init(keys)
    v_step = jax.jit(jax.vmap(lambda s, a, k: mod.gs_step(s, a, k, cfg)))
    actions = jnp.zeros((n_envs, n), jnp.int32)
    states2, obs, rew, u, done = v_step(states, actions, keys)
    assert obs.shape == (n_envs, n, info.obs_dim)
    assert done.shape == (n_envs,)

    # batched local sims over (E, N), as the IALS trainer runs them
    lkeys = jax.random.split(jax.random.PRNGKey(3), n_envs * n).reshape(
        n_envs, n, 2)
    v_ls_init = jax.jit(jax.vmap(jax.vmap(lambda k: mod.ls_init(k, cfg))))
    locals_ = v_ls_init(lkeys)
    v_ls_step = jax.jit(jax.vmap(jax.vmap(
        lambda l, a, u, k: mod.ls_step(l, a, u, k, cfg))))
    la = jnp.zeros((n_envs, n), jnp.int32)
    lu = jnp.zeros((n_envs, n, info.n_influence), jnp.float32)
    locals2, lobs, lrew, ldone = v_ls_step(locals_, la, lu, lkeys)
    assert lobs.shape == (n_envs, n, info.obs_dim)
    assert lrew.shape == (n_envs, n) and ldone.shape == (n_envs, n)


# ---------------------------------------------------------------------------
# spatial decomposition (the sharded-GS contract, for every env)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ENVS)
def test_region_partition_tiles_agents(name):
    """Every supported block count is a contiguous, equal-size,
    exactly-once cover of the agent axis; unsupported counts raise."""
    mod, cfg = registry.make(name, side=2, horizon=10)
    n = cfg.info().n_agents
    valid = _valid_block_counts(mod, cfg)
    assert 1 in valid, f"{name} must always support the 1-block split"
    for n_blocks in valid:
        part = np.asarray(mod.region_partition(cfg, n_blocks))
        assert part.shape == (n,)
        counts = np.bincount(part, minlength=n_blocks)
        assert (counts == n // n_blocks).all(), \
            f"{name}: blocks not equal-sized at {n_blocks}"
        assert (np.diff(part) >= 0).all(), f"{name}: not contiguous"
    with pytest.raises(ValueError):
        mod.region_partition(cfg, n + 1)     # can never tile
    # grid envs reject block counts that would split a row band
    if name in ("traffic", "warehouse"):
        assert 4 not in valid, \
            f"{name} side=2 must reject 4 blocks (half-row bands)"


@pytest.mark.parametrize("side", [2, 3])
@pytest.mark.parametrize("name", ENVS)
def test_boundary_influence_matches_replicated_u(name, side):
    """``boundary_influence`` on agent-major full data reproduces the
    realized ``u`` of ``gs_step_given`` bit-for-bit, along a rolled-out
    trajectory (so states are not just the init distribution)."""
    mod, cfg = registry.make(name, side=side, horizon=50)
    info = cfg.info()
    key = jax.random.PRNGKey(3)
    state = mod.gs_init(key, cfg)
    for _t in range(10):
        key, ka, kx = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (info.n_agents,), 0,
                                     info.n_actions)
        exo = mod.gs_exo(kx, cfg)
        u2 = mod.boundary_influence(mod.gs_locals(state, cfg), actions,
                                    exo, cfg)
        state, _, _, u, _ = mod.gs_step_given(state, actions, exo, cfg)
        assert u2.dtype == u.dtype
        np.testing.assert_array_equal(np.asarray(u2), np.asarray(u),
                                      err_msg=f"{name} side={side}")


@pytest.mark.parametrize("side", [2, 4])
@pytest.mark.parametrize("name", ENVS)
def test_block_decomposed_trajectory_is_bitwise(name, side):
    """The tentpole property: the region-decomposed GS step of
    ``repro.core.gs_sharded`` (block-local ``ls_step_given`` + one halo
    exchange), driven here by ``vmap`` over the block axis with the
    shard axis name, reproduces the replicated ``gs_step_given``
    trajectory bit-for-bit under a shared exo stream. side=2 covers
    every supported block count; side=4 runs only the largest supported
    count (4+ blocks), where the 3-block halo window no longer covers
    the whole system — the case that exercises the zero-padded rows of
    ``boundary_influence`` for the grid envs too."""
    from repro.core import gs_sharded
    from repro.distributed import runtime
    mod, cfg = registry.make(name, side=side, horizon=12)
    info = cfg.info()
    n = info.n_agents
    counts = _valid_block_counts(mod, cfg)
    if side > 2:
        counts = [max(counts)]
        assert counts[0] >= 4      # absent blocks really get zero rows
    for n_blocks in counts:
        bsz = n // n_blocks
        stack = lambda x: x.reshape((n_blocks, bsz) + x.shape[1:])
        unstack = lambda x: x.reshape((n,) + x.shape[2:])
        block_step = gs_sharded.make_block_step(mod, cfg,
                                                n_blocks=n_blocks)
        stepper = jax.jit(jax.vmap(
            block_step, in_axes=(0, None, 0, None),
            out_axes=(0, 0, 0, 0, None, None),
            axis_name=runtime.SHARD_AXIS))
        key = jax.random.PRNGKey(11)
        state = mod.gs_init(key, cfg)
        loc = jax.tree.map(stack, mod.gs_locals(state, cfg))
        t = state["t"]
        for step_i in range(12):
            key, ka, kx = jax.random.split(key, 3)
            actions = jax.random.randint(ka, (n,), 0, info.n_actions)
            exo = mod.gs_exo(kx, cfg)
            state, obs_r, rew_r, u_r, done_r = mod.gs_step_given(
                state, actions, exo, cfg)
            loc, obs_b, rew_b, u_b, done_b, t = stepper(
                loc, t, stack(actions), exo)
            ref = mod.gs_locals(state, cfg)
            for k in ref:
                np.testing.assert_array_equal(
                    np.asarray(unstack(loc[k])), np.asarray(ref[k]),
                    err_msg=f"{name} b={n_blocks} {k} t={step_i}")
            for got, want, what in ((u_b, u_r, "u"), (obs_b, obs_r, "obs"),
                                    (rew_b, rew_r, "rew")):
                np.testing.assert_array_equal(
                    np.asarray(unstack(got)), np.asarray(want),
                    err_msg=f"{name} b={n_blocks} {what} t={step_i}")
            assert bool(done_b) == bool(done_r)


# ---------------------------------------------------------------------------
# launch-layer scenario presets resolve through the registry
# ---------------------------------------------------------------------------
def test_marl_scenarios_resolve():
    from repro.launch import variants
    assert len(variants.MARL_SCENARIOS) >= 2 * len(ENVS)
    for scen, (env_name, _side) in variants.MARL_SCENARIOS.items():
        assert env_name in ENVS, scen
    mod, cfg = variants.marl_scenario("powergrid-ring4", horizon=5)
    assert cfg.n_agents == 4 and cfg.horizon == 5
    assert mod is registry.get("powergrid").module


def test_default_cfgs_are_frozen_dataclasses():
    for name in ENVS:
        cfg = registry.get(name).default_cfg
        assert dataclasses.is_dataclass(cfg)
        with pytest.raises(dataclasses.FrozenInstanceError):
            setattr(cfg, "horizon", 1)
