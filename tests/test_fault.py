"""Fault-tolerance substrate: straggler plans, bounded-staleness updates,
heartbeats, elastic resharding, and restart-safe data feeding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra absent: property tests skip
    from _hypothesis_stub import given, settings, st


from repro.data import pipeline
from repro.distributed import fault, mesh as mesh_lib


# ---------------------------------------------------------------------------
# straggler plan
# ---------------------------------------------------------------------------
def test_straggler_plan_reassigns_to_healthy():
    plan = fault.straggler_plan(8, late=[2, 5])
    assert set(plan.healthy) == {0, 1, 3, 4, 6, 7}
    for late in (2, 5):
        assert plan.owner(late) in plan.healthy
    # healthy shards keep their own work
    assert plan.owner(0) == 0 and plan.owner(7) == 7


def test_straggler_plan_all_late_raises():
    with pytest.raises(RuntimeError):
        fault.straggler_plan(3, late=[0, 1, 2])


@given(st.integers(2, 32), st.data())
@settings(max_examples=30, deadline=None)
def test_straggler_plan_deterministic_and_total(n, data):
    late = data.draw(st.lists(st.integers(0, n - 1), max_size=n - 1,
                              unique=True))
    p1 = fault.straggler_plan(n, late)
    p2 = fault.straggler_plan(n, list(reversed(late)))
    assert p1 == p2                     # host-order independent
    # every work unit has a healthy owner
    for w in range(n):
        assert p1.owner(w) in p1.healthy or w in p1.healthy


# ---------------------------------------------------------------------------
# elastic shard reassignment (host loss)
# ---------------------------------------------------------------------------
def test_elastic_plan_ownership_is_a_partition():
    plan = fault.elastic_plan(8, 4, dead=[1, 3])
    assert plan.survivors == (0, 2)
    assert plan.new_shards == 2            # choose_shards(8, 2)
    owners = [plan.agent_owner(a) for a in range(8)]
    # every agent has exactly one owner and blocks stay contiguous
    assert owners == [0, 0, 0, 0, 1, 1, 1, 1]
    for s in range(plan.new_shards):
        assert owners.count(s) == plan.n_agents // plan.new_shards


def test_elastic_plan_dead_blocks_land_on_survivors():
    plan = fault.elastic_plan(8, 4, dead=[2, 3])
    assert plan.reassigned_blocks == (2, 3)
    for block in plan.dead:
        assert 0 <= plan.owner(block) < plan.new_shards
    # the old healthy blocks also map into the shrunken mesh
    for block in range(plan.old_shards):
        assert 0 <= plan.owner(block) < plan.new_shards


def test_elastic_plan_non_divisible_survivors_pick_divisor():
    # 3 survivors do not divide 8 agents: the plan shrinks to 2 shards
    # (largest divisor that fits) rather than leaving a ragged tile
    plan = fault.elastic_plan(8, 4, dead=[1])
    assert plan.survivors == (0, 2, 3)
    assert plan.new_shards == 2


def test_elastic_plan_all_dead_raises():
    with pytest.raises(RuntimeError):
        fault.elastic_plan(4, 2, dead=[0, 1])
    with pytest.raises(ValueError):
        fault.elastic_plan(4, 2, dead=[5])


@given(st.integers(1, 6), st.integers(1, 5), st.data())
@settings(max_examples=40, deadline=None)
def test_elastic_plan_partition_property(blocks_per_shard, n_shards, data):
    n_agents = n_shards * blocks_per_shard
    dead = data.draw(st.lists(st.integers(0, n_shards - 1), max_size=n_shards - 1,
                              unique=True)) if n_shards > 1 else []
    plan = fault.elastic_plan(n_agents, n_shards, dead)
    per = n_agents // plan.new_shards
    counts = [0] * plan.new_shards
    for a in range(n_agents):
        counts[plan.agent_owner(a)] += 1
    assert counts == [per] * plan.new_shards


def test_elastic_plan_emits_reassign_telemetry(tmp_path):
    """The replan is reconstructable from the event log alone: dead
    blocks, the shrink, and the block -> new-owner mapping."""
    from repro import obs
    from repro.obs import sinks
    tel = obs.Telemetry.create(str(tmp_path), process_id=0)
    plan = fault.elastic_plan(8, 4, dead=[2, 3], telemetry=tel)
    tel.close()
    events = sinks.read_jsonl(sinks.proc_path(str(tmp_path), 0))
    assert [e["event"] for e in events] == ["elastic_reassign"]
    e = events[0]
    assert e["old_shards"] == 4 and e["new_shards"] == plan.new_shards
    assert e["dead_blocks"] == [2, 3] and e["survivors"] == [0, 1]
    # JSON keys are strings; values are the plan's owner() per dead block
    assert e["moved"] == {str(b): plan.owner(b) for b in plan.dead}
    # telemetry=None (the default) emits nothing and still plans
    assert fault.elastic_plan(8, 4, dead=[2, 3]) == plan


def test_host_monitor_death_emits_telemetry(tmp_path):
    """Death detection shows up in the event log exactly once per host
    (sticky deadness means no re-reporting)."""
    from repro import obs
    from repro.obs import sinks
    beat_dir = tmp_path / "beats"
    tel = obs.Telemetry.create(str(tmp_path / "tel"), process_id=0)
    m0 = fault.HostMonitor(str(beat_dir), host=0, n_hosts=2,
                           timeout_s=0.5, poll_s=0.01, telemetry=tel)
    m1 = fault.HostMonitor(str(beat_dir), host=1, n_hosts=2,
                           timeout_s=0.5, poll_s=0.01)
    m1.beat(0)
    assert m0.gate(0) == ()                 # everyone alive: no event
    assert m0.gate(1) == (1,)               # silent host 1 -> death event
    assert m0.gate(2) == ()                 # sticky: no second event
    tel.close()
    events = sinks.read_jsonl(sinks.proc_path(str(tmp_path / "tel"), 0))
    deaths = [e for e in events if e["event"] == "host_death"]
    assert len(deaths) == 1
    assert deaths[0]["round"] == 1 and deaths[0]["dead_hosts"] == [1]
    assert deaths[0]["all_dead"] == [1]
    assert deaths[0]["timeout_s"] == 0.5


def test_host_monitor_detects_silent_host(tmp_path):
    m0 = fault.HostMonitor(str(tmp_path), host=0, n_hosts=2,
                           timeout_s=0.5, poll_s=0.01)
    m1 = fault.HostMonitor(str(tmp_path), host=1, n_hosts=2,
                           timeout_s=0.5, poll_s=0.01)
    # both alive: beat each other for round 0
    m1.beat(0)
    assert m0.gate(0) == ()
    # host 1 goes silent for round 1: timeout -> declared dead
    assert m0.gate(1) == (1,)
    assert m0.dead == {1}
    # sticky: a dead host is never waited on (or re-reported) again
    assert m0.gate(2) == ()


def test_reshard_agents_roundtrips_through_fault_reshard():
    """Shrinking an agent-stacked tree from a 4-shard to a 2-shard mesh
    (the elastic move) preserves values and places each old block on the
    shard the plan assigns. Subprocess with 8 forced devices so the main
    process keeps its single CPU device."""
    import os
    import subprocess
    import sys
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import fault, runtime

n_agents = 8
tree = {'w': jnp.arange(n_agents * 3, dtype=jnp.float32).reshape(n_agents, 3),
        'r': jnp.arange(n_agents, dtype=jnp.int32)}
old_mesh = runtime.shard_mesh(4)
placed = runtime.shard_agent_tree(tree, old_mesh)

plan = fault.elastic_plan(n_agents, 4, dead=[2, 3])
survivors = [d for i, d in enumerate(old_mesh.devices.flat)
             if i not in plan.dead]
new_mesh = runtime.shard_mesh(plan.new_shards, devices=survivors)
out = fault.reshard_agents(placed, new_mesh)

np.testing.assert_array_equal(np.asarray(out['w']), np.asarray(tree['w']))
np.testing.assert_array_equal(np.asarray(out['r']), np.asarray(tree['r']))
assert out['w'].sharding.mesh.shape == {'shards': 2}

# per-device slices match the plan's even tiling: new shard s owns
# agents [s*per, (s+1)*per)
per = n_agents // plan.new_shards
for db in out['w'].addressable_shards:
    lo = db.index[0].start or 0
    np.testing.assert_array_equal(
        np.asarray(db.data), np.asarray(tree['w'][lo:lo + per]))
for a in range(n_agents):
    assert plan.agent_owner(a) == a // per
print('reshard-agents ok')
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=900,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "reshard-agents ok" in out.stdout


# ---------------------------------------------------------------------------
# bounded-staleness updates + heartbeat
# ---------------------------------------------------------------------------
def test_masked_tree_update_mixes_per_agent():
    old = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((4,))}
    new = {"w": jnp.ones((4, 3)), "b": jnp.ones((4,))}
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    out = fault.masked_tree_update(old, new, mask)
    np.testing.assert_allclose(out["w"][0], 1.0)
    np.testing.assert_allclose(out["w"][1], 0.0)
    np.testing.assert_allclose(out["b"], [1.0, 0.0, 1.0, 0.0])


def test_heartbeat_mask():
    reports = jnp.array([10, 8, 3, 10])
    mask = fault.heartbeat_mask(reports, current_step=10, max_staleness=2)
    np.testing.assert_array_equal(np.asarray(mask), [1.0, 1.0, 0.0, 1.0])


def test_freshness_gate_forces_refresh_past_bound():
    """A straggler (mask 0) keeps its old predictor only while its data
    is within max_staleness rounds; past the bound the gate overrides the
    mask (forced refresh) and the report round advances."""
    reports = jnp.array([4, 4, 1, 1], jnp.int32)
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    eff, new_reports, forced = fault.freshness_gate(
        mask, reports, data_round=5, current_round=5, max_staleness=2)
    # agent 1: stale by 1 round only -> straggle allowed
    # agent 3: stale by 4 rounds -> forced through
    np.testing.assert_array_equal(np.asarray(eff), [1.0, 0.0, 1.0, 1.0])
    np.testing.assert_array_equal(np.asarray(forced), [0.0, 0.0, 0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(new_reports), [5, 4, 5, 5])


def test_freshness_gate_zero_bound_forces_everyone():
    reports = jnp.full((3,), -1, jnp.int32)
    eff, new_reports, forced = fault.freshness_gate(
        jnp.zeros((3,)), reports, data_round=0, current_round=0,
        max_staleness=0)
    np.testing.assert_array_equal(np.asarray(eff), [1.0, 1.0, 1.0])
    np.testing.assert_array_equal(np.asarray(new_reports), [0, 0, 0])


def test_freshness_gate_jits_and_traces_round():
    """The gate runs inside the sharded round program: must accept traced
    round scalars under jit."""
    f = jax.jit(lambda m, r, d, c: fault.freshness_gate(m, r, d, c, 2))
    eff, rep, forced = f(jnp.zeros((2,)), jnp.zeros((2,), jnp.int32),
                         jnp.asarray(3), jnp.asarray(3))
    np.testing.assert_array_equal(np.asarray(eff), [1.0, 1.0])
    np.testing.assert_array_equal(np.asarray(rep), [3, 3])
    np.testing.assert_array_equal(np.asarray(forced), [1.0, 1.0])


# ---------------------------------------------------------------------------
# elastic resharding (host mesh scale)
# ---------------------------------------------------------------------------
def test_reshard_roundtrips_values():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    spec = {"w": ("embed", "mlp")}
    out = fault.reshard(tree, spec, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding.mesh.shape == {"data": 1, "model": 1}


# ---------------------------------------------------------------------------
# restart-safe pipeline
# ---------------------------------------------------------------------------
def test_lm_iterator_restart_resumes_mid_stream():
    it = pipeline.lm_iterator(seed=3, batch=2, seq=8, vocab=64)
    first = [next(it) for _ in range(5)]
    resumed = pipeline.lm_iterator(seed=3, batch=2, seq=8, vocab=64,
                                   start_step=3)
    np.testing.assert_array_equal(np.asarray(first[3]["tokens"]),
                                  np.asarray(next(resumed)["tokens"]))
    np.testing.assert_array_equal(np.asarray(first[4]["tokens"]),
                                  np.asarray(next(resumed)["tokens"]))


def test_shard_batch_places_on_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = {"tokens": jnp.zeros((4, 8), jnp.int32)}
    out = pipeline.shard_batch(batch, mesh)
    assert out["tokens"].sharding.mesh.shape == {"data": 1, "model": 1}


def test_with_extras_attaches_modalities():
    it = pipeline.lm_iterator(seed=0, batch=2, seq=4, vocab=16)
    it2 = pipeline.with_extras(
        it, lambda step: {"frames": jnp.full((2, 3, 8), step, jnp.bfloat16)})
    b0 = next(it2)
    b1 = next(it2)
    assert "frames" in b0 and float(b1["frames"][0, 0, 0]) == 1.0


def test_elastic_reshard_across_mesh_shapes():
    """Elastic restart: checkpoint written under one mesh restores onto a
    different mesh shape with identical values (8 fake devices,
    (4,2) -> (2,4) -> (8,1)). Subprocess so the main process keeps 1 CPU
    device."""
    import os
    import subprocess
    import sys
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import ckpt
from repro.distributed import fault, mesh as mesh_lib

tree = {'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        'b': jnp.ones((8,), jnp.bfloat16)}
spec = {'w': ('embed', 'mlp'), 'b': ('mlp',)}

d = tempfile.mkdtemp()
m1 = jax.make_mesh((4, 2), ('data', 'model'))
t1 = fault.reshard(tree, spec, m1, fsdp_axes=('data',))
ckpt.save(d, t1, step=1)

for shape in ((2, 4), (8, 1), (1, 8)):
    m2 = jax.make_mesh(shape, ('data', 'model'))
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh = mesh_lib.logical_to_sharding(spec, sds, m2, fsdp_axes=('data',))
    back, step = ckpt.restore(d, sds, shardings=sh)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(back['w']), np.asarray(tree['w']))
    np.testing.assert_array_equal(np.asarray(back['b'], np.float32),
                                  np.asarray(tree['b'], np.float32))
    assert dict(back['w'].sharding.mesh.shape) == dict(zip(('data','model'), shape))
print('elastic ok')
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo",
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "elastic ok" in out.stdout
