"""AOT step builders + §Perf variants lower and run on the host mesh with
reduced configs — guards every named variant against API drift."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes as shapes_mod
from repro.launch import mesh as prod_mesh, steps as steps_mod, variants

HOST = prod_mesh.make_host_mesh()


def _lower(spec, shape, kw):
    bundle = steps_mod.make_step(spec, shape, HOST, **kw)
    compiled = bundle.jit_fn.lower(*bundle.arg_sds).compile()
    assert compiled.cost_analysis() is not None
    return bundle


@pytest.mark.parametrize("variant", sorted(variants.VARIANTS))
def test_every_variant_lowers_on_host_mesh(variant):
    arch = ("granite-moe-1b-a400m" if variant.startswith("moe")
            else "tinyllama-1.1b")
    shape_name = ("decode_32k" if variant.startswith("decode")
                  else "train_4k")
    spec = registry.get(arch, reduced=True)
    shape = shapes_mod.REDUCED_SHAPES[shape_name]
    if "mb" in variant:                    # accumulation needs batch % mb
        import dataclasses
        shape = dataclasses.replace(shape, global_batch=8)
    kw = variants.VARIANTS[variant](spec, shape)
    spec = kw.pop("spec", spec)
    _lower(spec, shape, kw)


def test_train_step_executes_on_host_mesh():
    """The AOT train step actually runs (not just compiles): one step on
    concrete reduced inputs, loss finite."""
    spec = registry.get("tinyllama-1.1b", reduced=True)
    shape = shapes_mod.REDUCED_SHAPES["train_4k"]
    bundle = steps_mod.make_train_step(spec, shape, HOST)
    key = jax.random.PRNGKey(0)
    from repro.models import api
    from repro.optim import adamw
    params = api.init(key, spec)
    opt = adamw.init(params)
    batch = registry.concrete_inputs(key, spec, shape)
    params2, opt2, metrics = bundle.jit_fn(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(opt2["step"]) == 1


def test_serve_step_executes_on_host_mesh():
    spec = registry.get("tinyllama-1.1b", reduced=True)
    shape = shapes_mod.REDUCED_SHAPES["decode_32k"]
    bundle = steps_mod.make_serve_step(spec, shape, HOST)
    from repro.models import api
    params = api.init(jax.random.PRNGKey(0), spec)
    caches = api.init_caches(params, spec, shape.global_batch,
                             shape.seq_len)
    token = jnp.zeros((shape.global_batch, 1), jnp.int32)
    logits, new_caches = bundle.jit_fn(params, token, caches,
                                       jnp.zeros((), jnp.int32))
    cfg = spec.cfg
    assert logits.shape == (shape.global_batch, 1, cfg.vocab)
    assert not jnp.any(jnp.isnan(logits.astype(jnp.float32)))
