"""Environment tests, centred on the IBA exactness property the whole
paper rests on: given the realized influence sources u, the local
simulator reproduces the global simulator's per-region transition
EXACTLY (the GS and LS share the per-region step function, and u
d-separates the region from the rest of the system)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.envs import traffic, warehouse


# ---------------------------------------------------------------------------
# Warehouse
# ---------------------------------------------------------------------------
def test_warehouse_shapes():
    cfg = warehouse.WarehouseConfig(k=2, horizon=10)
    info = cfg.info()
    key = jax.random.PRNGKey(0)
    state = warehouse.gs_init(key, cfg)
    obs = warehouse.gs_obs(state, cfg)
    assert obs.shape == (info.n_agents, info.obs_dim)
    actions = jnp.zeros((info.n_agents,), jnp.int32)
    state2, obs2, rew, u, done = warehouse.gs_step(state, actions, key, cfg)
    assert obs2.shape == (info.n_agents, info.obs_dim)
    assert rew.shape == (info.n_agents,)
    assert u.shape == (info.n_agents, info.n_influence)
    assert done.shape == ()
    for leaf in jax.tree.leaves((obs2, rew)):
        assert not jnp.any(jnp.isnan(leaf))


@pytest.mark.parametrize("k", [2, 3])
def test_warehouse_gs_ls_exactness(k):
    """Replay each region's GS trajectory through the LS with the same
    (action, u, spawn) and require identical local states and rewards —
    the executable form of Eq. (1)/Definition 3."""
    cfg = warehouse.WarehouseConfig(k=k, horizon=50)
    n = cfg.n_agents
    cells = jnp.asarray(warehouse.item_cells(cfg))
    key = jax.random.PRNGKey(1)
    state = warehouse.gs_init(key, cfg)

    for t in range(20):
        key, ka, ks = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (n,), 0, 5)
        spawn_grid = jax.random.bernoulli(ks, cfg.p_item,
                                          (cfg.grid, cfg.grid))
        loc_before = warehouse.gs_locals(state, cfg)
        state2, _, rew, u, _ = warehouse.gs_step_given(
            state, actions, spawn_grid, cfg)
        loc_after = warehouse.gs_locals(state2, cfg)
        # per-region LS replay
        spawn = spawn_grid[cells[..., 0], cells[..., 1]]       # (N, 12)
        for i in range(n):
            local = {"pos": loc_before["pos"][i],
                     "ages": loc_before["ages"][i],
                     "t": state["t"]}
            new, _, r, _ = warehouse.ls_step_given(
                local, actions[i], u[i], spawn[i], cfg)
            np.testing.assert_array_equal(new["pos"], loc_after["pos"][i])
            np.testing.assert_array_equal(new["ages"], loc_after["ages"][i])
            np.testing.assert_allclose(r, rew[i], atol=1e-6)
        state = state2


def test_warehouse_influence_semantics():
    """u[i, c] is true iff ANOTHER robot stands on region i's item cell c."""
    cfg = warehouse.WarehouseConfig(k=2)
    # robot 1 (region (0,1), origin (0,4)) at local (0,1) -> abs (0,5).
    # region 0's east shelf is at abs (1..3,4); its north shelf (0,1..3).
    # Put robot 1 on abs (1,4): local pos (1,0) of region 1.
    pos = jnp.array([[2, 2], [1, 0], [2, 2], [2, 2]], jnp.int32)
    u = warehouse.gs_influence(pos, cfg)
    # region 0: cell index 3 is (r0+1, c0+4) = (1,4) -> influenced
    assert bool(u[0, 3])
    # the robot itself doesn't influence its own region
    assert not bool(u[1].any())


@given(st.integers(0, 4), st.integers(0, 4), st.integers(0, 4),
       st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_warehouse_region_step_invariants(r, c, action, seed):
    """Property: ages stay >= 0; reward in [0, 12]; occupied u-cells and
    self-collected cells are emptied; position stays in the 5x5 region."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    ages = jax.random.randint(k1, (12,), 0, 10)
    u = jax.random.bernoulli(k2, 0.3, (12,))
    spawn = jax.random.bernoulli(k3, 0.3, (12,))
    pos = jnp.array([r, c], jnp.int32)
    new_pos, new_ages, reward, on_item = warehouse.region_step(
        pos, ages, jnp.asarray(action), u, spawn)
    assert (new_ages >= 0).all()
    assert 0.0 <= float(reward) <= 12.0
    assert (new_pos >= 0).all() and (new_pos <= 4).all()
    # a cell with a neighbour robot on it cannot retain an item (unless
    # respawned this step)
    stolen = u & (ages > 0) & ~spawn
    assert not bool((new_ages[stolen] > 0).any())


# ---------------------------------------------------------------------------
# Traffic
# ---------------------------------------------------------------------------
def test_traffic_shapes():
    cfg = traffic.TrafficConfig(n=2, horizon=10)
    info = cfg.info()
    key = jax.random.PRNGKey(0)
    state = traffic.gs_init(key, cfg)
    obs = traffic.gs_obs(state, cfg)
    assert obs.shape == (info.n_agents, info.obs_dim)
    actions = jnp.zeros((info.n_agents,), jnp.int32)
    state2, obs2, rew, u, done = traffic.gs_step(state, actions, key, cfg)
    assert u.shape == (info.n_agents, info.n_influence)
    assert rew.shape == (info.n_agents,)
    for leaf in jax.tree.leaves((obs2, rew)):
        assert not jnp.any(jnp.isnan(leaf))


@pytest.mark.parametrize("n", [2, 3])
def test_traffic_gs_ls_exactness(n):
    """Same exactness property for the traffic env: replaying each
    intersection through the LS with the GS's realized inflow u gives
    identical lanes/phase/reward."""
    cfg = traffic.TrafficConfig(n=n, horizon=50)
    na = cfg.n_agents
    key = jax.random.PRNGKey(2)
    state = traffic.gs_init(key, cfg)

    for t in range(20):
        key, ka, ki = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (na,), 0, 2)
        inject = jax.random.bernoulli(ki, cfg.p_in, (cfg.n, cfg.n, 4))
        loc_before = traffic.gs_locals(state, cfg)
        state2, _, rew, u, _ = traffic.gs_step_given(
            state, actions, inject, cfg)
        loc_after = traffic.gs_locals(state2, cfg)
        for i in range(na):
            local = {"lanes": loc_before["lanes"][i],
                     "phase": loc_before["phase"][i], "t": state["t"]}
            new, _, r, _ = traffic.ls_step(
                local, actions[i], u[i], None, cfg)
            np.testing.assert_array_equal(new["lanes"],
                                          loc_after["lanes"][i])
            np.testing.assert_array_equal(new["phase"],
                                          loc_after["phase"][i])
            np.testing.assert_allclose(r, rew[i], atol=1e-6)
        state = state2


def test_traffic_coupling_via_influence_only():
    """Cars leaving intersection A must show up as inflow u at the
    neighbouring intersection — the hand-off is the only coupling."""
    cfg = traffic.TrafficConfig(n=2, p_in=0.0, init_density=0.9)
    key = jax.random.PRNGKey(3)
    state = traffic.gs_init(key, cfg)
    total_u = 0.0
    for t in range(10):
        key, ka, kk = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (cfg.n_agents,), 0, 2)
        state, _, _, u, _ = traffic.gs_step(state, actions, kk, cfg)
        total_u += float(u.sum())
    assert total_u > 0, "no inter-region influence despite dense traffic"


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_traffic_lane_step_conservation(seed):
    """Property: cars are conserved — new count = old count + inflow
    − crossed."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    lanes = jax.random.bernoulli(k1, 0.4, (4, 8))
    green = jax.random.bernoulli(k2, 0.5, (4,))
    inflow = jax.random.bernoulli(k3, 0.5, (4,))
    new_lanes, out, moved, count = traffic.lane_step(lanes, green, inflow)
    old = int(lanes.sum())
    delta = int(new_lanes.sum()) - (old - int(out.sum()))
    # conservation: cars only appear through inflow, only vanish by crossing
    assert 0 <= delta <= int(inflow.sum())
    assert 0 <= int(new_lanes.sum()) <= 32
    # crossed cars require green and an occupied stop line
    crossed = np.asarray(out)
    assert not np.any(crossed & ~np.asarray(green & lanes[:, -1]))
    assert float(count) == old
