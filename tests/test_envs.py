"""Env-specific semantics tests. The generic per-env contract — EnvInfo
shape consistency, GS↔LS exactness on the shared transition, and
jit/vmap-ability — is covered for EVERY registered env by the
parameterized conformance suite in ``test_registry.py``; here we pin the
meaning of each env's influence sources and transition invariants."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra absent: property tests skip
    from _hypothesis_stub import given, settings, st

from repro.envs import powergrid, supplychain, traffic, warehouse


# ---------------------------------------------------------------------------
# Warehouse
# ---------------------------------------------------------------------------
def test_warehouse_influence_semantics():
    """u[i, c] is true iff ANOTHER robot stands on region i's item cell c."""
    cfg = warehouse.WarehouseConfig(k=2)
    # robot 1 (region (0,1), origin (0,4)) at local (0,1) -> abs (0,5).
    # region 0's east shelf is at abs (1..3,4); its north shelf (0,1..3).
    # Put robot 1 on abs (1,4): local pos (1,0) of region 1.
    pos = jnp.array([[2, 2], [1, 0], [2, 2], [2, 2]], jnp.int32)
    u = warehouse.gs_influence(pos, cfg)
    # region 0: cell index 3 is (r0+1, c0+4) = (1,4) -> influenced
    assert bool(u[0, 3])
    # the robot itself doesn't influence its own region
    assert not bool(u[1].any())


@given(st.integers(0, 4), st.integers(0, 4), st.integers(0, 4),
       st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_warehouse_region_step_invariants(r, c, action, seed):
    """Property: ages stay >= 0; reward in [0, 12]; occupied u-cells and
    self-collected cells are emptied; position stays in the 5x5 region."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    ages = jax.random.randint(k1, (12,), 0, 10)
    u = jax.random.bernoulli(k2, 0.3, (12,))
    spawn = jax.random.bernoulli(k3, 0.3, (12,))
    pos = jnp.array([r, c], jnp.int32)
    new_pos, new_ages, reward, on_item = warehouse.region_step(
        pos, ages, jnp.asarray(action), u, spawn)
    assert (new_ages >= 0).all()
    assert 0.0 <= float(reward) <= 12.0
    assert (new_pos >= 0).all() and (new_pos <= 4).all()
    # a cell with a neighbour robot on it cannot retain an item (unless
    # respawned this step)
    stolen = u & (ages > 0) & ~spawn
    assert not bool((new_ages[stolen] > 0).any())


# ---------------------------------------------------------------------------
# Traffic
# ---------------------------------------------------------------------------
def test_traffic_coupling_via_influence_only():
    """Cars leaving intersection A must show up as inflow u at the
    neighbouring intersection — the hand-off is the only coupling."""
    cfg = traffic.TrafficConfig(n=2, p_in=0.0, init_density=0.9)
    key = jax.random.PRNGKey(3)
    state = traffic.gs_init(key, cfg)
    total_u = 0.0
    for t in range(10):
        key, ka, kk = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (cfg.n_agents,), 0, 2)
        state, _, _, u, _ = traffic.gs_step(state, actions, kk, cfg)
        total_u += float(u.sum())
    assert total_u > 0, "no inter-region influence despite dense traffic"


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_traffic_lane_step_conservation(seed):
    """Property: cars are conserved — new count = old count + inflow
    − crossed."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    lanes = jax.random.bernoulli(k1, 0.4, (4, 8))
    green = jax.random.bernoulli(k2, 0.5, (4,))
    inflow = jax.random.bernoulli(k3, 0.5, (4,))
    new_lanes, out, moved, count = traffic.lane_step(lanes, green, inflow)
    old = int(lanes.sum())
    delta = int(new_lanes.sum()) - (old - int(out.sum()))
    # conservation: cars only appear through inflow, only vanish by crossing
    assert 0 <= delta <= int(inflow.sum())
    assert 0 <= int(new_lanes.sum()) <= 32
    # crossed cars require green and an occupied stop line
    crossed = np.asarray(out)
    assert not np.any(crossed & ~np.asarray(green & lanes[:, -1]))
    assert float(count) == old


# ---------------------------------------------------------------------------
# Power grid
# ---------------------------------------------------------------------------
def test_powergrid_influence_semantics():
    """u[i] = [left_over, left_under, right_over, right_under] of i's ring
    neighbours, from the pre-step state."""
    cfg = powergrid.PowerGridConfig(n_buses=4, feeder=3, v_levels=9)
    nom = cfg.nominal
    volts = jnp.full((4, 3), nom, jnp.int32)
    volts = volts.at[1, 0].set(cfg.v_levels - 1)      # bus 1 over-voltage
    volts = volts.at[3, 2].set(0)                     # bus 3 under-voltage
    state = {"volts": volts, "tap": jnp.zeros((4,), jnp.int32),
             "t": jnp.zeros((), jnp.int32)}
    u = powergrid.gs_influence(state, cfg)
    # bus 2: left neighbour is bus 1 (over), right neighbour bus 3 (under)
    np.testing.assert_array_equal(np.asarray(u[2]), [1, 0, 0, 1])
    # bus 0: left neighbour is bus 3 (under), right neighbour bus 1 (over)
    np.testing.assert_array_equal(np.asarray(u[0]), [0, 1, 1, 0])
    # bus 1 sees only in-band neighbours (0 and 2)
    assert not bool(u[1].any())


def test_powergrid_push_and_tap_saturation():
    cfg = powergrid.PowerGridConfig(feeder=3)
    volts = jnp.full((3,), cfg.nominal, jnp.int32)
    zero_load = jnp.zeros((3,), jnp.int32)
    # both neighbours over-voltage push this feeder up by 2
    u = jnp.array([1, 0, 1, 0], bool)
    nv, nt, _ = powergrid.bus_step(volts, jnp.zeros((), jnp.int32),
                                   jnp.ones((), jnp.int32), u, zero_load,
                                   cfg)
    assert (np.asarray(nv) == cfg.nominal + 2).all()
    # tap saturates at +/- TAP_MAX
    tap = jnp.asarray(powergrid.TAP_MAX, jnp.int32)
    _, nt, _ = powergrid.bus_step(volts, tap, jnp.asarray(2), u * 0,
                                  zero_load, cfg)
    assert int(nt) == powergrid.TAP_MAX


@given(st.integers(0, 2), st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_powergrid_bus_step_invariants(action, seed):
    """Property: volts stay in [0, v_levels); tap in [-2, 2]; reward is a
    fraction in [0, 1]."""
    cfg = powergrid.PowerGridConfig(feeder=5)
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    volts = jax.random.randint(k1, (5,), 0, cfg.v_levels)
    tap = jax.random.randint(k2, (), -powergrid.TAP_MAX,
                             powergrid.TAP_MAX + 1)
    u = jax.random.bernoulli(k3, 0.5, (4,))
    load = jax.random.randint(jax.random.fold_in(k, 1), (5,), -1, 2)
    nv, nt, rew = powergrid.bus_step(volts, tap, jnp.asarray(action), u,
                                     load, cfg)
    assert (np.asarray(nv) >= 0).all()
    assert (np.asarray(nv) < cfg.v_levels).all()
    assert -powergrid.TAP_MAX <= int(nt) <= powergrid.TAP_MAX
    assert 0.0 <= float(rew) <= 1.0


# ---------------------------------------------------------------------------
# Supply chain
# ---------------------------------------------------------------------------
def test_supplychain_backpressure_blocks_shipping():
    cfg = supplychain.SupplyChainConfig(n_cells=3, buf=2)
    state = {"store": jnp.array([0, 0, 2], jnp.int32),   # cell 2 store full
             "buffer": jnp.array([1, 1, 1], jnp.int32),
             "t": jnp.zeros((), jnp.int32)}
    exo = {"breakdown": jnp.zeros((3,), bool),
           "arrival": jnp.zeros((), bool)}
    u = supplychain.gs_influence(state, exo, cfg)
    # cell 1 is backpressured by cell 2's full store; cell 0 is not
    np.testing.assert_array_equal(np.asarray(u[:, 1]), [0, 1, 0])
    # hand-offs: cell 1 receives from cell 0; cell 2 does NOT (blocked ship)
    np.testing.assert_array_equal(np.asarray(u[:, 0]), [0, 1, 0])
    actions = jnp.zeros((3,), jnp.int32)
    _, _, rew, _, _ = supplychain.gs_step_given(state, actions, exo, cfg)
    # shipping reward only for cells 0 (to cell 1) and 2 (to the sink)
    assert float(rew[0]) > 0 and float(rew[2]) > 0
    assert float(rew[1]) <= 0


def test_supplychain_part_conservation():
    """Total WIP changes only via head arrivals and tail shipments."""
    cfg = supplychain.SupplyChainConfig(n_cells=4)
    key = jax.random.PRNGKey(5)
    state = supplychain.gs_init(key, cfg)
    for t in range(20):
        key, ka, kx = jax.random.split(key, 3)
        actions = jax.random.randint(ka, (cfg.n_agents,), 0, 2)
        exo = supplychain.gs_exo(kx, cfg)
        u = supplychain.gs_influence(state, exo, cfg)
        before = int(state["store"].sum() + state["buffer"].sum())
        state2, _, _, _, _ = supplychain.gs_step_given(
            state, actions, exo, cfg)
        after = int(state2["store"].sum() + state2["buffer"].sum())
        head_in = int(u[0, 0])                       # arrival accepted
        tail_ship = int((state["buffer"][-1] > 0))   # sink never blocks
        assert after == before + head_in - tail_ship
        state = state2


@given(st.integers(0, 1), st.booleans(), st.booleans(),
       st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_supplychain_cell_step_invariants(action, bp, breakdown, seed):
    """Property: with u's GS semantics (hand-off only into non-full
    stores), both levels stay within [0, buf]."""
    cfg = supplychain.SupplyChainConfig(buf=3)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    store = jax.random.randint(k1, (), 0, cfg.buf + 1)
    buffer = jax.random.randint(k2, (), 0, cfg.buf + 1)
    handoff_in = store < cfg.buf       # GS invariant on the hand-off bit
    u = jnp.array([handoff_in, bp])
    ns, nb, rew, ship = supplychain.cell_step(
        store, buffer, jnp.asarray(action), u, jnp.asarray(breakdown), cfg)
    assert 0 <= int(ns) <= cfg.buf
    assert 0 <= int(nb) <= cfg.buf
    assert bool(ship) == (int(buffer) > 0 and not bp)
