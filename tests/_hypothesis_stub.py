"""Fallback for the optional ``hypothesis`` dev dependency.

The tier-1 suite must collect (and its example-based tests must run)
without the dev extras installed. Test modules import hypothesis as:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                # degrade gracefully: property
        from _hypothesis_stub import given, settings, st   # tests skip

With the real package absent, ``@given``-decorated property tests
collect as skips instead of erroring the whole module at import time;
everything else in the module runs normally.
"""
import pytest


class _AnyStrategy:
    """Accepts any strategy construction (st.integers(...), st.lists(...))."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _AnyStrategy()


def settings(*args, **kwargs):
    if args and callable(args[0]):          # bare @settings use
        return args[0]

    def deco(fn):
        return fn
    return deco


def given(*args, **kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed "
                                 "(pip install -r requirements-dev.txt)")
        def _skipped():
            pass
        _skipped.__name__ = getattr(fn, "__name__", "property_test")
        _skipped.__doc__ = fn.__doc__
        return _skipped
    return deco
