"""AIP learning, GS dataset collection, and the DIALS end-to-end loop.
Environments resolve through the registry, so the DIALS end-to-end smoke
test runs against every registered scenario."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dials, gs as gs_mod, ials as ials_mod, influence
from repro.envs import registry
from repro.marl import policy as policy_mod, ppo as ppo_mod
from repro.marl import runner as runner_mod


# ---------------------------------------------------------------------------
# AIP
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["fnn", "gru"])
def test_aip_learns_synthetic_rule(kind):
    """AIP must learn a learnable mapping: u = first M features > 0."""
    cfg = influence.AIPConfig(in_dim=8, n_sources=3, kind=kind,
                              hidden=(32,), gru_hidden=16,
                              lr=3e-3, epochs=40, batch=32)
    key = jax.random.PRNGKey(0)
    params = influence.aip_init(key, cfg)
    feats = jax.random.normal(key, (8, 64, cfg.in_dim))       # (E, T, F)
    u = (feats[..., :3] > 0).astype(jnp.float32)
    data = {"feats": feats, "u": u,
            "resets": jnp.zeros(feats.shape[:2], jnp.float32)}
    ce0 = influence.eval_ce(params, data, cfg)
    params, _ = influence.train_aip(params, data, jax.random.PRNGKey(1), cfg)
    ce1 = influence.eval_ce(params, data, cfg)
    assert float(ce1) < float(ce0) * 0.7, (float(ce0), float(ce1))


def test_epoch_minibatch_indices_cover_every_sequence():
    """Regression: the remainder used to be silently dropped
    (perm[:n_mb * batch]) — with n_seq % batch != 0 some collected
    sequences were never trained on in a given epoch."""
    for n_seq, batch in ((5, 2), (7, 3), (8, 4), (3, 16), (13, 4)):
        b = min(batch, n_seq)
        perm = jax.random.permutation(jax.random.PRNGKey(0), n_seq)
        idxs = influence.epoch_minibatch_indices(perm, b)
        assert idxs.shape == (-(-n_seq // b), b)
        assert set(np.asarray(idxs).ravel()) == set(range(n_seq))
    # divisible case: bit-identical to the old reshape (no behavior change)
    perm = jax.random.permutation(jax.random.PRNGKey(1), 8)
    np.testing.assert_array_equal(
        np.asarray(influence.epoch_minibatch_indices(perm, 4)),
        np.asarray(perm).reshape(2, 4))


def test_train_aip_trains_on_remainder_sequences():
    """n_seq=3, batch=2: the old path dropped one sequence per epoch; the
    wrapped permutation must train on all of them — the only sequence
    carrying signal is recovered even when it falls in the remainder."""
    cfg = influence.AIPConfig(in_dim=4, n_sources=1, kind="fnn",
                              hidden=(16,), lr=3e-3, epochs=30, batch=2)
    params = influence.aip_init(jax.random.PRNGKey(0), cfg)
    feats = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 4))
    u = (feats[..., :1] > 0).astype(jnp.float32)
    data = {"feats": feats, "u": u,
            "resets": jnp.zeros(feats.shape[:2], jnp.float32)}
    ce0 = influence.eval_ce(params, data, cfg)
    trained, loss = influence.train_aip(params, data,
                                        jax.random.PRNGKey(2), cfg)
    ce1 = influence.eval_ce(trained, data, cfg)
    assert jnp.isfinite(loss)
    assert float(ce1) < float(ce0) * 0.7, (float(ce0), float(ce1))


@pytest.mark.parametrize("kind", ["fnn", "gru"])
@pytest.mark.parametrize("n_seq,chunk", [(150, 64), (65, 64), (7, 3)])
def test_eval_ce_chunked_matches_full_batch(kind, n_seq, chunk):
    """eval_ce in fixed-size sequence chunks (the memory-spike fix: the
    all-at-once forward scales with collect size × T) agrees with the
    single-batch CE; vmapped over a stacked agent axis it still jits."""
    import dataclasses
    cfg = dataclasses.replace(
        influence.AIPConfig(in_dim=5, n_sources=2, kind=kind,
                            hidden=(8,), gru_hidden=8),
        eval_chunk=chunk)
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    params = influence.aip_init(ks[0], cfg)
    data = {"feats": jax.random.normal(ks[1], (n_seq, 6, 5)),
            "u": jax.random.bernoulli(
                ks[2], 0.4, (n_seq, 6, 2)).astype(jnp.float32),
            "resets": jax.random.bernoulli(
                ks[3], 0.1, (n_seq, 6)).astype(jnp.float32)}
    chunked = influence.eval_ce(params, data, cfg)
    full = influence.bce_loss(params, data["feats"], data["u"],
                              data["resets"], cfg)
    np.testing.assert_allclose(float(chunked), float(full), atol=1e-6)
    # the DIALS drivers run jit(vmap(eval_ce)) over stacked agents
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), data)
    both = jax.jit(jax.vmap(lambda p, d: influence.eval_ce(p, d, cfg)),
                   static_argnums=())(
        jax.tree.map(lambda x: jnp.stack([x, x]), params), stacked)
    np.testing.assert_allclose(np.asarray(both), float(full), atol=1e-6)


def test_aip_sample_sources_shape_and_range():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 3, 5))
    u = influence.sample_sources(key, logits)
    assert u.shape == logits.shape
    assert set(np.unique(np.asarray(u))) <= {0.0, 1.0}


def test_aip_stacked_vmap_training_independent():
    """Vmapped per-agent AIP training must equal training each agent
    alone (agents do not leak into one another)."""
    cfg = influence.AIPConfig(in_dim=6, n_sources=2, kind="fnn",
                              hidden=(16,), lr=1e-3, epochs=3, batch=16)
    k = jax.random.PRNGKey(2)
    n_agents = 3
    params = jax.vmap(lambda kk: influence.aip_init(kk, cfg))(
        jax.random.split(k, n_agents))
    feats = jax.random.normal(k, (n_agents, 4, 32, cfg.in_dim))
    u = (feats[..., :2] > 0).astype(jnp.float32)
    resets = jnp.zeros(feats.shape[:3], jnp.float32)
    data = {"feats": feats, "u": u, "resets": resets}
    keys = jax.random.split(jax.random.PRNGKey(3), n_agents)
    stacked, _ = jax.vmap(
        lambda p, d, kk: influence.train_aip(p, d, kk, cfg))(
        params, data, keys)
    for i in range(n_agents):
        pi = jax.tree.map(lambda x: x[i], params)
        di = jax.tree.map(lambda x: x[i], data)
        alone, _ = influence.train_aip(pi, di, keys[i], cfg)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            a, b, atol=1e-5), jax.tree.map(lambda x: x[i], stacked), alone)


# ---------------------------------------------------------------------------
# Algorithm 2: GS dataset collection
# ---------------------------------------------------------------------------
def test_collector_shapes_and_consistency():
    env_mod, cfg = registry.make("warehouse", horizon=16)
    info = cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, hidden=(16,))
    collect = gs_mod.make_collector(env_mod, cfg, pc, n_envs=3, steps=8)
    params = jax.vmap(lambda k: policy_mod.policy_init(k, pc))(
        jax.random.split(jax.random.PRNGKey(0), info.n_agents))
    data = collect(params, jax.random.PRNGKey(1))
    assert data["feats"].shape == (info.n_agents, 3, 8, info.alsh_dim)
    assert data["u"].shape == (info.n_agents, 3, 8, info.n_influence)
    assert data["resets"].shape == (info.n_agents, 3, 8)
    # first step of every env starts an episode
    assert bool(jnp.all(data["resets"][:, :, 0] == 1.0))
    for leaf in jax.tree.leaves(data):
        assert not jnp.any(jnp.isnan(leaf))


def test_split_dataset_holds_out_last_sequences():
    data = {"feats": jnp.arange(24.0).reshape(2, 4, 3),
            "u": jnp.arange(8).reshape(2, 4)}
    train, held = gs_mod.split_dataset(data, 1)
    np.testing.assert_array_equal(np.asarray(train["feats"]),
                                  np.asarray(data["feats"][:, :3]))
    np.testing.assert_array_equal(np.asarray(held["feats"]),
                                  np.asarray(data["feats"][:, 3:]))
    np.testing.assert_array_equal(np.asarray(held["u"]),
                                  np.asarray(data["u"][:, 3:]))
    # n_eval=0: both views are the full dataset (legacy train-set CE)
    train, held = gs_mod.split_dataset(data, 0)
    assert train is data and held is data
    with pytest.raises(ValueError, match="hold out"):
        gs_mod.split_dataset(data, 4)


# ---------------------------------------------------------------------------
# GS trainer + IALS trainer
# ---------------------------------------------------------------------------
def _tiny_setup(env_mod, env_cfg, kind="fnn"):
    info = env_cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, hidden=(16,),
                                 gru_hidden=8, kind=kind)
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="fnn",
                             hidden=(16,), epochs=2, batch=16)
    ppo_cfg = ppo_mod.PPOConfig(epochs=1, minibatches=2)
    return info, pc, ac, ppo_cfg


def test_gs_trainer_one_iteration():
    env_mod, cfg = registry.make("traffic", horizon=16)
    info, pc, _, ppo_cfg = _tiny_setup(env_mod, cfg)
    init_fn, train_fn, eval_fn = runner_mod.make_gs_trainer(
        env_mod, cfg, pc, ppo_cfg, runner_mod.RunConfig(
            n_envs=2, rollout_steps=8))
    state = init_fn(jax.random.PRNGKey(0))
    state2, metrics = train_fn(state)
    assert float(state2["iter"]) == 1
    for leaf in jax.tree.leaves(state2["params"]):
        assert not jnp.any(jnp.isnan(leaf))
    ret = eval_fn(state2["params"], jax.random.PRNGKey(1), episodes=2)
    assert jnp.isfinite(ret)


def test_ials_trainer_zero_cross_agent_interaction():
    """Agents in the IALS loop are isolated: zeroing agent j's params
    must not change agent i's trajectory metrics (given same keys)."""
    env_mod, cfg = registry.make("traffic", horizon=16)
    info, pc, ac, ppo_cfg = _tiny_setup(env_mod, cfg)
    init_fn, train_fn = ials_mod.make_ials_trainer(
        env_mod, cfg, pc, ac, ppo_cfg, n_envs=2, rollout_steps=8)
    state = init_fn(jax.random.PRNGKey(0))
    aips = jax.vmap(lambda k: influence.aip_init(k, ac))(
        jax.random.split(jax.random.PRNGKey(1), info.n_agents))
    s1, _ = train_fn(state, aips)

    # zero agent 3's policy params; agents 0-2 must evolve identically
    def zero_last(x):
        return x.at[-1].set(0.0) if x.ndim else x
    state_z = {**state, "params": jax.tree.map(zero_last, state["params"])}
    s2, _ = train_fn(state_z, aips)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a[:-1], b[:-1], atol=1e-5),
        s1["params"], s2["params"])


# ---------------------------------------------------------------------------
# DIALS end-to-end (Algorithm 1)
# ---------------------------------------------------------------------------
def _dials_trainer(tmp_path=None, env_name="warehouse", outer_rounds=2, **kw):
    env_mod, cfg = registry.make(env_name, horizon=16)
    info, pc, ac, ppo_cfg = _tiny_setup(env_mod, cfg)
    kw.setdefault("collect_envs", 2)
    dcfg = dials.DIALSConfig(
        outer_rounds=outer_rounds, aip_refresh=2,
        collect_steps=16, n_envs=2, rollout_steps=8, eval_episodes=2,
        ckpt_dir=str(tmp_path) if tmp_path else None, **kw)
    return dials.DIALSTrainer(env_mod, cfg, pc, ac, ppo_cfg, dcfg)


@pytest.mark.parametrize("env_name", registry.names())
def test_dials_end_to_end_runs(env_name):
    trainer = _dials_trainer(env_name=env_name)
    state, hist = trainer.run(jax.random.PRNGKey(0))
    assert len(hist) == 2
    for rec in hist:
        assert np.isfinite(rec["gs_return"])
        assert np.isfinite(rec["aip_ce_after"])
    # AIP training does not blow up the HELD-OUT CE (the record's CE is
    # now computed on collect_holdout sequences the AIP never trained
    # on; at this test's scale — 2 epochs on one sequence — generalized
    # descent is not guaranteed, only a small bounded move)
    assert hist[0]["aip_ce_after"] <= hist[0]["aip_ce_before"] + 5e-3


def test_dials_reports_true_held_out_ce():
    """The round record's CE is the paper's held-out Fig.-4 metric: it is
    computed on the collect_holdout env streams the AIP did NOT train on.
    Reconstruct round 0's dataset from the same key stream and check the
    reported ce_before against eval_ce on the held-out split (and that it
    differs from the train-split CE)."""
    trainer = _dials_trainer(outer_rounds=1, collect_envs=3)
    assert trainer.n_eval_seqs == 1
    key = jax.random.PRNGKey(0)
    state0 = trainer.init(key)
    _, hist = trainer.run(key)

    kc = jax.random.split(jax.random.fold_in(key, 0), 3)[0]
    data = trainer.collect(state0["ials"]["params"], kc)
    train_d, eval_d = gs_mod.split_dataset(data, trainer.n_eval_seqs)
    ce_held = float(trainer.eval_aips(state0["aips"], eval_d).mean())
    ce_train = float(trainer.eval_aips(state0["aips"], train_d).mean())
    assert hist[0]["aip_ce_before"] == pytest.approx(ce_held, abs=1e-6)
    assert hist[0]["aip_ce_before"] != pytest.approx(ce_train, abs=1e-9)


def test_dials_untrained_ablation_skips_aip_training():
    trainer = _dials_trainer(untrained=True)
    state, hist = trainer.run(jax.random.PRNGKey(0))
    for rec in hist:
        assert rec["aip_ce_before"] == pytest.approx(rec["aip_ce_after"])


def test_dials_checkpoint_restart_resumes(tmp_path):
    trainer = _dials_trainer(tmp_path)
    state, hist = trainer.run(jax.random.PRNGKey(0))
    # a fresh trainer restores round 2 and does no further work
    trainer2 = _dials_trainer(tmp_path)
    state2, hist2 = trainer2.run(jax.random.PRNGKey(0))
    assert hist2 == []                     # already complete
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=0),
                 state["aips"], state2["aips"])


def test_dials_resume_is_deterministic(tmp_path):
    """2 rounds + restart + 2 more == 4 straight rounds: the restored
    base key must continue the per-round fold-in stream exactly, and the
    restored per-agent iter counters must continue the inner streams."""
    s4, h4 = _dials_trainer(tmp_path / "straight", outer_rounds=4).run(
        jax.random.PRNGKey(0))
    part_dir = tmp_path / "parts"
    _dials_trainer(part_dir, outer_rounds=2).run(jax.random.PRNGKey(0))
    s_res, h_res = _dials_trainer(part_dir, outer_rounds=4).run(
        jax.random.PRNGKey(0))
    assert [h["round"] for h in h_res] == [2, 3]
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=0),
        {"p": s4["ials"]["params"], "a": s4["aips"],
         "it": s4["ials"]["iter"]},
        {"p": s_res["ials"]["params"], "a": s_res["aips"],
         "it": s_res["ials"]["iter"]})
    for r4, rr in zip(h4[2:], h_res):
        assert r4["gs_return"] == pytest.approx(rr["gs_return"], abs=0)


def test_dials_straggler_mask_keeps_old_aips():
    trainer = _dials_trainer()
    # every agent is a straggler: AIPs must never change
    state0 = trainer.init(jax.random.PRNGKey(0))
    state, hist = trainer.run(
        jax.random.PRNGKey(0),
        straggler_mask=lambda rnd: np.zeros(4, np.float32))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=0),
                 state0["aips"], state["aips"])
