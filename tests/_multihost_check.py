"""Multi-host contract check for the sharded DIALS runtime.

Run by ``tests/test_multihost.py`` as coordinated ``jax.distributed``
subprocesses (2 processes × 4 forced host devices = 8 global devices).
Three modes, selected by ``--mode``:

* ``reference`` — single process, 4 forced devices, 4-shard powergrid
  run (the sharded numbers PR 2/5 pinned to the single-device path).
  Writes params/AIPs/history to ``--out``.
* ``sharded``   — the same run on a 4-shard mesh spanning BOTH
  processes (2 devices each): the region-decomposed GS's halo
  exchange and the replicated fallback's gathers both cross the
  process boundary for real. Process 0 writes the same dump; the test
  asserts it matches ``reference`` to the PR-2 tolerances.
* ``hostdrop``  — elastic reassignment end-to-end: a 4-round traffic
  run on the cross-process mesh in which process 1 SIGKILLs itself at
  the top of round 2. Process 0's ``fault.HostMonitor`` times out,
  the driver reassigns the dead host's agent blocks onto a shrunken
  2-shard local mesh, training completes, and the round records carry
  the reassignment. Process 0 writes the history and exits via
  ``os._exit(0)`` (the normal interpreter exit would hang in the
  distributed-shutdown barrier against a dead peer).

With ``--telemetry-dir`` every process additionally writes its typed
event log (``repro.obs``) there — the trainer's round records plus, in
hostdrop mode, the HostMonitor's ``host_death`` and the planner's
``elastic_reassign`` events. The primary merges the per-process files
into ``telemetry.jsonl`` and asserts cross-process coverage (hostdrop:
the dead peer's truncated log must still merge, and the incident events
must be present).

Prints MULTIHOST-OK on success (process 0).
"""
import argparse
import json
import os
import signal
import sys

# bootstrap BEFORE any jax device use (repro imports are fine — they
# don't touch the backend at import time)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.distributed import bootstrap  # noqa: E402

ctx = bootstrap.bootstrap()

import jax  # noqa: E402
import numpy as np  # noqa: E402

from _multidevice_check import build_trainer  # noqa: E402
from repro import obs  # noqa: E402
from repro.distributed import fault, runtime  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import sinks as obs_sinks  # noqa: E402


def dump(path, state, history):
    """JSON dump of the run's observables: every param leaf (flattened,
    deterministic order) plus the round records."""
    leaves = {
        "aips": [np.asarray(x).tolist()
                 for x in jax.tree.leaves(state["aips"])],
        "params": [np.asarray(x).tolist()
                   for x in jax.tree.leaves(state["ials"]["params"])],
    }
    with open(path, "w") as f:
        json.dump({"history": history, **leaves}, f)


def check_merged_telemetry(telemetry_dir, *, procs, require=()):
    """Primary-only: merge the per-process JSONL logs and assert the
    merged stream covers every process's round records, validates
    against the round schema, and contains the ``require``d events."""
    merged = obs_sinks.merge_dir(telemetry_dir)
    events = obs_sinks.read_jsonl(merged)
    assert events, f"empty merged telemetry at {merged}"
    rounds = [e for e in events if e.get("event") == "round"]
    for e in rounds:
        problems = obs_metrics.validate_round(e)
        assert not problems, (problems, e)
    got_procs = {e["proc"] for e in rounds}
    assert got_procs == set(procs), \
        f"round records cover procs {got_procs}, want {set(procs)}"
    kinds = {e.get("event") for e in events}
    for kind in require:
        assert kind in kinds, f"missing {kind!r} event in {sorted(kinds)}"
    # global order: the merge key is (t, proc, seq)
    keys = [(e.get("t", 0.0), e.get("proc", 0), e.get("seq", 0))
            for e in events]
    assert keys == sorted(keys), "merged stream out of order"


def run_reference(out, telemetry_dir):
    assert ctx.num_processes == 1 and len(jax.devices()) == 4, \
        (ctx, jax.devices())
    trainer = build_trainer(env="powergrid", shards=4,
                            telemetry_dir=telemetry_dir)
    state, history = trainer.run(jax.random.PRNGKey(0))
    assert trainer._sharded.use_sharded_gs
    if telemetry_dir:
        check_merged_telemetry(telemetry_dir, procs=(0,))
    dump(out, state, history)
    print("MULTIHOST-OK")


def run_sharded(out, telemetry_dir):
    assert ctx.num_processes == 2, ctx
    assert len(jax.devices()) == 8 and len(jax.local_devices()) == 4, \
        jax.devices()
    trainer = build_trainer(env="powergrid", shards=4,
                            telemetry_dir=telemetry_dir)
    # the 4-shard mesh must take 2 devices from EACH process
    state, history = trainer.run(jax.random.PRNGKey(0))
    mesh = trainer._sharded.mesh
    assert runtime.mesh_hosts(mesh) == (0, 1), mesh
    assert runtime.mesh_spans_processes(mesh)
    assert trainer._sharded.use_sharded_gs     # halo exchange crosses hosts
    if ctx.is_primary:
        if telemetry_dir:
            check_merged_telemetry(telemetry_dir, procs=(0, 1))
        dump(out, state, history)
        print("MULTIHOST-OK")


def run_hostdrop(out, beat_dir, telemetry_dir):
    assert ctx.num_processes == 2, ctx
    # the monitor shares the trainer's telemetry directory: its
    # host_death events land in the same per-process JSONL stream the
    # round records do (the sink appends, so two emitters coexist)
    tel = obs.maybe(telemetry_dir)
    monitor = fault.HostMonitor(beat_dir, host=ctx.process_id, n_hosts=2,
                                timeout_s=10.0,
                                telemetry=tel if tel.enabled else None)

    def heartbeats(rnd):
        if ctx.process_id == 1 and rnd >= 2:
            # round 1's program and mirror all-gather have completed
            # globally (this process's round-1 sync blocked on them), so
            # the survivor's state is whole — die without a trace
            os.kill(os.getpid(), signal.SIGKILL)
        return monitor.gate(rnd)

    trainer = build_trainer(env="traffic", shards=4, outer_rounds=4,
                            telemetry_dir=telemetry_dir)
    state, history = trainer.run(jax.random.PRNGKey(0),
                                 heartbeats=heartbeats)
    # only the survivor reaches this point
    assert ctx.process_id == 0
    assert [r["n_shards"] for r in history] == [4, 4, 2, 2], history
    assert history[2]["dead_hosts"] == [1] and \
        history[2]["reassigned"] == 2, history[2]
    assert all(np.isfinite(r["gs_return"]) for r in history), history
    if telemetry_dir:
        # the whole incident must be reconstructable from the merged
        # event log: the dead peer's (possibly truncated) file still
        # merges, and death + replan events are present
        check_merged_telemetry(telemetry_dir, procs=(0, 1),
                               require=("host_death", "elastic_reassign"))
        events = obs_sinks.read_jsonl(
            os.path.join(telemetry_dir, obs_sinks.MERGED_NAME))
        death = [e for e in events if e.get("event") == "host_death"]
        assert death and death[0]["dead_hosts"] == [1], death
        replan = [e for e in events
                  if e.get("event") == "elastic_reassign"]
        assert replan and replan[0]["old_shards"] == 4 and \
            replan[0]["new_shards"] == 2, replan
        tel.close()
    dump(out, state, history)
    print("MULTIHOST-OK", flush=True)
    # skip the distributed-shutdown barrier: the peer is dead
    os._exit(0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", required=True,
                    choices=["reference", "sharded", "hostdrop"])
    ap.add_argument("--out", required=True)
    ap.add_argument("--beat-dir", default=None)
    ap.add_argument("--telemetry-dir", default=None)
    args = ap.parse_args()
    if args.mode == "reference":
        run_reference(args.out, args.telemetry_dir)
    elif args.mode == "sharded":
        run_sharded(args.out, args.telemetry_dir)
    else:
        run_hostdrop(args.out, args.beat_dir, args.telemetry_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
