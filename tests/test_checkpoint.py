"""Checkpoint substrate: integrity manifest, corruption detection,
rotation, latest-valid restore (the fault-tolerance contract)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"layer": {"w": jax.random.normal(k1, (4, 8)),
                      "b": jnp.zeros((8,), jnp.bfloat16)},
            "step": jnp.ones((), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "c1")
    ckpt.save(d, tree, step=7)
    assert ckpt.is_valid(d)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, step = ckpt.restore(d, sds)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, back)
    assert back["layer"]["b"].dtype == jnp.bfloat16


def test_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    d = str(tmp_path / "c2")
    ckpt.save(d, tree, step=1)
    # flip bytes in one leaf file
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(-4, os.SEEK_END)
        f.write(b"\xde\xad\xbe\xef")
    assert not ckpt.is_valid(d)


def test_missing_manifest_invalid(tmp_path):
    assert not ckpt.is_valid(str(tmp_path / "nope"))


def test_manager_rotation_and_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = _tree(jax.random.PRNGKey(2))
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    mgr.wait()
    assert mgr.steps() == [2, 3]          # keep=2 rotated out step 1
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, step = mgr.restore_latest(sds)
    assert step == 3
    np.testing.assert_allclose(np.asarray(back["layer"]["w"]),
                               np.asarray(tree["layer"]["w"]) + 3)


def test_manager_skips_corrupt_latest(tmp_path):
    """Node dies mid-write: the manager must fall back to the last VALID
    checkpoint instead of crashing."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    tree = _tree(jax.random.PRNGKey(3))
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree))
    mgr.wait()
    # corrupt step 2
    d2 = os.path.join(str(tmp_path), "step_2")
    victim = [f for f in os.listdir(d2) if f.endswith(".npy")][0]
    with open(os.path.join(d2, victim), "r+b") as f:
        f.seek(-4, os.SEEK_END)
        f.write(b"\x00\x00\x00\x00")
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, step = mgr.restore_latest(sds)
    assert step == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, back)


def test_restore_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    back, step = mgr.restore_latest({"x": jax.ShapeDtypeStruct((1,),
                                                               jnp.float32)})
    assert back is None


# ---------------------------------------------------------------------------
# async write-failure capture (a failed checkpoint must never be silent)
# ---------------------------------------------------------------------------
def test_async_write_failure_reraised_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)

    def exploding_hook(step, phase, directory):
        if phase == "leaves_written":
            raise OSError("disk full (injected)")

    mgr.hooks = exploding_hook
    mgr.save(1, _tree(jax.random.PRNGKey(0)))
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the error is consumed: the manager is usable again
    mgr.hooks = None
    mgr.save(2, _tree(jax.random.PRNGKey(0)))
    mgr.wait()
    assert mgr.steps() == [2]


def test_async_write_failure_reraised_on_next_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    boom = {"on": True}

    def hook(step, phase, directory):
        if boom["on"] and phase == "write_begin":
            raise RuntimeError("writer died (injected)")

    mgr.hooks = hook
    mgr.save(1, _tree(jax.random.PRNGKey(0)))
    while mgr._thread is not None and mgr._thread.is_alive():
        mgr._thread.join(0.01)
    boom["on"] = False
    with pytest.raises(RuntimeError, match="writer died"):
        mgr.save(2, _tree(jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# distributed per-slice layout (repro.checkpoint.distributed)
# ---------------------------------------------------------------------------
from repro.checkpoint import distributed as dckpt  # noqa: E402


def _blocks(n=4, dim=3, seed=0):
    """A full agent-stacked host tree plus its two half-slices."""
    rng = np.random.RandomState(seed)
    full = {"w.npy": rng.randn(n, dim).astype(np.float32),
            "b.npy": rng.randn(n).astype(np.float32)}
    lo_tree = {"w": full["w.npy"][:n // 2], "b": full["b.npy"][:n // 2]}
    hi_tree = {"w": full["w.npy"][n // 2:], "b": full["b.npy"][n // 2:]}
    return full, lo_tree, hi_tree


def _prepare_step(d, *, step=3, n=4, extra=None, seed=0):
    """Manufacture a fully prepared (uncommitted) 2-slice step dir."""
    full, lo_tree, hi_tree = _blocks(n=n, seed=seed)
    dckpt.write_slice(d, lo_tree, 0, n // 2, n, step=step, tag="a")
    dckpt.write_slice(d, hi_tree, n // 2, n, n, step=step, tag="b")
    dckpt.write_replicated(d, {"round": step, "key": np.arange(2,
                           dtype=np.uint32)}, step=step, extra=extra)
    return full


def test_distributed_two_slice_roundtrip(tmp_path):
    d = str(tmp_path / "step_3")
    full = _prepare_step(d, step=3, extra={"tag": "x"})
    meta = dckpt.build_commit_meta(d)
    assert meta is not None
    assert meta["n_agents"] == 4 and meta["slices"] == [[0, 2], [2, 4]]
    assert meta["extra"] == {"tag": "x"}
    dckpt.write_commit(d, meta)
    assert dckpt.committed_meta(d) is not None

    target = {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32),
              "b": jax.ShapeDtypeStruct((4,), jnp.float32),
              "round": 0,
              "key": jax.ShapeDtypeStruct((2,), jnp.uint32)}
    tree, step = dckpt.read_step_host(d, target)
    assert step == 3 and tree["round"] == 3
    np.testing.assert_array_equal(np.asarray(tree["w"]), full["w.npy"])
    np.testing.assert_array_equal(np.asarray(tree["b"]), full["b.npy"])

    # cross-shard-count assembly: row ranges that straddle the saved
    # slice boundary (what a 4-shard restore of a 2-slice save does)
    reader = dckpt.SliceReader(d, meta)
    np.testing.assert_array_equal(reader.rows("w.npy", 1, 3),
                                  full["w.npy"][1:3])
    np.testing.assert_array_equal(reader.rows("b.npy", 3, 4),
                                  full["b.npy"][3:4])


def test_build_commit_meta_rejects_incomplete_prepare(tmp_path):
    d = str(tmp_path / "step_1")
    full, lo_tree, _ = _blocks()
    # only the low slice present: the tiling [0,4) has a gap
    dckpt.write_slice(d, lo_tree, 0, 2, 4, step=1)
    dckpt.write_replicated(d, {"round": 1}, step=1)
    assert dckpt.build_commit_meta(d) is None
    # wrong expected agent count
    _prepare_step(d, step=1)
    assert dckpt.build_commit_meta(d, expect_n=8) is None
    assert dckpt.build_commit_meta(d, expect_n=4) is not None


def test_committed_meta_rejects_corrupted_step(tmp_path):
    d = str(tmp_path / "step_2")
    _prepare_step(d, step=2)
    dckpt.write_commit(d, dckpt.build_commit_meta(d))
    assert dckpt.committed_meta(d) is not None
    victim = os.path.join(d, "agents-00000-00002", "w.npy")
    with open(victim, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        f.write(b"\xff")
    # a corrupted committed step reads as uncommitted
    assert dckpt.committed_meta(d) is None


def _dist_mgr(path, **kw):
    kw.setdefault("async_write", False)
    return dckpt.DistributedCheckpointManager(str(path), **kw)


def _state(seed=0, n=4):
    k = jax.random.PRNGKey(seed)
    return {"ials": {"params": jax.random.normal(k, (n, 3))},
            "round": 1, "key": jnp.zeros((2,), jnp.uint32)}


def _struct(tree):
    return jax.tree.map(
        lambda x: (jax.ShapeDtypeStruct(x.shape, x.dtype)
                   if hasattr(x, "shape") else x), tree)


def test_distributed_manager_single_process_roundtrip(tmp_path):
    mgr = _dist_mgr(tmp_path, keep=5)
    st = _state()
    mgr.save(1, st, extra={"async_round": None, "reports": [0, 0, 0, 0]})
    mgr.save(2, jax.tree.map(
        lambda x: x + 1 if hasattr(x, "dtype") else x, st),
        extra={"async_round": 1, "reports": [1, 1, 1, 1]})
    assert mgr.latest_committed() == 2
    tree, step = mgr.restore_latest(_struct(st))
    assert step == 2
    assert mgr.last_extra == {"async_round": 1, "reports": [1, 1, 1, 1]}
    np.testing.assert_array_equal(
        np.asarray(tree["ials"]["params"]),
        np.asarray(st["ials"]["params"]) + 1)
    # restore_step reaches the older step
    tree1, step1 = mgr.restore_step(1, _struct(st))
    assert step1 == 1 and mgr.last_extra["async_round"] is None
    np.testing.assert_array_equal(np.asarray(tree1["ials"]["params"]),
                                  np.asarray(st["ials"]["params"]))


def test_flat_manager_restores_distributed_layout(tmp_path):
    """Cross-path dispatch: a checkpoint written by the sharded driver's
    distributed manager restores through the plain CheckpointManager
    (the loop driver / restore_or_init path)."""
    st = _state(seed=3)
    _dist_mgr(tmp_path).save(4, st, extra={"reports": [3, 3, 3, 3]})
    flat = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    tree, step = flat.restore_latest(_struct(st))
    assert step == 4
    assert flat.last_extra["reports"] == [3, 3, 3, 3]
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)),
        {k: v for k, v in st.items() if k != "round"},
        {k: v for k, v in tree.items() if k != "round"})


def test_restore_latest_skips_uncommitted_and_gcs(tmp_path):
    mgr = _dist_mgr(tmp_path, keep=5)
    st = _state()
    mgr.save(1, st)
    mgr.save(2, st)
    # step 3: fully prepared but never committed (writer died pre-commit)
    d3 = os.path.join(str(tmp_path), "step_3")
    _prepare_step(d3, step=3)
    # step 4: committed but then corrupted
    mgr.save(4, st)
    from repro.distributed import chaos
    assert chaos.corrupt_checkpoint(os.path.join(str(tmp_path), "step_4"),
                                    "bytes")
    tree, step = mgr.restore_latest(_struct(st))
    assert step == 2
    # the unusable newer steps were garbage-collected (rank 0 only)
    assert mgr.steps() == [1, 2]


def test_finalize_pending_commit_takeover(tmp_path):
    mgr = _dist_mgr(tmp_path, keep=5)
    mgr.save(1, _state())
    d2 = os.path.join(str(tmp_path), "step_2")
    full = _prepare_step(d2, step=2, extra={"async_round": 0})
    # a survivor (not necessarily rank 0) completes the commit
    survivor = _dist_mgr(tmp_path, keep=5, process_id=1)
    assert survivor.finalize_pending() == 2
    meta = dckpt.committed_meta(d2)
    assert meta is not None and meta["extra"] == {"async_round": 0}
    target = {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32),
              "b": jax.ShapeDtypeStruct((4,), jnp.float32),
              "round": 0,
              "key": jax.ShapeDtypeStruct((2,), jnp.uint32)}
    tree, step = survivor.restore_latest(target)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]), full["w.npy"])
    # newest step already committed -> nothing pending
    assert survivor.finalize_pending() is None


def test_finalize_pending_nothing_prepared(tmp_path):
    mgr = _dist_mgr(tmp_path)
    assert mgr.finalize_pending() is None
    mgr.save(1, _state())
    assert mgr.finalize_pending() is None   # newest is committed
