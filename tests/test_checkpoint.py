"""Checkpoint substrate: integrity manifest, corruption detection,
rotation, latest-valid restore (the fault-tolerance contract)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"layer": {"w": jax.random.normal(k1, (4, 8)),
                      "b": jnp.zeros((8,), jnp.bfloat16)},
            "step": jnp.ones((), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "c1")
    ckpt.save(d, tree, step=7)
    assert ckpt.is_valid(d)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, step = ckpt.restore(d, sds)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, back)
    assert back["layer"]["b"].dtype == jnp.bfloat16


def test_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    d = str(tmp_path / "c2")
    ckpt.save(d, tree, step=1)
    # flip bytes in one leaf file
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(-4, os.SEEK_END)
        f.write(b"\xde\xad\xbe\xef")
    assert not ckpt.is_valid(d)


def test_missing_manifest_invalid(tmp_path):
    assert not ckpt.is_valid(str(tmp_path / "nope"))


def test_manager_rotation_and_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = _tree(jax.random.PRNGKey(2))
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    mgr.wait()
    assert mgr.steps() == [2, 3]          # keep=2 rotated out step 1
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, step = mgr.restore_latest(sds)
    assert step == 3
    np.testing.assert_allclose(np.asarray(back["layer"]["w"]),
                               np.asarray(tree["layer"]["w"]) + 3)


def test_manager_skips_corrupt_latest(tmp_path):
    """Node dies mid-write: the manager must fall back to the last VALID
    checkpoint instead of crashing."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    tree = _tree(jax.random.PRNGKey(3))
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree))
    mgr.wait()
    # corrupt step 2
    d2 = os.path.join(str(tmp_path), "step_2")
    victim = [f for f in os.listdir(d2) if f.endswith(".npy")][0]
    with open(os.path.join(d2, victim), "r+b") as f:
        f.seek(-4, os.SEEK_END)
        f.write(b"\x00\x00\x00\x00")
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, step = mgr.restore_latest(sds)
    assert step == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, back)


def test_restore_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    back, step = mgr.restore_latest({"x": jax.ShapeDtypeStruct((1,),
                                                               jnp.float32)})
    assert back is None
