"""The deterministic fault-injection harness
(``repro.distributed.chaos``): schedule construction (spec strings,
seeded draws), the three hook surfaces, once-only/host/generation
filtering, the pre-act ``chaos_inject`` telemetry contract — and the
satellite torn-write test: a REAL SIGKILL mid-checkpoint-write (via a
subprocess), after which ``restore_latest`` must return the last
committed step and garbage-collect the wreckage."""
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint import distributed as dckpt
from repro.checkpoint.manager import CheckpointManager
from repro.distributed import chaos, fault

CHECK = os.path.join(os.path.dirname(__file__), "_chaos_check.py")


class _Rec:
    """Telemetry fake recording emits in order."""

    def __init__(self):
        self.events = []

    def emit(self, event, **fields):
        self.events.append({"event": event, **fields})


# ---------------------------------------------------------------------------
# schedule construction
# ---------------------------------------------------------------------------
def test_from_spec_parses_kinds_and_options():
    s = chaos.FaultSchedule.from_spec(
        "kill@2:host=1,crash@3:phase=pre_commit:mode=raise,"
        "corrupt@4:target=commit,delay@1:delay_s=0.5,"
        "interrupt@2:generation=1")
    kinds = [e.kind for e in s.events]
    assert kinds == ["host_kill", "writer_crash", "corrupt",
                     "heartbeat_delay", "interrupt"]
    assert s.events[0].host == 1 and s.events[0].round == 2
    assert s.events[1].phase == "pre_commit" and s.events[1].mode == "raise"
    assert s.events[2].target == "commit"
    assert s.events[3].delay_s == 0.5
    assert s.events[4].generation == 1


def test_from_spec_rejects_unknowns():
    with pytest.raises(ValueError, match="unknown fault kind"):
        chaos.FaultSchedule.from_spec("meteor@1")
    with pytest.raises(ValueError, match="unknown fault option"):
        chaos.FaultSchedule.from_spec("kill@1:sev=9")


def test_seeded_schedule_is_deterministic():
    a = chaos.FaultSchedule.seeded(7, rounds=6, hosts=2, n_faults=4)
    b = chaos.FaultSchedule.seeded(7, rounds=6, hosts=2, n_faults=4)
    assert a.events == b.events
    assert len(a.events) == 4
    for ev in a.events:
        assert ev.kind in ("host_kill", "heartbeat_delay", "writer_crash")
        assert 1 <= ev.round < 6 and ev.host in (0, 1)
    c = chaos.FaultSchedule.seeded(8, rounds=6, hosts=2, n_faults=4)
    assert c.events != a.events


# ---------------------------------------------------------------------------
# hook surfaces + filtering
# ---------------------------------------------------------------------------
def test_round_start_interrupt_fires_once_with_telemetry():
    rec = _Rec()
    s = chaos.FaultSchedule.from_spec("interrupt@2", telemetry=rec)
    s.round_start(0)
    s.round_start(1)
    assert not s.fired
    with pytest.raises(chaos.ChaosInterrupt):
        s.round_start(2)
    # telemetry was emitted BEFORE the fault acted, and exactly once
    assert [e["event"] for e in rec.events] == ["chaos_inject"]
    assert rec.events[0]["kind"] == "interrupt"
    assert len(s.fired) == 1
    s.round_start(2)                      # once-only: does not re-fire
    assert len(s.fired) == 1


def test_host_kill_uses_injected_kill():
    killed = []
    s = chaos.FaultSchedule.from_spec(
        "kill@1:host=3", host=3,
        kill=lambda pid, sig: killed.append((pid, sig)))
    s.round_start(1)
    assert killed == [(os.getpid(), signal.SIGKILL)]


def test_host_and_generation_filtering():
    s0 = chaos.FaultSchedule.from_spec("kill@1:host=1", host=0,
                                       kill=lambda *a: (_ for _ in ()
                                                        ).throw(AssertionError))
    s0.round_start(1)                     # wrong host: no fire
    assert not s0.fired
    s1 = chaos.FaultSchedule.from_spec(
        "interrupt@1", generation=1)      # event is generation 0
    s1.round_start(1)
    assert not s1.fired                   # survivor gen-1 must not re-fire


def test_heartbeat_delay_through_host_monitor(tmp_path):
    slept = []
    s = chaos.FaultSchedule.from_spec("delay@2:delay_s=0.3",
                                      sleep=slept.append)
    mon = fault.HostMonitor(str(tmp_path), host=0, n_hosts=1, chaos=s)
    mon.beat(1)
    assert slept == []
    mon.beat(2)
    assert slept == [0.3] and len(s.fired) == 1
    assert os.path.exists(os.path.join(str(tmp_path), "beat-0-2"))


def test_commit_delay_sleeps_at_matching_phase_only():
    slept = []
    s = chaos.FaultSchedule.from_spec(
        "commit_delay@5:phase=pre_commit:delay_s=2.0", sleep=slept.append)
    s.checkpoint_phase(5, "prepared", "/nowhere")
    assert slept == []
    s.checkpoint_phase(5, "pre_commit", "/nowhere")
    assert slept == [2.0]


def test_writer_crash_raise_mode_surfaces_via_manager(tmp_path):
    """The in-process half of the torn-write story: a writer_crash in
    mode=raise on the async writer thread is captured and re-raised by
    the next wait() — checkpointing never fails silently."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    s = chaos.FaultSchedule.from_spec(
        "crash@2:phase=leaves_written:mode=raise")
    mgr.hooks = s.checkpoint_phase
    tree = {"w": np.zeros((2, 2), np.float32)}
    mgr.save(1, tree)
    mgr.wait()                            # step 1: no fault scheduled
    mgr.save(2, tree)
    with pytest.raises(chaos.ChaosError):
        mgr.wait()
    mgr.hooks = None
    mgr.save(3, tree)
    mgr.wait()
    assert set(mgr.steps()) == {1, 3}


def test_corrupt_checkpoint_targets(tmp_path):
    d = str(tmp_path / "step_1")
    ckpt.save(d, {"w": np.ones((3,), np.float32)}, step=1)
    assert ckpt.is_valid(d)
    assert chaos.corrupt_checkpoint(d, "bytes").endswith(".npy")
    assert not ckpt.is_valid(d)
    # commit target writes a torn marker
    d2 = str(tmp_path / "step_2")
    os.makedirs(d2)
    with open(os.path.join(d2, "COMMIT"), "w") as f:
        f.write("{}")
    assert chaos.corrupt_checkpoint(d2, "commit").endswith("COMMIT")
    assert dckpt.committed_meta(d2) is None
    assert chaos.corrupt_checkpoint(str(tmp_path / "empty"), "bytes") is None


# ---------------------------------------------------------------------------
# the torn-write subprocess test (satellite: SIGKILL mid-write)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("phase,finalizable", [
    ("leaves_written", False),   # torn slice: only tmp wreckage
    ("prepared", False),         # slice renamed, replicated missing
    ("pre_commit", True),        # fully prepared, COMMIT never written
])
def test_sigkill_mid_write_restores_last_committed(tmp_path, phase,
                                                   finalizable):
    ck = str(tmp_path / "ck")
    proc = subprocess.run(
        [sys.executable, CHECK, ck, phase],
        cwd="/root/repo", capture_output=True, text=True, timeout=300,
        env={**os.environ, "JAX_PLATFORMS": "cpu", "PYTHONPATH": "src"})
    assert proc.returncode == -signal.SIGKILL, \
        (proc.returncode, proc.stdout, proc.stderr)
    assert "STEP1-COMMITTED" in proc.stdout
    assert "SURVIVED" not in proc.stdout

    mgr = dckpt.DistributedCheckpointManager(ck, keep=5, async_write=False)
    if finalizable:
        # died between prepare and commit: a survivor can take over
        assert mgr.finalize_pending() == 2
        expect_step, expect_off = 2, 1.0
    else:
        assert mgr.finalize_pending() is None
        expect_step, expect_off = 1, 0.0
    target = {"w": np.zeros((4, 3), np.float32),
              "key": np.zeros((2,), np.uint32), "round": 0}
    tree, step = mgr.restore_latest(target)
    assert step == expect_step
    assert tree["round"] == expect_step
    np.testing.assert_array_equal(
        np.asarray(tree["w"]),
        np.arange(12, dtype=np.float32).reshape(4, 3) + expect_off)
    assert mgr.last_extra == {"async_round": None if expect_step == 1 else 1,
                              "reports": [expect_step - 1] * 4}
    # the wreckage of the torn step was garbage-collected on restore
    assert mgr.steps() == ([1, 2] if finalizable else [1])
