"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.gae import ops as gae_ops
from repro.kernels.gae import ref as gae_ref
from repro.kernels.gru import ops as gru_ops
from repro.kernels.gru import ref as gru_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref
from repro.nn import gru as gru_mod


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,hkv,d", [
    (1, 128, 4, 4, 64),          # MHA
    (2, 256, 8, 2, 64),          # GQA 4:1
    (1, 128, 4, 1, 128),         # MQA, wide head
    (2, 384, 6, 6, 64),          # T not a block multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, t, h, hkv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, d), dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True, interpret=True)
    ref = fa_ref.attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, t, h, d = 1, 256, 4, 64
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True,
                                 sliding_window=window, interpret=True)
    ref = fa_ref.attention(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, t, h, d = 1, 128, 2, 64
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32) * 3
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32) * 3
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True, softcap=50.0,
                                 interpret=True)
    ref = fa_ref.attention(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)
    # softcap must actually change the answer
    ref_nocap = fa_ref.attention(q, k, v, causal=True)
    assert not np.allclose(ref, ref_nocap, atol=1e-3)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, t, h, d = 2, 128, 4, 64
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=False, interpret=True)
    ref = fa_ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# GRU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,din,h", [
    (2, 16, 8, 16), (4, 33, 12, 32), (1, 64, 32, 64),
])
def test_gru_kernel_matches_ref(b, t, din, h):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=din, hidden=h))
    xs = jax.random.normal(k2, (b, t, din), jnp.float32)
    h0 = jax.random.normal(k3, (b, h), jnp.float32)
    out_k, last_k = gru_ops.gru_sequence(params, xs, h0, interpret=True)
    out_r, last_r = gru_ref.gru_sequence(params, xs, h0)
    np.testing.assert_allclose(out_k, out_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(last_k, last_r, atol=1e-5, rtol=1e-5)


def test_gru_kernel_reset_mask():
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(6), 4)
    b, t, din, h = 3, 24, 8, 16
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=din, hidden=h))
    xs = jax.random.normal(k2, (b, t, din), jnp.float32)
    h0 = jax.random.normal(k3, (b, h), jnp.float32)
    resets = jax.random.bernoulli(k4, 0.2, (b, t)).astype(jnp.float32)
    out_k, _ = gru_ops.gru_sequence(params, xs, h0, reset_mask=resets,
                                    interpret=True)
    out_r, _ = gru_ref.gru_sequence(params, xs, h0, reset_mask=resets)
    np.testing.assert_allclose(out_k, out_r, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba2 state-space duality)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 256, 4, 32, 32, 64),
    (1, 64, 1, 8, 64, 64),       # single chunk
])
def test_ssd_kernel_matches_ref(b, t, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, t, n), jnp.float32)
    c = jax.random.normal(ks[4], (b, t, n), jnp.float32)
    y_k, s_k = ssd_ops.ssd(x, dt, a, bmat, c, chunk=chunk, interpret=True)
    y_r, s_r = ssd_ref.ssd(x, dt, a, bmat, c, chunk=chunk)
    np.testing.assert_allclose(y_k, y_r, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s_k, s_r, atol=2e-4, rtol=2e-4)


def test_ssd_kernel_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    b, t, h, p, n, chunk = 1, 64, 2, 8, 16, 32
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, t, n), jnp.float32)
    c = jax.random.normal(ks[4], (b, t, n), jnp.float32)
    s0 = jax.random.normal(ks[5], (b, h, p, n), jnp.float32)
    y_k, s_k = ssd_ops.ssd(x, dt, a, bmat, c, chunk=chunk,
                           initial_state=s0, interpret=True)
    y_r, s_r = ssd_ref.ssd(x, dt, a, bmat, c, chunk=chunk, initial_state=s0)
    np.testing.assert_allclose(y_k, y_r, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s_k, s_r, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# GAE
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 16), (2, 3, 32), (8,)])
def test_gae_kernel_matches_ref(shape):
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    rewards = jax.random.normal(ks[0], shape)
    values = jax.random.normal(ks[1], shape)
    dones = jax.random.bernoulli(ks[2], 0.1, shape).astype(jnp.float32)
    last_value = jax.random.normal(ks[3], shape[:-1])
    adv_k, ret_k = gae_ops.gae(rewards, values, dones, last_value,
                               interpret=True)
    adv_r, ret_r = gae_ref.gae(rewards, values, dones, last_value)
    np.testing.assert_allclose(adv_k, adv_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(ret_k, ret_r, atol=1e-5, rtol=1e-5)
