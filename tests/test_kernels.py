"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracle, swept over shapes and dtypes — forward AND backward (the
gru/gae kernels carry custom_vjp Pallas reverse passes), plus the
dispatch layer that routes the MARL hot spots onto them."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.gae import ops as gae_ops
from repro.kernels.gae import ref as gae_ref
from repro.kernels.gru import ops as gru_ops
from repro.kernels.gru import ref as gru_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref
from repro.nn import gru as gru_mod


def tree_maxdiff(a, b):
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,hkv,d", [
    (1, 128, 4, 4, 64),          # MHA
    (2, 256, 8, 2, 64),          # GQA 4:1
    (1, 128, 4, 1, 128),         # MQA, wide head
    (2, 384, 6, 6, 64),          # T not a block multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, t, h, hkv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, d), dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True, interpret=True)
    ref = fa_ref.attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, t, h, d = 1, 256, 4, 64
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True,
                                 sliding_window=window, interpret=True)
    ref = fa_ref.attention(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, t, h, d = 1, 128, 2, 64
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32) * 3
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32) * 3
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True, softcap=50.0,
                                 interpret=True)
    ref = fa_ref.attention(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)
    # softcap must actually change the answer
    ref_nocap = fa_ref.attention(q, k, v, causal=True)
    assert not np.allclose(ref, ref_nocap, atol=1e-3)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, t, h, d = 2, 128, 4, 64
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=False, interpret=True)
    ref = fa_ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# GRU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,din,h", [
    (2, 16, 8, 16), (4, 33, 12, 32), (1, 64, 32, 64),
])
def test_gru_kernel_matches_ref(b, t, din, h):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=din, hidden=h))
    xs = jax.random.normal(k2, (b, t, din), jnp.float32)
    h0 = jax.random.normal(k3, (b, h), jnp.float32)
    out_k, last_k = gru_ops.gru_sequence(params, xs, h0, interpret=True)
    out_r, last_r = gru_ref.gru_sequence(params, xs, h0)
    np.testing.assert_allclose(out_k, out_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(last_k, last_r, atol=1e-5, rtol=1e-5)


def test_gru_kernel_reset_mask():
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(6), 4)
    b, t, din, h = 3, 24, 8, 16
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=din, hidden=h))
    xs = jax.random.normal(k2, (b, t, din), jnp.float32)
    h0 = jax.random.normal(k3, (b, h), jnp.float32)
    resets = jax.random.bernoulli(k4, 0.2, (b, t)).astype(jnp.float32)
    out_k, _ = gru_ops.gru_sequence(params, xs, h0, reset_mask=resets,
                                    interpret=True)
    out_r, _ = gru_ref.gru_sequence(params, xs, h0, reset_mask=resets)
    np.testing.assert_allclose(out_k, out_r, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("with_resets", [False, True])
@pytest.mark.parametrize("b,t,din,h", [(2, 16, 8, 16), (4, 33, 12, 32)])
def test_gru_kernel_grad_matches_ref(b, t, din, h, with_resets):
    """custom_vjp through the Pallas backward-scan kernel vs jax.grad of
    the jnp oracle — w.r.t. params (incl. wh/bh accumulation), xs, h0."""
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(11), 5)
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=din, hidden=h))
    xs = jax.random.normal(k2, (b, t, din), jnp.float32)
    h0 = jax.random.normal(k3, (b, h), jnp.float32)
    resets = (jax.random.bernoulli(k4, 0.2, (b, t)).astype(jnp.float32)
              if with_resets else None)
    g = jax.random.normal(k5, (b, t, h), jnp.float32)

    def loss(seq_fn):
        def f(p, x, h0_):
            hs, h_last = seq_fn(p, x, h0_)
            return (hs * g).sum() + (h_last ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2))

    grads_k = loss(lambda p, x, h0_: gru_ops.gru_sequence(
        p, x, h0_, reset_mask=resets, interpret=True))(params, xs, h0)
    grads_r = loss(lambda p, x, h0_: gru_ref.gru_sequence(
        p, x, h0_, reset_mask=resets))(params, xs, h0)
    assert tree_maxdiff(grads_k, grads_r) < 1e-5


def test_gru_kernel_grad_under_vmap():
    """The sharded runtime vmaps the kernelized sequence over the agent
    axis (stacked params) — grads must survive jit(vmap(grad(...)))."""
    n, b, t, din, h = 3, 2, 9, 6, 8
    params = jax.vmap(lambda k: gru_mod.gru_init(
        k, gru_mod.GRUConfig(in_dim=din, hidden=h)))(
        jax.random.split(jax.random.PRNGKey(0), n))
    xs = jax.random.normal(jax.random.PRNGKey(1), (n, b, t, din))
    h0 = jnp.zeros((n, b, h))
    resets = jax.random.bernoulli(
        jax.random.PRNGKey(2), 0.2, (n, b, t)).astype(jnp.float32)

    def one(seq_fn):
        def f(p, x, h0_, r):
            hs, _ = seq_fn(p, x, h0_, r)
            return (hs ** 2).mean()
        return jax.jit(jax.vmap(jax.grad(f)))

    gk = one(lambda p, x, h0_, r: gru_ops.gru_sequence(
        p, x, h0_, reset_mask=r, interpret=True))(params, xs, h0, resets)
    gr = one(lambda p, x, h0_, r: gru_ref.gru_sequence(
        p, x, h0_, reset_mask=r))(params, xs, h0, resets)
    assert tree_maxdiff(gk, gr) < 1e-6


@pytest.mark.parametrize("xs_dtype,h0_dtype,want", [
    (jnp.float32, jnp.float32, jnp.float32),
    (jnp.bfloat16, None, jnp.bfloat16),       # oracle: h0 inherits xs dtype
    (jnp.bfloat16, jnp.float32, jnp.float32),  # oracle: hs threads h0 dtype
])
def test_gru_kernel_dtype_contract(xs_dtype, h0_dtype, want):
    """No silent upcasting: hs/h_last come back in the oracle's output
    dtype (h0.dtype when given, else xs.dtype)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(12), 3)
    b, t, din, h = 2, 8, 4, 8
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=din, hidden=h))
    xs = jax.random.normal(k2, (b, t, din), xs_dtype)
    h0 = (jax.random.normal(k3, (b, h), h0_dtype)
          if h0_dtype is not None else None)
    hs_k, last_k = gru_ops.gru_sequence(params, xs, h0, interpret=True)
    hs_r, last_r = gru_ref.gru_sequence(params, xs, h0)
    assert hs_k.dtype == hs_r.dtype == want
    assert last_k.dtype == last_r.dtype == want
    # bf16 anywhere on the path (inputs or outputs) loosens the tolerance:
    # the oracle rounds the input-gate matmul through bf16, the kernel
    # computes it in fp32
    tol = 3e-2 if jnp.bfloat16 in (xs_dtype, want) else 1e-5
    np.testing.assert_allclose(hs_k.astype(jnp.float32),
                               hs_r.astype(jnp.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# SSD (Mamba2 state-space duality)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 256, 4, 32, 32, 64),
    (1, 64, 1, 8, 64, 64),       # single chunk
])
def test_ssd_kernel_matches_ref(b, t, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, t, n), jnp.float32)
    c = jax.random.normal(ks[4], (b, t, n), jnp.float32)
    y_k, s_k = ssd_ops.ssd(x, dt, a, bmat, c, chunk=chunk, interpret=True)
    y_r, s_r = ssd_ref.ssd(x, dt, a, bmat, c, chunk=chunk)
    np.testing.assert_allclose(y_k, y_r, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s_k, s_r, atol=2e-4, rtol=2e-4)


def test_ssd_kernel_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    b, t, h, p, n, chunk = 1, 64, 2, 8, 16, 32
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, t, n), jnp.float32)
    c = jax.random.normal(ks[4], (b, t, n), jnp.float32)
    s0 = jax.random.normal(ks[5], (b, h, p, n), jnp.float32)
    y_k, s_k = ssd_ops.ssd(x, dt, a, bmat, c, chunk=chunk,
                           initial_state=s0, interpret=True)
    y_r, s_r = ssd_ref.ssd(x, dt, a, bmat, c, chunk=chunk, initial_state=s0)
    np.testing.assert_allclose(y_k, y_r, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s_k, s_r, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# GAE
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 16), (2, 3, 32), (8,)])
def test_gae_kernel_matches_ref(shape):
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    rewards = jax.random.normal(ks[0], shape)
    values = jax.random.normal(ks[1], shape)
    dones = jax.random.bernoulli(ks[2], 0.1, shape).astype(jnp.float32)
    last_value = jax.random.normal(ks[3], shape[:-1])
    adv_k, ret_k = gae_ops.gae(rewards, values, dones, last_value,
                               interpret=True)
    adv_r, ret_r = gae_ref.gae(rewards, values, dones, last_value)
    # fp32 in, same op sequence: the interpret-mode kernel is bitwise
    np.testing.assert_array_equal(np.asarray(adv_k), np.asarray(adv_r))
    np.testing.assert_array_equal(np.asarray(ret_k), np.asarray(ret_r))


@pytest.mark.parametrize("shape", [(4, 16), (2, 3, 32)])
def test_gae_kernel_grad_matches_ref(shape):
    """Linear-adjoint Pallas reverse pass vs jax.grad of the oracle —
    w.r.t. rewards, values (incl. the next_values shift), last_value."""
    ks = jax.random.split(jax.random.PRNGKey(10), 5)
    rewards = jax.random.normal(ks[0], shape)
    values = jax.random.normal(ks[1], shape)
    dones = jax.random.bernoulli(ks[2], 0.15, shape).astype(jnp.float32)
    last_value = jax.random.normal(ks[3], shape[:-1])
    g = jax.random.normal(ks[4], shape)

    def loss(gae_fn):
        def f(r, v, lv):
            adv, ret = gae_fn(r, v, lv)
            return (adv * g).sum() + (ret ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2))

    gk = loss(lambda r, v, lv: gae_ops.gae(r, v, dones, lv,
                                           interpret=True))(
        rewards, values, last_value)
    gr = loss(lambda r, v, lv: gae_ref.gae(r, v, dones, lv))(
        rewards, values, last_value)
    assert tree_maxdiff(gk, gr) < 1e-5


def test_gae_oracle_traces_and_round_trips_bf16():
    """The oracle used to desync its scan carry dtype under bf16 inputs
    (the (1 - d) masking promotes to f32) and crash at trace time; it
    now accumulates in f32 and casts back, so bf16 in means bf16 out —
    the DtypeRoundTrip contract."""
    from repro.marl import gae as gae_mod
    shape = (3, 8)
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    args = (jax.random.normal(ks[0], shape, jnp.bfloat16),
            jax.random.normal(ks[1], shape, jnp.bfloat16),
            jax.random.bernoulli(ks[2], 0.1, shape).astype(jnp.bfloat16),
            jax.random.normal(ks[3], shape[:-1], jnp.bfloat16))
    adv, ret = gae_mod.gae(*args)
    assert adv.dtype == jnp.bfloat16 and ret.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(adv.astype(jnp.float32)).all())
    # f32 numerics untouched by the accumulate-then-cast rewrite
    f32 = tuple(a.astype(jnp.float32) for a in args)
    adv32, _ = gae_mod.gae(*f32)
    np.testing.assert_allclose(np.asarray(adv.astype(jnp.float32)),
                               np.asarray(adv32), atol=0.15, rtol=0.15)


def test_gae_kernel_path_round_trips_bf16():
    """The kernel dispatch path scans in f32 and used to return f32 for
    bf16 inputs — a silent upcast; it now casts back to values.dtype."""
    shape = (2, 8)
    ks = jax.random.split(jax.random.PRNGKey(12), 4)
    args = (jax.random.normal(ks[0], shape, jnp.bfloat16),
            jax.random.normal(ks[1], shape, jnp.bfloat16),
            jax.random.bernoulli(ks[2], 0.1, shape).astype(jnp.bfloat16),
            jax.random.normal(ks[3], shape[:-1], jnp.bfloat16))
    adv_k, ret_k = gae_ops.gae(*args, interpret=True)
    assert adv_k.dtype == jnp.bfloat16 and ret_k.dtype == jnp.bfloat16
    adv_r, _ = gae_ref.gae(*args)
    np.testing.assert_allclose(
        np.asarray(adv_k.astype(jnp.float32)),
        np.asarray(adv_r.astype(jnp.float32)), atol=0.1, rtol=0.1)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------
def test_dispatch_resolve_modes():
    on_cpu = dispatch.resolve("on", backend="cpu")
    assert on_cpu.use and on_cpu.interpret
    off_cpu = dispatch.resolve("off", backend="cpu")
    assert not off_cpu.use and off_cpu.interpret
    auto_cpu = dispatch.resolve("auto", backend="cpu")
    assert not auto_cpu.use          # auto on CPU: oracle, no interp cost
    auto_tpu = dispatch.resolve("auto", backend="tpu")
    assert auto_tpu.use and not auto_tpu.interpret
    assert dispatch.resolve("on", backend="tpu") == auto_tpu
    # pre-resolved decisions pass through unchanged
    assert dispatch.resolve(on_cpu) is on_cpu
    with pytest.raises(ValueError, match="use_kernels"):
        dispatch.resolve("yes")


def test_dispatch_override_mode():
    from repro.core import influence
    cfg = influence.AIPConfig(in_dim=4, n_sources=2)
    assert cfg.use_kernels == "auto"
    assert dispatch.override_mode(cfg, "auto") is cfg       # driver defers
    on = dispatch.override_mode(cfg, "on")
    assert on.use_kernels == "on" and on.in_dim == cfg.in_dim
    assert dispatch.override_mode(on, "on") is on           # idempotent
    with pytest.raises(ValueError, match="use_kernels"):
        dispatch.override_mode(cfg, "maybe")


def test_nn_gru_sequence_routes_to_kernel():
    """use_kernels='on' through the nn-level entry point returns the
    kernel's numbers (and 'off' the oracle's) — the route the AIP and
    policy configs thread."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(13))
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=4, hidden=8))
    xs = jax.random.normal(k2, (2, 6, 4), jnp.float32)
    hs_on, _ = gru_mod.gru_sequence(params, xs, use_kernels="on")
    hs_off, _ = gru_mod.gru_sequence(params, xs, use_kernels="off")
    hs_k, _ = gru_ops.gru_sequence(params, xs, interpret=True)
    np.testing.assert_array_equal(np.asarray(hs_on), np.asarray(hs_k))
    np.testing.assert_allclose(hs_on, hs_off, atol=1e-5, rtol=1e-5)


def test_nn_gru_cell_routes_to_kernel():
    """The single-step rollout path (policy_apply / aip_apply inside the
    GS and LS rollouts) dispatches to the T=1 Pallas cell: 'on' matches
    the op-level kernel exactly and the oracle to fp32 tolerance, under
    plain calls AND vmapped over an agent axis (how the rollouts run
    it); dtype contract follows the hidden state."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(14), 3)
    params = gru_mod.gru_init(k1, gru_mod.GRUConfig(in_dim=5, hidden=8))
    h = jax.random.normal(k2, (4, 8), jnp.float32)
    x = jax.random.normal(k3, (4, 5), jnp.float32)
    on = gru_mod.gru_cell(params, h, x, use_kernels="on")
    off = gru_mod.gru_cell(params, h, x)                  # oracle default
    kern = gru_ops.gru_cell(params, h, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(kern))
    np.testing.assert_allclose(on, off, atol=1e-5, rtol=1e-5)
    assert on.dtype == h.dtype
    # a kernel step equals one step of the kernel scan (shared kernel)
    hs, _ = gru_ops.gru_sequence(params, x[:, None, :], h, interpret=True)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(hs[:, 0]))
    # vmapped over agents, as the stacked-policy rollout step runs it
    stack = lambda t: jax.tree.map(lambda a: jnp.stack([a] * 3), t)
    v_on = jax.vmap(lambda p, hh, xx: gru_mod.gru_cell(
        p, hh, xx, use_kernels="on"))(stack(params), stack(h), stack(x))
    v_off = jax.vmap(gru_mod.gru_cell)(stack(params), stack(h), stack(x))
    np.testing.assert_allclose(v_on, v_off, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: the kernelized hot paths match the oracle training paths
# ---------------------------------------------------------------------------
def _aip_setup(use_kernels):
    from repro.core import influence
    cfg = influence.AIPConfig(in_dim=6, n_sources=3, kind="gru",
                              hidden=(12,), gru_hidden=8, epochs=20,
                              batch=8, lr=1e-3, use_kernels=use_kernels)
    ks = jax.random.split(jax.random.PRNGKey(20), 4)
    data = {"feats": jax.random.normal(ks[0], (12, 16, 6)),
            "u": jax.random.bernoulli(
                ks[1], 0.4, (12, 16, 3)).astype(jnp.float32),
            "resets": jax.random.bernoulli(
                ks[2], 0.1, (12, 16)).astype(jnp.float32)}
    params = influence.aip_init(ks[3], cfg)
    return cfg, params, data


def test_train_aip_kernel_path_matches_oracle():
    """Full train_aip (minibatch Adam over epochs, grads through the
    custom_vjp) with use_kernels='on' lands on the oracle path's params
    and loss to 1e-5."""
    from repro.core import influence
    (cfg_on, p0, data), (cfg_off, _, _) = _aip_setup("on"), _aip_setup("off")
    key = jax.random.PRNGKey(21)
    p_on, loss_on = influence.train_aip(p0, data, key, cfg_on)
    p_off, loss_off = influence.train_aip(p0, data, key, cfg_off)
    assert tree_maxdiff(p_on, p_off) < 1e-5
    assert abs(float(loss_on) - float(loss_off)) < 1e-5
    # the loss curves agree too: held-out CE from either param set matches
    ce_on = influence.eval_ce(p_on, data, cfg_on)
    ce_off = influence.eval_ce(p_off, data, cfg_off)
    assert abs(float(ce_on) - float(ce_off)) < 1e-5


def test_ppo_update_kernel_path_matches_oracle():
    """One PPO update on a synthetic GRU-policy trajectory: the Pallas
    policy-GRU recompute (custom_vjp inside ppo_loss grads) matches the
    oracle to 1e-5."""
    from repro.marl import policy as policy_mod
    from repro.marl import ppo as ppo_mod
    from repro.optim import adamw
    e, t, obs_dim, n_act = 8, 10, 5, 3
    ks = jax.random.split(jax.random.PRNGKey(30), 8)
    traj = {
        "obs": jax.random.normal(ks[0], (e, t, obs_dim)),
        "actions": jax.random.randint(ks[1], (e, t), 0, n_act),
        "logp_old": -jnp.abs(jax.random.normal(ks[2], (e, t))),
        "adv": jax.random.normal(ks[3], (e, t)),
        "ret": jax.random.normal(ks[4], (e, t)),
        "values_old": jax.random.normal(ks[5], (e, t)),
        "resets": jax.random.bernoulli(
            ks[6], 0.15, (e, t)).astype(jnp.float32),
    }
    outs = {}
    for mode in ("on", "off"):
        pc = policy_mod.PolicyConfig(obs_dim=obs_dim, n_actions=n_act,
                                     kind="gru", hidden=(8,), gru_hidden=8,
                                     use_kernels=mode)
        params = policy_mod.policy_init(ks[7], pc)
        batch = {**traj, "h0": jnp.zeros((e, pc.gru_hidden))}
        cfg = ppo_mod.PPOConfig(epochs=2, minibatches=2, use_kernels=mode)
        new_params, _, metrics = ppo_mod.ppo_update(
            params, adamw.init(params), batch,
            jax.random.PRNGKey(31), pc, cfg)
        outs[mode] = (new_params, metrics)
    assert tree_maxdiff(outs["on"][0], outs["off"][0]) < 1e-5
    assert abs(float(outs["on"][1]["loss"])
               - float(outs["off"][1]["loss"])) < 1e-5
