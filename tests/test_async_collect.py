"""Async / overlapped GS collect (repro.distributed.async_collect) and the
now-real staleness machinery (DIALSConfig.max_aip_staleness +
fault.freshness_gate) through the public DIALSTrainer.run API.

The async schedule's contract, pinned by construction:

* round 0 primes the double buffer with a blocking collect — identical to
  the serial round 0;
* steady state trains round k on the dataset collected under round k-1's
  entry policy (``data_round == k-1``: the documented one-round lag that
  Lemma 2 licenses);
* ``max_aip_staleness=0`` leaves no lag to tolerate, so the force-sync
  path fires every round and the async run degenerates to the serial
  schedule — on the single-device loop path this is BITWISE equality;
* the ``untrained`` ablation never consumes the dataset for training, so
  async and serial histories must agree exactly on returns/rewards even
  with the lag (only the CE metrics see the lagged data).

The same contract on a real multi-device mesh runs in
``tests/_multidevice_check.py`` (CI's runtime-multidevice job).
"""
import threading

import jax
import numpy as np
import pytest

from repro.core import dials, influence
from repro.distributed import async_collect
from repro.envs import registry
from repro.marl import policy as policy_mod, ppo as ppo_mod


def build_trainer(**kw):
    env_mod, cfg = registry.make("traffic", horizon=16)
    info = cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, hidden=(16,))
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="fnn",
                             hidden=(16,), epochs=2, batch=16)
    ppo_cfg = ppo_mod.PPOConfig(epochs=1, minibatches=2)
    kw.setdefault("shards", 1)      # loop path unless a test overrides
    kw.setdefault("outer_rounds", 3)
    dcfg = dials.DIALSConfig(
        aip_refresh=2, collect_envs=2, collect_steps=16,
        n_envs=2, rollout_steps=8, eval_episodes=2, **kw)
    return dials.DIALSTrainer(env_mod, cfg, pc, ac, ppo_cfg, dcfg)


# ---------------------------------------------------------------------------
# AsyncCollector mechanics (thread mode, controllable fake collector)
# ---------------------------------------------------------------------------
class _FakeCollect:
    """Deterministic fake: returns (params, key) echo + call count; can be
    held back with an event to simulate a slow background collect."""

    def __init__(self):
        self.calls = 0
        self.release = threading.Event()
        self.release.set()

    def __call__(self, params, key):
        self.release.wait(timeout=30)
        self.calls += 1
        return {"params": params, "key": key}


def test_collector_primes_then_pipelines():
    fake = _FakeCollect()
    c = async_collect.AsyncCollector(fake, mode="thread")
    d0, forced = c.obtain(0, 10.0, 0, max_staleness=2)
    assert forced and d0.round == 0 and fake.calls == 1     # prime
    c.submit(11.0, 1, round=0)
    d1, forced = c.obtain(1, 11.0, 1, max_staleness=2)
    assert d1.round == 0 and d1.data["params"] == 11.0
    assert not forced                                       # harvested async
    assert c.idle()
    c.close()


def test_collector_barrier_blocks_until_inflight_slot_ready():
    """obtain() at a round the current slot is stale for BLOCKS on the
    in-flight collect instead of opportunistically reusing older data:
    which dataset trains round r is a function of the round alone, never
    of thread scheduling (per-seed determinism)."""
    fake = _FakeCollect()
    c = async_collect.AsyncCollector(fake, mode="thread")
    c.obtain(0, 0.0, 0, max_staleness=2)                    # prime, tag 0
    fake.release.clear()                                    # stall the bg
    c.submit(1.0, 1, round=0)
    out = {}
    t = threading.Thread(target=lambda: out.update(zip(
        ("d", "forced"), c.obtain(1, 1.0, 1, max_staleness=2))))
    t.start()
    t.join(timeout=0.5)
    assert t.is_alive(), "obtain() must wait for the in-flight collect"
    fake.release.set()
    t.join(timeout=30)
    assert not t.is_alive()
    assert out["d"].round == 0 and not out["forced"]
    assert out["d"].data["params"] == 1.0                   # the bg result
    assert fake.calls == 2 and c.idle()
    c.close()


def test_collector_force_syncs_when_harvest_still_too_old():
    """A harvested slot older than the bound (here: the bound is 0, so
    the one-round lag itself is intolerable) triggers a fresh blocking
    collect tagged with the current round."""
    fake = _FakeCollect()
    c = async_collect.AsyncCollector(fake, mode="thread")
    c.obtain(0, 0.0, 0, max_staleness=0)                    # prime, tag 0
    c.submit(1.0, 1, round=0)
    d, forced = c.obtain(1, 1.0, 1, max_staleness=0)
    assert forced and d.round == 1 and d.data["params"] == 1.0
    assert c.idle() and fake.calls == 3     # prime + discarded bg + sync
    c.close()


def test_collector_single_inflight_slot():
    fake = _FakeCollect()
    c = async_collect.AsyncCollector(fake, mode="thread")
    c.submit(0.0, 0, round=0)
    with pytest.raises(RuntimeError, match="in flight"):
        c.submit(1.0, 1, round=1)
    c.close()


# ---------------------------------------------------------------------------
# same-seed equivalence through the public DIALSTrainer.run API (loop path)
# ---------------------------------------------------------------------------
def test_async_staleness_zero_is_bitwise_serial():
    """max_aip_staleness=0 forbids any lag: every round force-syncs with
    the serial round's own collect key and policy, so the async run IS
    the serial run, bit for bit."""
    s1, h1 = build_trainer().run(jax.random.PRNGKey(0))
    s2, h2 = build_trainer(async_collect=True,
                           max_aip_staleness=0).run(jax.random.PRNGKey(0))
    assert [r["gs_return"] for r in h1] == [r["gs_return"] for r in h2]
    assert [r["aip_ce_after"] for r in h1] == \
        [r["aip_ce_after"] for r in h2]
    assert all(r["forced_sync"] for r in h2)
    assert [r["data_round"] for r in h2] == [r["round"] for r in h2]
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        {"p": s1["ials"]["params"], "a": s1["aips"]},
        {"p": s2["ials"]["params"], "a": s2["aips"]})


def test_async_steady_state_has_one_round_lag():
    """Happy path: round 0 primes (forced, tag 0), round k>=1 trains on
    the tag k-1 dataset that was collected during round k-1."""
    _, hist = build_trainer(async_collect=True).run(jax.random.PRNGKey(0))
    assert [r["data_round"] for r in hist] == [0, 0, 1]
    assert [r["forced_sync"] for r in hist] == [True, False, False]
    # serial history for reference: tags follow the round index
    _, serial = build_trainer().run(jax.random.PRNGKey(0))
    assert [r["data_round"] for r in serial] == [0, 1, 2]
    # round 0 primes with the serial round-0 collect -> identical record
    assert hist[0]["gs_return"] == serial[0]["gs_return"]
    assert hist[0]["aip_ce_after"] == serial[0]["aip_ce_after"]


def test_async_untrained_histories_match_serial_exactly():
    """The untrained ablation never trains on the dataset, so the lag is
    invisible to the policy stream: returns/rewards must match the serial
    run exactly; only the CE metrics see the lagged datasets."""
    _, h1 = build_trainer(untrained=True).run(jax.random.PRNGKey(0))
    _, h2 = build_trainer(untrained=True,
                          async_collect=True).run(jax.random.PRNGKey(0))
    assert [r["gs_return"] for r in h1] == [r["gs_return"] for r in h2]
    assert [r["ials_reward"] for r in h1] == [r["ials_reward"] for r in h2]


def test_async_run_is_deterministic():
    _, h1 = build_trainer(async_collect=True).run(jax.random.PRNGKey(0))
    _, h2 = build_trainer(async_collect=True).run(jax.random.PRNGKey(0))
    assert [r["gs_return"] for r in h1] == [r["gs_return"] for r in h2]
    assert [r["data_round"] for r in h1] == [r["data_round"] for r in h2]


# ---------------------------------------------------------------------------
# the staleness bound is ENFORCED (satellite: dead machinery made real)
# ---------------------------------------------------------------------------
def test_straggler_force_refreshed_past_staleness_bound():
    """An agent whose straggler_mask never clears must still be refreshed
    once its predictor's data is max_aip_staleness rounds old — before
    this gate existed, a permanent straggler trained on arbitrarily old
    influence forever."""
    trainer = build_trainer(outer_rounds=4, max_aip_staleness=1)
    state0 = trainer.init(jax.random.PRNGKey(0))
    # agent 0 never reports in time; the rest always do
    mask = np.array([0.0, 1.0, 1.0, 1.0], np.float32)
    state, hist = trainer.run(jax.random.PRNGKey(0),
                              straggler_mask=lambda rnd: mask)
    # rounds 0 (report -1, age 1 <= 1): tolerated; round 1 (age 2 > 1):
    # forced; round 2 tolerated again; round 3 forced.
    assert [r["stale_forced"] for r in hist] == [0, 1, 0, 1]
    # the forced refresh really replaced agent 0's predictor
    leaf0 = jax.tree.leaves(state0["aips"])[0][0]
    leaf = jax.tree.leaves(state["aips"])[0][0]
    assert not np.allclose(np.asarray(leaf0), np.asarray(leaf))


def test_straggler_within_bound_keeps_old_aips():
    """Inside the bound nothing is forced: with the default bound (2) and
    2 rounds, a permanent straggler's AIPs never change (the seed
    behavior, now an explicit consequence of the gate)."""
    trainer = build_trainer(outer_rounds=2)
    state0 = trainer.init(jax.random.PRNGKey(0))
    state, hist = trainer.run(
        jax.random.PRNGKey(0),
        straggler_mask=lambda rnd: np.zeros(4, np.float32))
    assert [r["stale_forced"] for r in hist] == [0, 0]
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=0),
                 state0["aips"], state["aips"])


# ---------------------------------------------------------------------------
# sharded path (1-shard mesh runs on the single real CPU device)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_async_staleness_zero_matches_serial():
    """The split collect/shard-train programs at bound 0 reproduce the
    serial loop path (same math; split-vs-fused XLA fusion differences
    stay at ulp scale)."""
    s1, h1 = build_trainer().run(jax.random.PRNGKey(0))
    tr = build_trainer(async_collect=True, max_aip_staleness=0)
    state = tr.restore_or_init(jax.random.PRNGKey(0))
    s2, h2 = tr._run_sharded(state, 1, log=None, straggler_mask=None)
    assert all(r["forced_sync"] for r in h2)
    for r1, r2 in zip(h1, h2):
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=1e-5)
        assert r1["data_round"] == r2["data_round"]
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), atol=1e-5),
        {"p": s1["ials"]["params"], "a": s1["aips"]},
        {"p": s2["ials"]["params"], "a": s2["aips"]})


@pytest.mark.slow
def test_sharded_async_one_round_lag_and_loop_agreement():
    """Sharded async vs loop async: same schedule, same tags, same
    numbers (to the usual cross-path tolerance)."""
    _, h_loop = build_trainer(async_collect=True).run(jax.random.PRNGKey(0))
    tr = build_trainer(async_collect=True)
    state = tr.restore_or_init(jax.random.PRNGKey(0))
    _, h_shard = tr._run_sharded(state, 1, log=None, straggler_mask=None)
    assert [r["data_round"] for r in h_shard] == \
        [r["data_round"] for r in h_loop] == [0, 0, 1]
    for r1, r2 in zip(h_loop, h_shard):
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=1e-5)
        np.testing.assert_allclose(r1["aip_ce_after"], r2["aip_ce_after"],
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint-resume under async collect (the re-primed double buffer)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_async_resume_matches_uninterrupted_run(tmp_path):
    """Kill-and-resume equality on the async loop path: a run interrupted
    at a round boundary and resumed from its checkpoint must produce the
    SAME final params and the SAME staleness schedule as the
    uninterrupted run — the checkpoint carries the in-flight collect's
    round tag (``extra["async_round"]``) and the resume re-submits that
    exact collect (same params, same key, same tag) instead of
    force-syncing into a fresher dataset (which would silently change
    the data every post-resume round trains on)."""
    from repro.distributed import chaos as chaos_mod

    kw = dict(async_collect=True, max_aip_staleness=2, outer_rounds=4)
    ref = build_trainer(**kw)
    s_ref, h_ref = ref.run(jax.random.PRNGKey(0))

    ck = str(tmp_path / "ck")
    interrupted = build_trainer(ckpt_dir=ck, ckpt_keep=10, **kw)
    sched = chaos_mod.FaultSchedule.from_spec("interrupt@2")
    with pytest.raises(chaos_mod.ChaosInterrupt):
        interrupted.run(jax.random.PRNGKey(0), chaos=sched)
    interrupted.manager.wait()           # drain the async step-2 write

    resumed = build_trainer(ckpt_dir=ck, ckpt_keep=10, **kw)
    s_res, h_res = resumed.run(jax.random.PRNGKey(0))

    # the resumed rounds keep the steady-state schedule: the re-primed
    # in-flight collect is harvested (no force-sync) with the exact
    # one-round-lag tags of the uninterrupted run
    assert [r["round"] for r in h_res] == [2, 3], h_res
    assert [r["forced_sync"] for r in h_res] == [False, False], h_res
    assert [r["data_round"] for r in h_res] == \
        [r["data_round"] for r in h_ref[2:]], h_res
    for r1, r2 in zip(h_ref[2:], h_res):
        assert r1["gs_return"] == r2["gs_return"], (r1, r2)
        assert r1["aip_ce_after"] == r2["aip_ce_after"], (r1, r2)
    # bitwise: the single-device loop path is deterministic and the
    # restored carry + re-primed collect reproduce the original inputs
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg="resumed vs uninterrupted params"),
        {"p": s_ref["ials"]["params"], "a": s_ref["aips"]},
        {"p": s_res["ials"]["params"], "a": s_res["aips"]})


@pytest.mark.slow
def test_async_resume_force_syncs_when_reprime_impossible(tmp_path):
    """When the checkpoint that held the in-flight collect's submit
    params has been rotated away, the resume falls back to the legacy
    force-sync prime — fresher data, still Lemma-2-legal — instead of
    crashing or silently training on nothing."""
    ck = str(tmp_path / "ck")
    kw = dict(async_collect=True, max_aip_staleness=2, outer_rounds=4)
    interrupted = build_trainer(ckpt_dir=ck, ckpt_keep=10, **kw)
    from repro.distributed import chaos as chaos_mod
    with pytest.raises(chaos_mod.ChaosInterrupt):
        interrupted.run(jax.random.PRNGKey(0),
                        chaos=chaos_mod.FaultSchedule.from_spec(
                            "interrupt@3"))
    interrupted.manager.wait()
    # simulate rotation: the async_round tag in step_3's extra is 2, so
    # deleting step_2 makes the re-prime impossible
    import shutil
    shutil.rmtree(str(tmp_path / "ck" / "step_2"))
    resumed = build_trainer(ckpt_dir=ck, ckpt_keep=10, **kw)
    _, h_res = resumed.run(jax.random.PRNGKey(0))
    assert [r["round"] for r in h_res] == [3], h_res
    assert bool(h_res[0]["forced_sync"]), h_res
    assert h_res[0]["data_round"] == 3, h_res
