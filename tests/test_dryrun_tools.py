"""The dry-run's HLO collective-bytes parser and roofline arithmetic."""
import pytest

from repro.launch import dryrun

HLO = """
ENTRY %main {
  %p0 = f32[256,1024]{1,0} parameter(0)
  %ag = f32[256,16384]{1,0} all-gather(%p0), dimensions={1}
  %ar = bf16[1024,1024]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[16,1024]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%q, %r)
  %fusion.1 = f32[2]{0} fusion(%ag), kind=kLoop, calls=%fused_all_gather
}
"""


def test_collective_bytes_parses_all_kinds():
    out = dryrun.collective_bytes(HLO)
    b = out["bytes"]
    assert b["all-gather"] == 256 * 16384 * 4
    assert b["all-reduce"] == 1024 * 1024 * 2
    assert b["reduce-scatter"] == 16 * 1024 * 4
    assert b["collective-permute"] == 8 * 4
    assert b["all-to-all"] == 2 * 16 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == sum(b.values())


def test_collective_bytes_ignores_fusion_names():
    out = dryrun.collective_bytes(
        "%f = f32[1024]{0} fusion(%a), calls=%fused_all_reduce_stuff")
    assert out["total_bytes"] == 0


def test_type_bytes_dtypes():
    assert dryrun._type_bytes("bf16[2,3]") == 12
    assert dryrun._type_bytes("f32[10]") == 40
    assert dryrun._type_bytes("pred[8]") == 8
    assert dryrun._type_bytes("s8[5] u32[2]") == 13


def test_roofline_terms():
    from benchmarks import roofline
    terms = roofline.terms(flops=1e15, bytes_accessed=1e12,
                           collective_bytes=1e9, n_devices=256)
    assert terms["compute_s"] == pytest.approx(
        1e15 / (256 * roofline.PEAK_FLOPS), rel=1e-6)
    assert terms["memory_s"] == pytest.approx(
        1e12 / (256 * roofline.HBM_BW), rel=1e-6)
    assert terms["collective_s"] == pytest.approx(
        1e9 / (256 * roofline.ICI_BW), rel=1e-6)
    assert terms["bottleneck"] in ("compute", "memory", "collective")
