"""Gradient-accumulation microbatching: the accumulated step must equal
the monolithic step exactly (same loss gradient, one optimizer update)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes as shapes_mod
from repro.launch import mesh as prod_mesh, steps as steps_mod

HOST = prod_mesh.make_host_mesh()


@pytest.mark.parametrize("micro", [2, 4])
def test_microbatched_train_step_matches_monolithic(micro):
    from repro.models import api
    from repro.optim import adamw
    spec = registry.get("tinyllama-1.1b", reduced=True)
    shape = shapes_mod.REDUCED_SHAPES["train_4k"]   # batch 2 — pad via micro
    # use a batch divisible by micro
    import dataclasses
    shape = dataclasses.replace(shape, global_batch=4)

    b_mono = steps_mod.make_train_step(spec, shape, HOST)
    b_micro = steps_mod.make_train_step(spec, shape, HOST,
                                        microbatches=micro)
    key = jax.random.PRNGKey(0)
    batch = registry.concrete_inputs(key, spec, shape)

    # the step donates params/opt: build a fresh copy per invocation
    params_a = api.init(key, spec)
    params_b = api.init(key, spec)
    p1, o1, m1 = b_mono.jit_fn(params_a, adamw.init(params_a), batch)
    p2, o2, m2 = b_micro.jit_fn(params_b, adamw.init(params_b), batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    # parameters after one update agree (bf16 tolerance)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=3e-2, rtol=3e-2), p1, p2)


def test_microbatch_requires_divisibility():
    spec = registry.get("tinyllama-1.1b", reduced=True)
    shape = shapes_mod.REDUCED_SHAPES["train_4k"]   # global_batch=2
    with pytest.raises(AssertionError):
        steps_mod.make_train_step(spec, shape, HOST, microbatches=3)
