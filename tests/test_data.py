"""Data pipeline: determinism, shapes, vocab range."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline, synthetic


def test_lm_batch_range_and_labels():
    b = synthetic.lm_batch(jax.random.PRNGKey(0), 4, 16, vocab=100)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    assert int(b["tokens"].min()) >= 0 and int(b["tokens"].max()) < 100
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))


def test_lm_batch_zipf_skew():
    """Zipf sampling: low token ids must be much more frequent."""
    b = synthetic.lm_batch(jax.random.PRNGKey(1), 64, 128, vocab=1000)
    toks = np.asarray(b["tokens"]).ravel()
    low = float(np.mean(toks < 100))
    assert low > 0.3


def test_lm_iterator_deterministic():
    it1 = pipeline.lm_iterator(seed=7, batch=2, seq=8, vocab=50)
    it2 = pipeline.lm_iterator(seed=7, batch=2, seq=8, vocab=50)
    for _ in range(3):
        a, b = next(it1), next(it2)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
    it3 = pipeline.lm_iterator(seed=8, batch=2, seq=8, vocab=50)
    assert not np.array_equal(np.asarray(next(it3)["tokens"]),
                              np.asarray(next(pipeline.lm_iterator(
                                  seed=7, batch=2, seq=8, vocab=50))["tokens"]))


def test_frames_and_patches_dtype():
    f = synthetic.frames(jax.random.PRNGKey(0), 2, 10, 16)
    p = synthetic.patches(jax.random.PRNGKey(0), 2, 10, 16)
    assert f.dtype == jnp.bfloat16 and f.shape == (2, 10, 16)
    assert p.dtype == jnp.bfloat16 and p.shape == (2, 10, 16)
