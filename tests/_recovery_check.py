"""Post-loss re-bootstrap worker for ``tests/test_recovery.py``.

Run as a coordinated 2-process ``jax.distributed`` group (4 forced host
devices each). A chaos spec kills one rank deterministically relative to
checkpoint state — ``writer_crash`` SIGKILLs its writer thread at a
chosen checkpoint phase while a ``heartbeat_delay`` parks its main
thread inside ``monitor.beat`` (so the dying rank never beats that
round and is never inside a collective when it dies). The survivor's
heartbeat gate times out, raises ``HostLossDetected``, and
``recovery.recover`` takes over: finalize any prepared-but-uncommitted
step, timeout-guarded teardown, shrink to a solo group (env cleared),
``os.execv``. The re-executed generation ≥ 1 process bootstraps solo,
resumes from the committed distributed checkpoint, finishes the run,
dumps params/history, then exercises the corrupt-fallback contract
(damage the newest committed step; ``restore_latest`` must fall back to
the previous one) and prints ``RECOVERY-OK``.

``--mode reference`` is the uninterrupted single-process run the test
compares final params against.
"""
import argparse
import json
import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import obs  # noqa: E402
from repro.distributed import chaos as chaos_mod  # noqa: E402
from repro.distributed import fault, recovery  # noqa: E402


def dump(path, state, history):
    """Same observable dump as _multihost_check: every param leaf in
    deterministic order plus the round records."""
    import jax
    import numpy as np
    leaves = {
        "aips": [np.asarray(x).tolist()
                 for x in jax.tree.leaves(state["aips"])],
        "params": [np.asarray(x).tolist()
                   for x in jax.tree.leaves(state["ials"]["params"])],
    }
    with open(path, "w") as f:
        json.dump({"history": history, **leaves}, f)


def build(args, telemetry_dir):
    # local import: _multidevice_check imports jax at module level, so
    # it must come after bootstrap
    from _multidevice_check import build_trainer
    return build_trainer(env="traffic", shards=4, outer_rounds=5,
                         ckpt_dir=args.ckpt_dir, ckpt_keep=10,
                         telemetry_dir=telemetry_dir)


def check_corrupt_fallback(args, trainer, state):
    """Damage the newest committed step; restore must skip it (and GC
    it) and land on the previous committed step."""
    from repro.checkpoint.distributed import DistributedCheckpointManager
    from repro.checkpoint.manager import step_dir
    mgr = DistributedCheckpointManager(args.ckpt_dir, keep=10,
                                       async_write=False)
    newest = mgr.latest_committed()
    assert newest >= 2, f"expected several committed steps, got {newest}"
    chaos_mod.corrupt_checkpoint(step_dir(args.ckpt_dir, newest), "bytes")
    tree, step = mgr.restore_latest(trainer._state_struct(state))
    assert step == newest - 1, (step, newest)
    assert tree is not None and tree["round"] == newest - 1


def run_worker(args):
    rank = int(os.environ.get("DIALS_PROCESS_ID", "0"))
    # telemetry BEFORE startup (explicit process_id — no device query)
    # so generation >= 1's rebootstrap event lands in the stream
    tel = (obs.Telemetry.create(args.telemetry_dir, process_id=rank)
           if args.telemetry_dir else obs.DISABLED)
    # tight clocks: jax's coordination service kills survivors ~10 s
    # after a peer stops heartbeating (its own missed-heartbeat
    # reaction) — detection (4 s) + teardown (2 s) must beat it to execv
    reco = recovery.RecoveryConfig(teardown_timeout_s=2.0,
                                   init_timeout_s=30.0, retries=4,
                                   backoff_s=0.25)
    ctx, gen = recovery.startup(reco=reco, telemetry=tel)

    import jax
    trainer = build(args, args.telemetry_dir)
    schedule = None
    if args.chaos:
        schedule = chaos_mod.FaultSchedule.from_spec(
            args.chaos, host=ctx.process_id, generation=gen, telemetry=tel)
    heartbeats, deadman = None, None
    if ctx.num_processes > 1:
        monitor = fault.HostMonitor(
            args.beat_dir, host=ctx.process_id,
            n_hosts=ctx.num_processes, timeout_s=4.0,
            telemetry=tel if tel.enabled else None)
        heartbeats = recovery.raising_gate(monitor)
        # out-of-band backstop: a peer dying mid-collective can wedge
        # this process in a native wait that never errors — the deadman
        # pulses/watches from daemon threads and recovers via execv
        # when a peer's pulse goes silent, main thread be damned
        deadman = recovery.Deadman(
            args.beat_dir, host=ctx.process_id,
            n_hosts=ctx.num_processes,
            current_round=lambda: heartbeats.round,
            on_loss=lambda loss: recovery.recover(
                loss, ctx, ckpt_dir=args.ckpt_dir, reco=reco,
                telemetry=tel),
            interval_s=1.0, silence_s=20.0, telemetry=tel).start()
    try:
        state, history = trainer.run(jax.random.PRNGKey(0),
                                     heartbeats=heartbeats, chaos=schedule)
        if deadman is not None:
            deadman.stop()           # a finished peer is silent, not dead
    except Exception as err:
        # a death BETWEEN rounds raises HostLossDetected at the gate; a
        # death MID-round surfaces first as a failed gloo collective —
        # diagnose() turns the wreckage into a verdict (and re-raises
        # anything that isn't a peer failure)
        loss = recovery.diagnose(err, heartbeats, telemetry=tel)
        if deadman is not None and not deadman.claim():
            threading.Event().wait()  # watchdog already recovering; it
            #                           will exec this process away
        recovery.recover(loss, ctx, ckpt_dir=args.ckpt_dir, reco=reco,
                         telemetry=tel)
        raise AssertionError("recover() returned")    # pragma: no cover
    if gen == 0:
        # the scheduled fault never fired — fail loudly, don't let a
        # fault-free run masquerade as a recovery
        print("NO-FAULT", flush=True)
        return 1
    dump(args.out, state, history)
    check_corrupt_fallback(args, trainer, state)
    tel.close()
    print("RECOVERY-OK", flush=True)
    return 0


def run_reference(args):
    ctx, _ = recovery.startup()
    assert ctx.num_processes == 1, ctx
    import jax
    trainer = build(args, args.telemetry_dir)
    state, history = trainer.run(jax.random.PRNGKey(0))
    dump(args.out, state, history)
    print("RECOVERY-OK", flush=True)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", required=True,
                    choices=["reference", "worker"])
    ap.add_argument("--out", required=True)
    ap.add_argument("--beat-dir", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--telemetry-dir", default=None)
    ap.add_argument("--chaos", default=None,
                    help="FaultSchedule.from_spec string (host/generation "
                         "filtering makes one spec safe for every rank)")
    args = ap.parse_args()
    if args.mode == "reference":
        return run_reference(args)
    return run_worker(args)


if __name__ == "__main__":
    sys.exit(main())
