"""Sharded DIALS runtime (repro.distributed.runtime +
repro.core.dials_sharded).

In-process tests cover the mesh/jaxpr utilities, the fixed ``pbroadcast``
collective (driven through ``vmap(..., axis_name=...)`` so no real mesh is
needed), and the no-collectives audit of the per-shard round body.

The multi-device contract — sharded-vs-single-device equivalence,
bitwise determinism, jaxpr cleanliness on a real 4-shard mesh — needs
more than one XLA device, which the main pytest process must not force
(see conftest). It runs ``tests/_multidevice_check.py`` in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; marked slow
(CI runs it in the dedicated ``runtime-multidevice`` job).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import collectives, runtime

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tests"))
# single source of the tiny traffic config: the sharded-vs-unfused
# equivalence claims only hold if every comparison uses the same setup
from _multidevice_check import build_trainer  # noqa: E402


# ---------------------------------------------------------------------------
# jaxpr auditing
# ---------------------------------------------------------------------------
def test_audit_detects_collectives():
    jx = jax.make_jaxpr(
        jax.vmap(lambda x: jax.lax.psum(x, "i"), axis_name="i"))(
        jnp.arange(4.0))
    assert "psum" in runtime.collectives_in_jaxpr(jx)
    with pytest.raises(AssertionError, match="psum"):
        runtime.assert_no_collectives(jx)


def test_audit_clean_program_passes():
    jx = jax.make_jaxpr(lambda x: jnp.sin(x).sum() * 2)(jnp.arange(4.0))
    assert runtime.collectives_in_jaxpr(jx) == set()
    runtime.assert_no_collectives(jx)


def test_audit_recurses_into_scan():
    def f(x):
        def body(c, t):
            return c + jax.lax.psum(t, "i"), c
        out, _ = jax.lax.scan(body, x[0], x)
        return out

    jx = jax.make_jaxpr(jax.vmap(f, axis_name="i"))(jnp.ones((4, 3)))
    assert "psum" in runtime.collectives_in_jaxpr(jx)


def test_audit_recurses_into_cond():
    def f(flag, x):
        return jax.lax.cond(flag, lambda v: jax.lax.pmax(v, "i"),
                            lambda v: v, x)

    jx = jax.make_jaxpr(
        jax.vmap(f, in_axes=(None, 0), axis_name="i"))(True, jnp.arange(4.0))
    assert runtime.collectives_in_jaxpr(jx) & {"pmax", "psum"}


def test_audit_only_halo_collectives():
    """The sharded-GS whitelist: a halo exchange passes, psum fails, and
    a program with no communication at all fails too (a 'decomposed' GS
    that never exchanges halos is not decomposed). Traced through
    shard_map — the audit's real substrate; vmap batching rules may
    rewrite ppermute away entirely."""
    from jax.sharding import PartitionSpec as P
    mesh = runtime.shard_mesh(1)
    spec = P(runtime.SHARD_AXIS)

    def trace(body):
        jx = jax.make_jaxpr(runtime.shard_map_nocheck(
            body, mesh, in_specs=(spec,), out_specs=spec))(
            jnp.arange(4.0))
        bodies = runtime.find_shard_map_jaxprs(jx)
        assert len(bodies) == 1
        return bodies[0]

    ring = trace(lambda x: collectives.halo_exchange(
        x, runtime.SHARD_AXIS, axis_size=1)[0])
    assert runtime.collectives_in_jaxpr(ring) == {"ppermute"}
    runtime.assert_only_halo_collectives(ring)

    summed = trace(lambda x: collectives.tree_psum(
        x, runtime.SHARD_AXIS)[None][0])
    with pytest.raises(AssertionError, match="psum"):
        runtime.assert_only_halo_collectives(summed)

    silent = trace(lambda x: x * 2)
    with pytest.raises(AssertionError, match="no halo exchange"):
        runtime.assert_only_halo_collectives(silent)


# ---------------------------------------------------------------------------
# mesh / placement helpers
# ---------------------------------------------------------------------------
def test_choose_shards_largest_divisor():
    assert runtime.choose_shards(4, 8) == 4
    assert runtime.choose_shards(4, 3) == 2
    assert runtime.choose_shards(25, 8) == 5
    assert runtime.choose_shards(7, 2) == 1
    assert runtime.choose_shards(16, 16) == 16


def test_shard_mesh_single_device():
    mesh = runtime.shard_mesh(1)
    assert mesh.shape[runtime.SHARD_AXIS] == 1
    with pytest.raises(ValueError, match="devices"):
        runtime.shard_mesh(len(jax.devices()) + 1)


def test_local_slice_struct():
    tree = {"a": jnp.zeros((4, 3)), "b": jnp.zeros((4,), jnp.int32)}
    sl = runtime.local_slice_struct(tree, 2)
    assert sl["a"].shape == (2, 3) and sl["b"].shape == (2,)
    with pytest.raises(ValueError, match="divisible"):
        runtime.local_slice_struct(tree, 3)


def test_shard_agent_tree_roundtrip():
    mesh = runtime.shard_mesh(1)
    tree = {"x": jnp.arange(8.0).reshape(4, 2)}
    placed = runtime.shard_agent_tree(tree, mesh)
    np.testing.assert_array_equal(np.asarray(placed["x"]),
                                  np.asarray(tree["x"]))


# ---------------------------------------------------------------------------
# pbroadcast (satellite fix): a REAL root-broadcast now
# ---------------------------------------------------------------------------
def test_pbroadcast_broadcasts_root_value():
    x = jnp.arange(8.0).reshape(4, 2)
    out = jax.vmap(lambda v: collectives.pbroadcast(v, "i", root=2),
                   axis_name="i")(x)
    np.testing.assert_array_equal(
        np.asarray(out), np.broadcast_to(np.asarray(x[2]), (4, 2)))


def test_pbroadcast_pytree_and_dtypes():
    tree = {"i": jnp.arange(4, dtype=jnp.int32),
            "f": jnp.arange(12.0).reshape(4, 3),
            "b": jnp.array([True, False, True, False])}
    out = jax.vmap(lambda v: collectives.pbroadcast(v, "i", root=1),
                   axis_name="i")(tree)
    assert out["i"].dtype == jnp.int32 and out["i"].tolist() == [1, 1, 1, 1]
    np.testing.assert_array_equal(
        np.asarray(out["f"]), np.broadcast_to(np.arange(3.0) + 3, (4, 3)))
    assert out["b"].dtype == jnp.bool_ and out["b"].tolist() == [False] * 4


# ---------------------------------------------------------------------------
# sharded round body: collective-free by construction
# ---------------------------------------------------------------------------
def _tiny_runner(n_shards=1, **kw):
    from repro.core import dials_sharded
    tr = build_trainer(**kw)
    return dials_sharded.ShardedDIALSRunner(
        tr.env_mod, tr.env_cfg, tr.policy_cfg, tr.aip_cfg, tr.ppo_cfg,
        tr.cfg, n_shards=n_shards)


def test_inner_round_body_is_collective_free():
    """The paper's runtime-stays-constant claim: between AIP refreshes the
    per-shard program (AIP train + staleness gate + F inner IALS+PPO
    steps) communicates with nobody. The audited jaxpr is EXTRACTED from
    the traced round program, not re-traced separately. With the
    region-decomposed GS active (traffic tiles the 1-block split) the
    round holds three shard_maps — collect, train, eval — of which
    exactly the train body is collective-free and the GS bodies carry
    only halo ppermutes."""
    runner = _tiny_runner(n_shards=1)
    assert runner.use_sharded_gs
    jx = runner.inner_jaxpr()
    runtime.assert_no_collectives(jx, what="per-shard round body")
    # sanity: the audit actually saw a non-trivial program
    assert {"scan", "dot_general"} <= runtime.jaxpr_primitives(jx)
    assert len(runtime.find_shard_map_jaxprs(runner.round_jaxpr())) == 3
    gs_bodies = runner.gs_jaxprs()
    assert len(gs_bodies) == 2                    # collect + eval
    for body in gs_bodies:
        runtime.assert_only_halo_collectives(body)
    runner.audit_collectives()


def test_replicated_gs_fallback_has_one_shard_map():
    """sharded_gs='off' restores the pre-decomposition program shape:
    exactly one shard_map (the train body), replicated GS around it."""
    runner = _tiny_runner(n_shards=1, sharded_gs="off")
    assert not runner.use_sharded_gs
    assert len(runtime.find_shard_map_jaxprs(runner.round_jaxpr())) == 1
    runtime.assert_no_collectives(runner.inner_jaxpr())
    assert runner.gs_jaxprs() == []
    runner.audit_collectives()


def test_split_shard_train_program_is_collective_free():
    """The async-collect driver runs the SPLIT round: a collect program
    plus a shard-train program. The shard-train half (the one whose
    shard_map body carries the freshness gate) must stay collective-free.
    The region-decomposed collect half is one shard_map whose only
    collectives are its halo ppermutes; with sharded_gs='off' it must
    not touch the mesh at all (no shard_map — it can run on a spare
    device)."""
    runner = _tiny_runner(n_shards=1)
    jx = runner.split_inner_jaxpr()
    runtime.assert_no_collectives(jx, what="shard-train program")
    assert {"scan", "dot_general"} <= runtime.jaxpr_primitives(jx)

    params = jax.eval_shape(
        lambda k: runner.ials_init(k)["params"],
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    collect_jx = jax.make_jaxpr(runner.collect)(params, key_struct)
    bodies = runtime.find_shard_map_jaxprs(collect_jx)
    assert len(bodies) == 1
    runtime.assert_only_halo_collectives(
        bodies[0], what="region-decomposed collect body")

    rep = _tiny_runner(n_shards=1, sharded_gs="off")
    rep_jx = jax.make_jaxpr(rep.collect)(params, key_struct)
    assert runtime.find_shard_map_jaxprs(rep_jx) == []
    runtime.assert_no_collectives(rep_jx, what="replicated collect")


def test_sharded_gs_collect_matches_replicated_on_one_mesh():
    """In-process cross-check of the two Algorithm-2 implementations:
    on a 1-device mesh the region-decomposed collector must emit the
    replicated collector's dataset EXACTLY (same key plumbing, same
    per-agent arithmetic, replicated random bits sliced per block)."""
    from repro.core import gs as gs_mod, gs_sharded
    from repro.marl import policy as policy_mod
    tr = build_trainer()
    info = tr.env_cfg.info()
    mesh = runtime.shard_mesh(1)
    params = jax.vmap(
        lambda k: policy_mod.policy_init(k, tr.policy_cfg))(
        jax.random.split(jax.random.PRNGKey(5), info.n_agents))
    rep = gs_mod.make_collector(tr.env_mod, tr.env_cfg, tr.policy_cfg,
                                n_envs=2, steps=12)
    shc = gs_sharded.make_sharded_collector(
        tr.env_mod, tr.env_cfg, tr.policy_cfg, n_envs=2, steps=12,
        mesh=mesh)
    key = jax.random.PRNGKey(6)
    d_rep, d_sh = rep(params, key), shc(params, key)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(jax.device_get(b))), d_rep, d_sh)


def test_kernelized_inner_body_is_collective_free():
    """With the Pallas fast paths forced ON (use_kernels='on': AIP GRU,
    policy GRU, GAE all route through pallas_call + custom_vjp), the
    per-shard body of BOTH round programs must still audit clean — the
    kernels are per-agent compute, not communication — and the vmapped
    agent-axis layout must trace."""
    from repro.core import dials, dials_sharded, influence
    from repro.envs import registry
    from repro.marl import policy as policy_mod, ppo as ppo_mod
    env_mod, env_cfg = registry.make("warehouse", side=2, horizon=16)
    info = env_cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, kind="gru",
                                 hidden=(16,), gru_hidden=8)
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="gru",
                             hidden=(16,), gru_hidden=8, epochs=2, batch=8)
    runner = dials_sharded.ShardedDIALSRunner(
        env_mod, env_cfg, pc, ac, ppo_mod.PPOConfig(epochs=1, minibatches=2),
        dials.DIALSConfig(outer_rounds=1, aip_refresh=2, collect_envs=2,
                          collect_steps=8, n_envs=2, rollout_steps=8,
                          use_kernels="on"),
        n_shards=1)
    for jx, what in ((runner.inner_jaxpr(), "kernelized round body"),
                     (runner.split_inner_jaxpr(),
                      "kernelized shard-train program")):
        runtime.assert_no_collectives(jx, what=what)
        prims = runtime.jaxpr_primitives(jx)
        assert "pallas_call" in prims, \
            f"{what} traced without the Pallas kernels: {sorted(prims)[:8]}"


def test_spare_device_helper():
    n_dev = len(jax.devices())
    assert runtime.spare_device(n_dev) is None
    if n_dev > 1:
        assert runtime.spare_device(1) == jax.devices()[1]


@pytest.mark.slow
def test_single_shard_fused_round_matches_python_loop():
    """The fused one-program round on a 1-device mesh reproduces the
    unfused python-loop path (same math, F+3 syncs -> 1)."""
    import jax.random as jr
    tr = build_trainer()
    s1, h1 = tr.run(jr.PRNGKey(0))

    tr2 = build_trainer()
    state = tr2.restore_or_init(jr.PRNGKey(0))
    s2, h2 = tr2._run_sharded(state, 1, log=None, straggler_mask=None)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), atol=1e-5),
        {"p": s1["ials"]["params"], "a": s1["aips"]},
        {"p": s2["ials"]["params"], "a": s2["aips"]})
    for r1, r2 in zip(h1, h2):
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# the multi-device contract, in a subprocess with 8 forced host devices
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_multidevice_sharded_equivalence():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests",
                                      "_multidevice_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIDEVICE-OK" in proc.stdout
