"""Multi-device contract check for the sharded DIALS runtime.

Run by ``tests/test_runtime.py`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process must keep the single real CPU device — see tests/conftest.py).
Asserts, on the smallest traffic config:

1. the driver auto-selects the sharded path (4 shards for 4 agents);
2. sharded execution is bitwise-deterministic per seed;
3. sharded ≡ single-device numerics: GS-collect-trained AIPs to 1e-6 and
   policy params / returns to optimizer-step tolerance — XLA batches the
   agent axis differently at different widths (ulp-level reassociation),
   and Adam's first-step update is ``±lr`` wherever a gradient component
   sits near zero, so ulp noise lawfully becomes O(lr) parameter noise;
   anything beyond a few·lr means a real sharding bug;
4. the per-shard round body contains no cross-shard collectives, on the
   real 4-device mesh — for BOTH the fused round and the split
   shard-train program the async-collect driver runs;
5. the async-collect contract on the real mesh: the overlapped collect
   dispatches onto the spare device (4 shards < 8 devices), round 0
   primes like the serial round, the steady state carries the documented
   one-round dataset lag, ``max_aip_staleness=0`` force-syncs every
   round and reproduces the sync sharded run, and the async run is
   deterministic per seed;
6. the Pallas fast paths (now including the single-step ``gru_cell``
   rollout dispatch) match the oracle path on the mesh, still auditing
   collective-free;
7. the region-decomposed GS (``repro.core.gs_sharded``): the sharded
   collect on the FULL 8-shard mesh emits the replicated collector's
   dataset (supplychain, 8 cells — one block per device), its program
   audits halo-only, a sharded_gs-on powergrid DIALS run matches the
   replicated-GS run (incl. under async collect, dispatched without
   the spare-device copy), and traffic 2x2 at 4 shards auto-falls back
   to the replicated GS (4 blocks cannot tile a 2-row grid).

Prints MULTIDEVICE-OK on success.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dials, influence
from repro.distributed import runtime
from repro.envs import registry
from repro.marl import policy as policy_mod, ppo as ppo_mod


def build_trainer(*, env="traffic", kind="fnn", **kw):
    env_mod, cfg = registry.make(env, horizon=16)
    info = cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, kind=kind,
                                 hidden=(16,), gru_hidden=8)
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind=kind,
                             hidden=(16,), gru_hidden=8, epochs=2, batch=16)
    ppo_cfg = ppo_mod.PPOConfig(epochs=1, minibatches=2)
    dcfg = dials.DIALSConfig(**{
        **dict(outer_rounds=2, aip_refresh=2, collect_envs=2,
               collect_steps=16, n_envs=2, rollout_steps=8,
               eval_episodes=2), **kw})
    return dials.DIALSTrainer(env_mod, cfg, pc, ac, ppo_cfg, dcfg)


def tree_close(a, b, atol, what):
    def one(x, y):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   err_msg=what)
    jax.tree.map(one, a, b)


def main():
    assert len(jax.devices()) == 8, \
        f"expected 8 forced host devices, got {jax.devices()}"

    single = build_trainer(shards=1)
    s_single, h_single = single.run(jax.random.PRNGKey(0))

    sharded = build_trainer()                 # auto path selection
    assert sharded._select_shards() == 4, sharded._select_shards()
    s_shard, h_shard = sharded.run(jax.random.PRNGKey(0))

    # (2) bitwise determinism: same seed through the same runner again
    s_again, h_again = sharded.run(jax.random.PRNGKey(0))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg="determinism"),
        {"p": s_shard["ials"]["params"], "a": s_shard["aips"]},
        {"p": s_again["ials"]["params"], "a": s_again["aips"]})
    assert [r["gs_return"] for r in h_shard] == \
        [r["gs_return"] for r in h_again]

    # (3) sharded ≡ single-device
    tree_close(s_single["aips"], s_shard["aips"], 1e-6, "AIP params")
    tree_close(s_single["ials"]["params"], s_shard["ials"]["params"],
               1e-2, "policy params (optimizer-step tolerance)")
    for r1, r2 in zip(h_single, h_shard):
        np.testing.assert_allclose(r1["aip_ce_before"], r2["aip_ce_before"],
                                   atol=1e-5, err_msg="ce_before")
        np.testing.assert_allclose(r1["aip_ce_after"], r2["aip_ce_after"],
                                   atol=1e-5, err_msg="ce_after")
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=5e-2, err_msg="gs_return")

    # (4) zero cross-shard collectives between AIP refreshes — fused
    # round AND the split shard-train program of the async driver
    jx = sharded._sharded.inner_jaxpr()
    runtime.assert_no_collectives(jx, what="per-shard round body")
    runtime.assert_no_collectives(sharded._sharded.split_inner_jaxpr(),
                                  what="shard-train program")

    # the sharded state really lived on the 4-shard mesh; traffic 2x2
    # cannot tile 4 GS blocks (2 grid rows), so sharded_gs=auto must
    # have fallen back to the replicated GS
    assert sharded._sharded.n_shards == 4
    assert not sharded._sharded.use_sharded_gs

    # (5) async-collect contract on the real mesh
    assert runtime.spare_device(4) == jax.devices()[4]
    asy = build_trainer(async_collect=True)
    s_asy, h_asy = asy.run(jax.random.PRNGKey(0))
    assert asy._sharded.n_shards == 4
    assert [r["data_round"] for r in h_asy] == [0, 0], h_asy
    assert [r["forced_sync"] for r in h_asy] == [True, False], h_asy
    # round 0 primes with the serial round-0 collect: records agree with
    # the sync sharded run's round 0
    np.testing.assert_allclose(h_asy[0]["gs_return"],
                               h_shard[0]["gs_return"], atol=1e-5,
                               err_msg="async prime round")
    np.testing.assert_allclose(h_asy[0]["aip_ce_after"],
                               h_shard[0]["aip_ce_after"], atol=1e-5,
                               err_msg="async prime ce")
    # determinism of the overlapped schedule
    _, h_asy2 = asy.run(jax.random.PRNGKey(0))
    assert [r["gs_return"] for r in h_asy] == \
        [r["gs_return"] for r in h_asy2], "async determinism"

    # staleness bound 0: force-sync every round == the sync sharded run
    b0 = build_trainer(async_collect=True, max_aip_staleness=0)
    s_b0, h_b0 = b0.run(jax.random.PRNGKey(0))
    assert all(r["forced_sync"] for r in h_b0)
    for r1, r2 in zip(h_shard, h_b0):
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=1e-5, err_msg="b0 == sync")
    tree_close(s_shard["aips"], s_b0["aips"], 1e-5,
               "AIP params (async staleness-0 vs sync)")

    # the freshness gate force-refreshes a permanent straggler in the
    # sharded body: bound 1, 2 rounds -> round 1 forces agent 0
    strag = build_trainer(max_aip_staleness=1)
    mask = np.array([0.0, 1.0, 1.0, 1.0], np.float32)
    _, h_strag = strag.run(jax.random.PRNGKey(0),
                           straggler_mask=lambda rnd: mask)
    assert [r["stale_forced"] for r in h_strag] == [0, 1], h_strag

    # (6) Pallas fast paths on the real mesh: a GRU-kind warehouse run
    # with use_kernels='on' (interpret mode on CPU — AIP GRU, policy GRU
    # and GAE all go through pallas_call + custom_vjp inside the
    # shard_map'd vmap-over-agents body) matches the oracle path, and the
    # kernelized body still audits collective-free
    kern_kw = dict(env="warehouse", kind="gru", outer_rounds=1,
                   aip_refresh=2, collect_steps=8)
    k_on = build_trainer(use_kernels="on", **kern_kw)
    s_on, h_on = k_on.run(jax.random.PRNGKey(0))
    assert k_on._sharded.n_shards == 4
    runtime.assert_no_collectives(k_on._sharded.inner_jaxpr(),
                                  what="kernelized per-shard round body")
    assert "pallas_call" in runtime.jaxpr_primitives(
        k_on._sharded.inner_jaxpr())
    k_off = build_trainer(use_kernels="off", **kern_kw)
    s_off, h_off = k_off.run(jax.random.PRNGKey(0))
    tree_close(s_on["aips"], s_off["aips"], 1e-5,
               "AIP params (kernels on vs off)")
    tree_close(s_on["ials"]["params"], s_off["ials"]["params"], 1e-4,
               "policy params (kernels on vs off)")
    np.testing.assert_allclose(h_on[0]["aip_ce_after"],
                               h_off[0]["aip_ce_after"], atol=1e-5,
                               err_msg="kernelized held-out CE")

    # (7) region-decomposed GS on the mesh
    from repro.core import gs as gs_mod, gs_sharded
    from repro.marl import policy as policy_mod

    # (7a) sharded collect ≡ replicated collect on the FULL 8-shard mesh
    # (supplychain line of 8 cells — one block per device)
    env_mod, env_cfg = registry.make("supplychain", horizon=16, n_cells=8)
    info = env_cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, kind="fnn",
                                 hidden=(16,), gru_hidden=8)
    mesh = runtime.shard_mesh(8)
    params = jax.vmap(lambda k: policy_mod.policy_init(k, pc))(
        jax.random.split(jax.random.PRNGKey(3), info.n_agents))
    rep_collect = gs_mod.make_collector(env_mod, env_cfg, pc,
                                        n_envs=2, steps=16)
    sh_collect = gs_sharded.make_sharded_collector(
        env_mod, env_cfg, pc, n_envs=2, steps=16, mesh=mesh)
    kc = jax.random.PRNGKey(4)
    d_rep = rep_collect(params, kc)
    d_sh = sh_collect(runtime.shard_agent_tree(params, mesh), kc)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(jax.device_get(b)),
            err_msg="sharded-GS collect vs replicated"), d_rep, d_sh)
    collect_jx = jax.make_jaxpr(sh_collect)(
        jax.eval_shape(lambda: params),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    bodies = runtime.find_shard_map_jaxprs(collect_jx)
    assert len(bodies) == 1
    runtime.assert_only_halo_collectives(
        bodies[0], what="8-shard collect body")

    # (7b) a sharded_gs-on DIALS run (powergrid ring: 4 buses over 4
    # shards, one block each) matches the replicated-GS run, and its
    # round programs audit: train body collective-free, GS bodies
    # halo-only
    gs_on = build_trainer(env="powergrid")
    s_gs_on, h_gs_on = gs_on.run(jax.random.PRNGKey(0))
    assert gs_on._sharded.n_shards == 4
    assert gs_on._sharded.use_sharded_gs
    gs_on._sharded.audit_collectives()
    assert len(gs_on._sharded.gs_jaxprs()) == 2     # collect + eval
    gs_off = build_trainer(env="powergrid", sharded_gs="off")
    s_gs_off, h_gs_off = gs_off.run(jax.random.PRNGKey(0))
    assert not gs_off._sharded.use_sharded_gs
    tree_close(s_gs_on["aips"], s_gs_off["aips"], 1e-6,
               "AIP params (sharded GS vs replicated GS)")
    tree_close(s_gs_on["ials"]["params"], s_gs_off["ials"]["params"],
               1e-4, "policy params (sharded GS vs replicated GS)")
    for r1, r2 in zip(h_gs_on, h_gs_off):
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=1e-5, err_msg="sharded-GS return")
        np.testing.assert_allclose(r1["aip_ce_after"], r2["aip_ce_after"],
                                   atol=1e-6, err_msg="sharded-GS CE")

    # (7c) async collect with the sharded GS: dispatched WITHOUT the
    # spare-device copy (the collect is a mesh program), one-round lag,
    # prime round agrees with the sync sharded-GS run
    gs_asy = build_trainer(env="powergrid", async_collect=True)
    _, h_gs_asy = gs_asy.run(jax.random.PRNGKey(0))
    assert gs_asy._sharded.use_sharded_gs
    assert [r["data_round"] for r in h_gs_asy] == [0, 0], h_gs_asy
    np.testing.assert_allclose(h_gs_asy[0]["gs_return"],
                               h_gs_on[0]["gs_return"], atol=1e-5,
                               err_msg="async sharded-GS prime round")

    print("MULTIDEVICE-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
