"""Multi-device contract check for the sharded DIALS runtime.

Run by ``tests/test_runtime.py`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process must keep the single real CPU device — see tests/conftest.py).
Asserts, on the smallest traffic config:

1. the driver auto-selects the sharded path (4 shards for 4 agents);
2. sharded execution is bitwise-deterministic per seed;
3. sharded ≡ single-device numerics: GS-collect-trained AIPs to 1e-6 and
   policy params / returns to optimizer-step tolerance — XLA batches the
   agent axis differently at different widths (ulp-level reassociation),
   and Adam's first-step update is ``±lr`` wherever a gradient component
   sits near zero, so ulp noise lawfully becomes O(lr) parameter noise;
   anything beyond a few·lr means a real sharding bug;
4. the per-shard round body contains no cross-shard collectives, on the
   real 4-device mesh.

Prints MULTIDEVICE-OK on success.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dials, influence
from repro.distributed import runtime
from repro.envs import registry
from repro.marl import policy as policy_mod, ppo as ppo_mod


def build_trainer(**kw):
    env_mod, cfg = registry.make("traffic", horizon=16)
    info = cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, hidden=(16,))
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="fnn",
                             hidden=(16,), epochs=2, batch=16)
    ppo_cfg = ppo_mod.PPOConfig(epochs=1, minibatches=2)
    dcfg = dials.DIALSConfig(
        outer_rounds=2, aip_refresh=2, collect_envs=2, collect_steps=16,
        n_envs=2, rollout_steps=8, eval_episodes=2, **kw)
    return dials.DIALSTrainer(env_mod, cfg, pc, ac, ppo_cfg, dcfg)


def tree_close(a, b, atol, what):
    def one(x, y):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   err_msg=what)
    jax.tree.map(one, a, b)


def main():
    assert len(jax.devices()) == 8, \
        f"expected 8 forced host devices, got {jax.devices()}"

    single = build_trainer(shards=1)
    s_single, h_single = single.run(jax.random.PRNGKey(0))

    sharded = build_trainer()                 # auto path selection
    assert sharded._select_shards() == 4, sharded._select_shards()
    s_shard, h_shard = sharded.run(jax.random.PRNGKey(0))

    # (2) bitwise determinism: same seed through the same runner again
    s_again, h_again = sharded.run(jax.random.PRNGKey(0))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg="determinism"),
        {"p": s_shard["ials"]["params"], "a": s_shard["aips"]},
        {"p": s_again["ials"]["params"], "a": s_again["aips"]})
    assert [r["gs_return"] for r in h_shard] == \
        [r["gs_return"] for r in h_again]

    # (3) sharded ≡ single-device
    tree_close(s_single["aips"], s_shard["aips"], 1e-6, "AIP params")
    tree_close(s_single["ials"]["params"], s_shard["ials"]["params"],
               1e-2, "policy params (optimizer-step tolerance)")
    for r1, r2 in zip(h_single, h_shard):
        np.testing.assert_allclose(r1["aip_ce_before"], r2["aip_ce_before"],
                                   atol=1e-5, err_msg="ce_before")
        np.testing.assert_allclose(r1["aip_ce_after"], r2["aip_ce_after"],
                                   atol=1e-5, err_msg="ce_after")
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=5e-2, err_msg="gs_return")

    # (4) zero cross-shard collectives between AIP refreshes
    jx = sharded._sharded.inner_jaxpr()
    runtime.assert_no_collectives(jx, what="per-shard round body")

    # the sharded state really lived on the 4-shard mesh
    assert sharded._sharded.n_shards == 4

    print("MULTIDEVICE-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
