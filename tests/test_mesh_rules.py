"""Sharding-rule resolution (the MaxText-style logical-axis system) over
AbstractMesh — no devices needed, so the production 16x16 and 2x16x16
meshes are exercised directly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra absent: property tests skip
    from _hypothesis_stub import given, settings, st

from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed import mesh as mesh_lib


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: (sizes, names) vs shape_tuple."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


SINGLE = _abstract_mesh((16, 16), ("data", "model"))
MULTI = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class _Leaf:
    def __init__(self, shape):
        self.shape = shape


def _axes_used(spec):
    used = []
    for s in spec:
        if s is None:
            continue
        used.extend((s,) if isinstance(s, str) else list(s))
    return used


def test_basic_rules_single_pod():
    spec = mesh_lib.resolve_spec(("embed", "mlp"), (1024, 4096), SINGLE,
                                 mesh_lib.TRAIN_RULES)
    assert spec == P(None, "model")


def test_divisibility_fallback():
    # kv_heads=8 cannot shard over model=16 -> fall back to replication
    spec = mesh_lib.resolve_spec(("cache_batch", "kv_heads"), (256, 8),
                                 SINGLE, mesh_lib.TRAIN_RULES)
    assert spec[1] is None


def test_no_mesh_axis_used_twice():
    spec = mesh_lib.resolve_spec(("heads", "kv_heads"), (64, 16), SINGLE,
                                 mesh_lib.TRAIN_RULES)
    used = _axes_used(spec)
    assert len(used) == len(set(used))


def test_multi_axis_target():
    spec = mesh_lib.resolve_spec(("batch", "seq"), (256, 4096), MULTI,
                                 mesh_lib.TRAIN_RULES)
    assert spec[0] == ("pod", "data")


def test_multi_axis_prefix_fallback():
    # batch=2 divides pod(2) but not pod*data(32): prefix ("pod",) applies
    spec = mesh_lib.resolve_spec(("batch",), (2,), MULTI,
                                 mesh_lib.TRAIN_RULES)
    assert spec[0] == "pod"


def test_fsdp_augment_uses_free_axes():
    sh = mesh_lib.logical_to_sharding(
        {"w": ("embed", "mlp")}, {"w": _Leaf((1024, 4096))}, SINGLE,
        rules=mesh_lib.TRAIN_RULES, fsdp_axes=("data",))
    spec = sh["w"].spec
    # mlp -> model; fsdp puts data on the largest free dim (embed)
    assert spec == P("data", "model")


def test_fsdp_augment_skips_when_no_free_dim():
    sh = mesh_lib.logical_to_sharding(
        {"w": ("mlp",)}, {"w": _Leaf((4096,))}, SINGLE,
        rules=mesh_lib.TRAIN_RULES, fsdp_axes=("data",))
    assert sh["w"].spec == P("model")


def test_fsdp_augment_respects_divisibility():
    sh = mesh_lib.logical_to_sharding(
        {"w": ("embed", "mlp")}, {"w": _Leaf((10, 4096))}, SINGLE,
        rules=mesh_lib.TRAIN_RULES, fsdp_axes=("data",))
    # 10 doesn't divide 16: embed stays replicated
    assert sh["w"].spec == P(None, "model")


@given(st.lists(st.sampled_from(
    ["batch", "embed", "mlp", "heads", "kv_heads", "vocab", "seq", None]),
    min_size=1, max_size=4),
    st.lists(st.sampled_from([1, 2, 8, 16, 64, 256, 1024]),
             min_size=4, max_size=4),
    st.booleans())
@settings(max_examples=60, deadline=None)
def test_resolve_spec_properties(logical, dims, multi):
    """Properties for ANY logical/shape combination:
    (1) no mesh axis appears twice, (2) every sharded dim is divisible by
    its mesh-axes product, (3) output arity matches input."""
    mesh = MULTI if multi else SINGLE
    shape = tuple(dims[:len(logical)])
    spec = mesh_lib.resolve_spec(tuple(logical), shape, mesh,
                                 mesh_lib.TRAIN_RULES)
    assert len(spec) == len(shape)
    used = _axes_used(spec)
    assert len(used) == len(set(used))
    for dim, s in zip(shape, spec):
        if s is None:
            continue
        axes = (s,) if isinstance(s, str) else s
        total = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % total == 0


def test_decode_rules_cache_seq_takes_model():
    spec = mesh_lib.resolve_spec(
        ("layers", "cache_batch", "cache_seq", "kv_heads", None),
        (22, 128, 32768, 4, 64), SINGLE, mesh_lib.DECODE_RULES)
    assert spec[2] == "model"
    assert spec[1] == "data"


def test_long_context_rules_shard_seq_over_data():
    spec = mesh_lib.resolve_spec(
        ("layers", "cache_batch", "cache_seq", "kv_heads", None),
        (22, 1, 524288, 4, 64), SINGLE, mesh_lib.LONG_CONTEXT_RULES)
    assert spec[2] == "data"
    assert spec[1] is None


def test_production_mesh_factory():
    """make_production_mesh builds the brief's meshes (needs 512 fake
    devices — subprocess so the main process keeps 1 CPU device)."""
    import subprocess
    import sys
    code = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch import mesh\n"
        "m1 = mesh.make_production_mesh()\n"
        "assert dict(m1.shape) == {'data': 16, 'model': 16}, m1.shape\n"
        "m2 = mesh.make_production_mesh(multi_pod=True)\n"
        "assert dict(m2.shape) == {'pod': 2, 'data': 16, 'model': 16}\n"
        "print('ok')\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__('os').environ,
                                          "PYTHONPATH": "src"},
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ok" in out.stdout
