"""Batched env-pool properties: per-stream key discipline, S-prefix
invariance, in-program auto-reset, and the device-resident ring.

These pin the invariants the large-batch collect path advertises:

* growing the stream count S preserves the prefix streams BITWISE
  (stream s's randomness folds in its absolute index, so it depends on
  (key, s, t) — never on the batch width),
* auto-reset happens in-program for every registered env at any width
  (episode-boundary flags, policy-state zeroing, done broadcast by
  rank),
* the donating ring buffer is a bitwise drop-in for the plain collector
  while actually reusing (donating) retired slot buffers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env_pool, gs as gs_mod, ials as ials_mod, influence
from repro.distributed import async_collect as async_mod
from repro.envs import registry
from repro.marl import policy as policy_mod


def _tiny_policy(info, kind="fnn"):
    return policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                   n_actions=info.n_actions, kind=kind,
                                   hidden=(8,), gru_hidden=8)


def _params(pc, n_agents, seed=0):
    return jax.vmap(lambda k: policy_mod.policy_init(k, pc))(
        jax.random.split(jax.random.PRNGKey(seed), n_agents))


def _collect(env_name, n_envs, steps, *, horizon=8, seed=0):
    env_mod, env_cfg = registry.make(env_name, horizon=horizon)
    info = env_cfg.info()
    pc = _tiny_policy(info)
    coll = gs_mod.make_collector(env_mod, env_cfg, pc,
                                 n_envs=n_envs, steps=steps)
    return coll(_params(pc, info.n_agents, seed),
                jax.random.PRNGKey(7)), info


# ---------------------------------------------------------------------------
# per-stream key derivation
# ---------------------------------------------------------------------------
def test_stream_keys_prefix_invariant():
    """fold_in by ABSOLUTE stream id: the S=8 chain roots are bitwise
    the first 8 of the S=1024 roots, and so are the derived init/step
    keys — the property that makes S an honest width knob."""
    key = jax.random.PRNGKey(3)
    small = env_pool.stream_keys(key, 8)
    large = env_pool.stream_keys(key, 1024)
    np.testing.assert_array_equal(np.asarray(small),
                                  np.asarray(large[:8]))
    np.testing.assert_array_equal(
        np.asarray(env_pool.init_keys(small)),
        np.asarray(env_pool.init_keys(large))[:8])
    for t in (0, 5):
        ks = env_pool.step_keys(small, t, 3)
        kl = env_pool.step_keys(large, t, 3)
        assert ks.shape == (3, 8, 2)
        np.testing.assert_array_equal(np.asarray(ks),
                                      np.asarray(kl)[:, :8])


def test_step_keys_distinct_across_t_and_purpose():
    skeys = env_pool.stream_keys(jax.random.PRNGKey(0), 4)
    k0 = np.asarray(env_pool.step_keys(skeys, 0, 3))
    k1 = np.asarray(env_pool.step_keys(skeys, 1, 3))
    flat = np.concatenate([k0.reshape(-1, 2), k1.reshape(-1, 2)])
    assert len({tuple(r) for r in flat}) == len(flat)   # all distinct
    # init keys (chain position 0) never collide with step keys (t+1)
    init = np.asarray(env_pool.init_keys(skeys)).reshape(-1, 2)
    assert not ({tuple(r) for r in init} & {tuple(r) for r in flat})


# ---------------------------------------------------------------------------
# S-prefix invariance of whole rollouts
# ---------------------------------------------------------------------------
def test_collector_stream_prefix_bitwise():
    """The S=8 GS dataset is bitwise the first 8 streams of the S=1024
    dataset: a wide population run CONTAINS every narrower run."""
    small, _ = _collect("traffic", 8, 4)
    large, _ = _collect("traffic", 1024, 4)
    for k in small:
        np.testing.assert_array_equal(
            np.asarray(small[k]), np.asarray(large[k][:, :8]),
            err_msg=f"stream prefix diverged in {k!r}")


def test_ials_init_stream_prefix_bitwise():
    """Per-(agent, stream) fold-in chains: growing E preserves every
    existing local sim bitwise (and so does slicing the agent axis)."""
    env_mod, env_cfg = registry.make("traffic", horizon=8)
    info = env_cfg.info()
    pc = _tiny_policy(info)
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="fnn",
                             hidden=(8,), epochs=1, batch=8)
    key = jax.random.PRNGKey(11)
    init4 = ials_mod.make_ials_init(env_mod, env_cfg, pc, ac, n_envs=4)
    init16 = ials_mod.make_ials_init(env_mod, env_cfg, pc, ac, n_envs=16)
    s4, s16 = init4(key), init16(key)
    for leaf4, leaf16 in zip(jax.tree.leaves(s4["locals"]),
                             jax.tree.leaves(s16["locals"])):
        np.testing.assert_array_equal(np.asarray(leaf4),
                                      np.asarray(leaf16)[:, :4])


# ---------------------------------------------------------------------------
# auto-reset properties (every registered env × stream widths)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("env_name", registry.names())
@pytest.mark.parametrize("n_envs", [1, 8, 256])
def test_auto_reset_properties(env_name, n_envs):
    """Episode boundaries are in-program and correctly recorded at any
    width: resets flag step 0 and every post-``horizon`` boundary, the
    flag is agent-invariant, and the ALSH feature's previous-action
    one-hot is zeroed exactly where an episode starts."""
    horizon, steps = 4, 10
    data, info = _collect(env_name, n_envs, steps, horizon=horizon)
    resets = np.asarray(data["resets"])           # (N, S, T)
    assert resets.shape == (info.n_agents, n_envs, steps)
    # a collect starts a fresh episode in every stream
    np.testing.assert_array_equal(resets[:, :, 0], 1.0)
    # the done flag is per-stream: broadcast identically to every agent
    np.testing.assert_array_equal(
        resets, np.broadcast_to(resets[:1], resets.shape))
    # with steps > horizon at least one in-program reset must fire
    assert resets[:, :, 1:].sum() > 0, "no auto-reset ever fired"
    # fixed-horizon envs reset on the horizon grid
    expect = np.zeros(steps)
    expect[::horizon] = 1.0
    np.testing.assert_array_equal(
        resets[0, 0], expect,
        err_msg="resets off the horizon grid for a fixed-horizon env")
    # where an episode starts, prev_a was zeroed: the one-hot tail of
    # the ALSH feature is exactly one_hot(0)
    feats = np.asarray(data["feats"])             # (N, S, T, alsh)
    tail = feats[..., info.alsh_dim - info.n_actions:]
    onehot0 = np.zeros(info.n_actions)
    onehot0[0] = 1.0
    at_reset = tail[resets == 1.0]
    np.testing.assert_array_equal(
        at_reset, np.broadcast_to(onehot0, at_reset.shape))


def test_reset_where_broadcasts_by_rank():
    done = jnp.asarray([True, False, True])
    fresh = {"a": jnp.ones((3,)), "b": jnp.ones((3, 2)),
             "c": jnp.ones((3, 2, 2))}
    cur = jax.tree.map(lambda x: x * 0.0, fresh)
    out = env_pool.reset_where(done, fresh, cur)
    for leaf in jax.tree.leaves(out):
        arr = np.asarray(leaf)
        assert (arr[0] == 1.0).all() and (arr[2] == 1.0).all()
        assert (arr[1] == 0.0).all()
    zeroed = env_pool.zero_on_done(done, fresh)
    for leaf in jax.tree.leaves(zeroed):
        arr = np.asarray(leaf)
        assert (arr[0] == 0.0).all() and (arr[1] == 1.0).all()


# ---------------------------------------------------------------------------
# the device-resident ring
# ---------------------------------------------------------------------------
def test_device_ring_bitwise_equals_plain_and_donates():
    """ring.collect is a drop-in for the plain collector: bitwise-equal
    datasets every round — and past the ring depth, retired slot
    buffers are actually DONATED (the old dataset's arrays die), which
    is the no-reallocation claim made observable."""
    env_mod, env_cfg = registry.make("traffic", horizon=8)
    info = env_cfg.info()
    pc = _tiny_policy(info)
    params = _params(pc, info.n_agents)
    coll = gs_mod.make_collector(env_mod, env_cfg, pc, n_envs=4, steps=6)
    into = gs_mod.make_collector_into(env_mod, env_cfg, pc,
                                      n_envs=4, steps=6)
    ring = async_mod.DeviceRing(coll, into)
    keys = [jax.random.PRNGKey(i) for i in range(4)]
    outs = []
    for k in keys:
        out = ring.collect(params, k)
        plain = coll(params, k)
        for name in plain:
            np.testing.assert_array_equal(np.asarray(out[name]),
                                          np.asarray(plain[name]),
                                          err_msg=f"{name!r} diverged")
        outs.append(out)
    # slots=2: by collect #3 the round-1 dataset's buffers were donated
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(outs[0]))
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(outs[1]))
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(outs[3]))


def test_device_ring_rejects_single_slot():
    with pytest.raises(ValueError):
        async_mod.DeviceRing(lambda p, k: None, lambda b, p, k: None,
                             slots=1)
