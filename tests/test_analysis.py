"""Tests for the static program-contract analyzer (repro.analysis).

Covers the walker's path/source provenance on nested programs
(scan-in-shard_map-in-pjit, pallas_call kernel bodies), pass/fail
fixtures for every contract rule, the lint rules, the live-primitive
table validation, and — slow — driver parity: the full rule set is
clean over both drivers' traced programs for a real scenario.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import contracts, lint, recompile, walker
from repro.analysis.report import Finding, format_finding
from repro.distributed import runtime


# ---------------------------------------------------------------------------
# walker: paths + provenance
# ---------------------------------------------------------------------------
def _nested_jaxpr():
    """scan inside shard_map inside pjit, with a psum in the scan body."""
    mesh = runtime.shard_mesh(1)

    def shard_body(x):
        def step(carry, v):
            carry = carry + jax.lax.psum(v, runtime.SHARD_AXIS)
            return carry, carry
        out, _ = jax.lax.scan(step, jnp.zeros(()), x)
        return x + out

    fn = jax.jit(runtime.shard_map_nocheck(
        shard_body, mesh, in_specs=(P(runtime.SHARD_AXIS),),
        out_specs=P(runtime.SHARD_AXIS)))
    return jax.make_jaxpr(fn)(jnp.ones((4,)))


def test_walker_nested_path_and_source_info():
    jaxpr = _nested_jaxpr()
    sites = walker.sites(jaxpr, ("psum",))
    assert len(sites) == 1
    s = sites[0]
    # the path names every enclosing structured primitive, outermost
    # first: pjit body -> shard_map body -> scan body
    assert any(c.startswith("pjit") for c in s.path)
    assert "shard_map" in s.path
    assert "scan" in s.path
    assert s.path.index("shard_map") < s.path.index("scan")
    # provenance points at the user line that emitted the psum
    assert s.file and s.file.endswith("test_analysis.py")
    assert s.line and s.line > 0
    assert s.fn == "step"
    assert "psum" in s.describe() and "scan" in s.describe()


def test_walker_primitives_recurse_everywhere():
    jaxpr = _nested_jaxpr()
    prims = walker.primitives(jaxpr)
    assert {"psum", "scan", "shard_map", "add"} <= prims
    # the runtime compatibility shim routes through the walker
    assert runtime.jaxpr_primitives(jaxpr) == prims


def test_walker_sees_pallas_kernel_body():
    """Regression for the pallas_call blindness: the old generic param
    scan missed kernel bodies (raw Jaxpr under the ``jaxpr`` param);
    the walker must descend into them with a ``pallas_call`` path
    component."""
    from repro.kernels.gae import kernel as k_mod
    t, b = 4, 2
    arr = jnp.ones((t, b), jnp.float32)
    fn = lambda r, v, nv, d: k_mod.gae_reverse_scan(
        r, v, nv, d, gamma=0.9, lam=0.9, interpret=True)
    jaxpr = jax.make_jaxpr(fn)(arr, arr, arr, arr)
    assert "pallas_call" in walker.primitives(jaxpr)
    inside = [s for s in walker.walk(walker.raw_jaxpr(jaxpr))
              if any("pallas_call" in c for c in s.path)]
    assert inside, "walker did not descend into the pallas kernel body"
    assert {"mul", "add"} <= {s.prim for s in inside}


def test_walker_fingerprint_detects_structural_change():
    mesh = runtime.shard_mesh(1)

    def body(x):
        return x * 2.0

    def body2(x):
        return x * 2.0 + jax.lax.psum(x, runtime.SHARD_AXIS)

    mk = lambda f: jax.make_jaxpr(runtime.shard_map_nocheck(
        f, mesh, in_specs=(P(runtime.SHARD_AXIS),),
        out_specs=P(runtime.SHARD_AXIS)))(jnp.ones((4,)))
    assert walker.fingerprint(mk(body)) == walker.fingerprint(mk(body))
    assert walker.fingerprint(mk(body)) != walker.fingerprint(mk(body2))


def test_find_shard_map_jaxprs_still_extracts_bodies():
    jaxpr = _nested_jaxpr()
    bodies = runtime.find_shard_map_jaxprs(jaxpr)
    assert len(bodies) == 1
    assert "psum" in walker.primitives(bodies[0])


# ---------------------------------------------------------------------------
# primitive tables vs the running jax
# ---------------------------------------------------------------------------
def test_collective_tables_cover_live_jax():
    live = runtime.live_collective_prims()
    assert "psum" in live and "ppermute" in live
    assert "axis_index" not in live
    runtime.validate_collective_tables()       # must not raise
    assert runtime.HALO_PRIMS < runtime.COLLECTIVE_PRIMS


# ---------------------------------------------------------------------------
# contract rules: pass/fail fixtures
# ---------------------------------------------------------------------------
def _shard_jaxpr(f, shape=(4,)):
    mesh = runtime.shard_mesh(1)
    return jax.make_jaxpr(runtime.shard_map_nocheck(
        f, mesh, in_specs=(P(runtime.SHARD_AXIS),),
        out_specs=P(runtime.SHARD_AXIS)))(jnp.ones(shape))


def _body(f, shape=(4,)):
    return runtime.find_shard_map_jaxprs(_shard_jaxpr(f, shape))[0]


def test_collective_free_rule():
    rule = contracts.CollectiveFree()
    clean = contracts.Program(name="fix/clean", roles=("train_body",),
                              jaxpr=_body(lambda x: x * 2.0))
    assert rule.check(clean) == []
    dirty = contracts.Program(
        name="fix/psum", roles=("train_body",),
        jaxpr=_body(lambda x: x + jax.lax.psum(x, runtime.SHARD_AXIS)))
    found = rule.check(dirty)
    assert len(found) == 1
    f = found[0]
    assert "psum" in f.message and f.file.endswith("test_analysis.py")
    assert f.line and f.rule == "CollectiveFree"


def test_halo_only_rule():
    rule = contracts.HaloOnly()
    halo = contracts.Program(
        name="fix/halo", roles=("gs_body",),
        jaxpr=_body(lambda x: jax.lax.ppermute(
            x, runtime.SHARD_AXIS, [(0, 0)])))
    assert rule.check(halo) == []
    psum = contracts.Program(
        name="fix/psum", roles=("gs_body",),
        jaxpr=_body(lambda x: x + jax.lax.psum(x, runtime.SHARD_AXIS)))
    found = rule.check(psum)
    assert any("non-halo" in f.message and f.line for f in found)
    silent = contracts.Program(name="fix/none", roles=("gs_body",),
                               jaxpr=_body(lambda x: x * 2.0))
    found = rule.check(silent)
    assert len(found) == 1 and "no halo exchange" in found[0].message


def test_no_host_callback_rule():
    rule = contracts.NoHostCallback()
    clean = contracts.Program(
        name="fix/clean", roles=("round",),
        jaxpr=jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones((3,))))
    assert rule.check(clean) == []

    def leaky(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((3,), jnp.float32), x)

    dirty = contracts.Program(name="fix/callback", roles=("round",),
                              jaxpr=jax.make_jaxpr(leaky)(jnp.ones((3,))))
    found = rule.check(dirty)
    assert len(found) == 1 and "host callback" in found[0].message


def test_donation_used_rule():
    rule = contracts.DonationUsed()
    aval = jax.ShapeDtypeStruct((8,), jnp.float32)

    def used(carry, x):
        return carry + x

    ok = contracts.Program(name="fix/used", roles=("donated",),
                           fn=used, args=(aval, aval),
                           donate_argnums=(0,))
    assert rule.check(ok) == []

    def unused(carry, x):
        return x * 2.0

    bad = contracts.Program(name="fix/unused", roles=("donated",),
                            fn=unused, args=(aval, aval),
                            donate_argnums=(0,))
    found = rule.check(bad)
    assert len(found) == 1
    assert "0 of 1 donated buffers" in found[0].message


def test_dtype_round_trip_rule():
    rule = contracts.DtypeRoundTrip()
    aval = jax.ShapeDtypeStruct((4,), jnp.bfloat16)
    ok = contracts.Program(name="fix/ok", roles=("dtype",),
                           fn=lambda x: x * 2, args=(aval,))
    assert rule.check(ok) == []
    upcast = contracts.Program(
        name="fix/upcast", roles=("dtype",),
        fn=lambda x: x.astype(jnp.float32) * 2, args=(aval,))
    found = rule.check(upcast)
    assert len(found) == 1 and "silent upcast" in found[0].message

    def crashes(x):
        def step(c, v):
            return c + v.astype(jnp.float32), c
        return jax.lax.scan(step, jnp.zeros((), x.dtype), x)

    broken = contracts.Program(name="fix/trace-crash", roles=("dtype",),
                               fn=crashes, args=(aval,))
    found = rule.check(broken)
    assert len(found) == 1
    assert "does not trace at reduced precision" in found[0].message


def test_scalar_sync_budget_rule():
    from repro.obs import metrics
    rule = contracts.ScalarSyncBudget()
    scalar = jnp.zeros(())
    good = contracts.Program(
        name="fix/good", roles=("round",),
        fn=lambda c: (c, {"gs_return": scalar, "ials_reward": scalar}),
        args=(jnp.ones((3,)),))
    assert rule.check(good) == []
    off_schema = contracts.Program(
        name="fix/extra-key", roles=("round",),
        fn=lambda c: (c, {"gs_return": scalar, "surprise": scalar}),
        args=(jnp.ones((3,)),))
    found = rule.check(off_schema)
    assert any("outside the typed round schema" in f.message
               for f in found)
    fat = contracts.Program(
        name="fix/vector", roles=("round",),
        fn=lambda c: (c, {"gs_return": jnp.ones((7,))}),
        args=(jnp.ones((3,)),))
    found = rule.check(fat)
    assert any("scalars only" in f.message for f in found)
    assert metrics.ROUND_KEYS  # schema itself must stay non-empty


def test_run_rules_routes_by_role():
    jaxpr = _body(lambda x: x + jax.lax.psum(x, runtime.SHARD_AXIS))
    # as a train body the psum is a violation; untagged it is ignored
    hit = contracts.run_rules(
        [contracts.Program(name="p", roles=("train_body",), jaxpr=jaxpr)])
    assert hit
    miss = contracts.run_rules(
        [contracts.Program(name="p", roles=("other",), jaxpr=jaxpr)])
    assert miss == []
    with pytest.raises(AssertionError) as e:
        contracts.raise_findings(hit)
    assert "CONTRACT-VIOLATION" in str(e.value)


# ---------------------------------------------------------------------------
# refactored runtime audits keep their contract AND gain provenance
# ---------------------------------------------------------------------------
def test_assert_no_collectives_names_the_line():
    jaxpr = _nested_jaxpr()
    with pytest.raises(AssertionError) as e:
        runtime.assert_no_collectives(jaxpr, what="fixture")
    msg = str(e.value)
    assert "must be collective-free between AIP refreshes" in msg
    assert "psum" in msg and "test_analysis.py" in msg


def test_assert_only_halo_collectives_messages():
    bad = _body(lambda x: x + jax.lax.psum(x, runtime.SHARD_AXIS))
    with pytest.raises(AssertionError,
                       match="only halo-exchange collectives"):
        runtime.assert_only_halo_collectives(bad, what="fixture")
    none = _body(lambda x: x * 2.0)
    with pytest.raises(AssertionError,
                       match="no halo exchange at all"):
        runtime.assert_only_halo_collectives(none, what="fixture")


# ---------------------------------------------------------------------------
# lint rules
# ---------------------------------------------------------------------------
def _lint(src, filename="src/repro/core/fixture.py"):
    return lint.lint_source("import jax\nimport jax.numpy as jnp\n" + src,
                            filename=filename)


def test_lint_prng_reuse():
    found = _lint("def f(key):\n"
                  "    a = jax.random.normal(key, (3,))\n"
                  "    b = jax.random.uniform(key, (3,))\n"
                  "    return a + b\n")
    assert any(f.rule == "prng-reuse" and f.line for f in found)
    clean = _lint("def f(key):\n"
                  "    k1, k2 = jax.random.split(key)\n"
                  "    return jax.random.normal(k1, (3,)) + "
                  "jax.random.uniform(k2, (3,))\n")
    assert clean == []


def test_lint_discarded_split_and_relative_fold():
    found = _lint("def f(key):\n"
                  "    k1, k2 = jax.random.split(key)\n"
                  "    return jax.random.normal(k1, (3,))\n")
    assert any(f.rule == "prng-discarded-split" for f in found)
    # underscore names opt out of the discarded-split rule
    clean = _lint("def f(key):\n"
                  "    k1, _k2 = jax.random.split(key)\n"
                  "    return jax.random.normal(k1, (3,))\n")
    assert clean == []
    found = _lint("def f(key):\n"
                  "    i = jax.lax.axis_index('shards')\n"
                  "    k = jax.random.fold_in(key, i * 4 + 2)\n"
                  "    return jax.random.normal(k, (3,))\n")
    assert any(f.rule == "prng-relative-fold" for f in found)


def test_lint_numpy_random_and_host_time():
    found = _lint("import numpy as np\n"
                  "def f(x):\n"
                  "    def inner(y):\n"
                  "        return y * np.random.rand()\n"
                  "    return inner(x)\n")
    assert any(f.rule == "numpy-random" for f in found)
    found = _lint("import time\n"
                  "def f(x):\n"
                  "    def inner(y):\n"
                  "        return y + time.time()\n"
                  "    return inner(x)\n")
    assert any(f.rule == "host-time" for f in found)


def test_lint_traced_branch_only_in_runtime_dirs():
    src = ("def f(x):\n"
           "    def inner(y):\n"
           "        if y:\n"
           "            return y\n"
           "        return -y\n"
           "    return inner(x)\n")
    hit = _lint(src, filename="src/repro/distributed/fixture.py")
    assert any(f.rule == "traced-branch" for f in hit)
    # host-side code opts out (lint_file flips this off outside
    # core/ and distributed/)
    miss = lint.lint_source("import jax\n" + src,
                            filename="src/repro/envs/fixture.py",
                            branch_rules=False)
    assert not any(f.rule == "traced-branch" for f in miss)


def test_lint_tree_is_clean():
    import os
    src_root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    findings = lint.lint_paths(lint.default_targets(src_root))
    assert findings == [], "\n".join(
        format_finding(f, github=False) for f in findings)


# ---------------------------------------------------------------------------
# recompile + report plumbing
# ---------------------------------------------------------------------------
def test_check_steady_state():
    assert recompile.check_steady_state([17, 17, 17], what="d") == []
    found = recompile.check_steady_state([17, 19, 19], what="d")
    assert found and found[0].rule == "SteadyStateCompile"
    assert "d" in found[0].message


def test_format_finding_github_annotations():
    f = Finding(tag="CONTRACT-VIOLATION", rule="CollectiveFree",
                message="psum in body\nsecond line",
                file="src/repro/core/x.py", line=12)
    plain = format_finding(f, github=False)
    assert plain.startswith("CONTRACT-VIOLATION src/repro/core/x.py:12")
    gh = format_finding(f, github=True)
    assert gh.startswith("::error file=src/repro/core/x.py,line=12,"
                         "title=CollectiveFree::")
    assert "\n" not in gh


# ---------------------------------------------------------------------------
# driver parity: the full rule set is clean over BOTH drivers' programs
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_driver_parity_contracts_clean_on_traffic():
    from repro.analysis import programs
    progs = programs.scenario_programs("traffic")
    names = {p.name for p in progs}
    assert any(n.startswith("loop/traffic/") for n in names)
    assert any("/round" in n and n.startswith("sharded/traffic@")
               for n in names)
    # every structural role the checker relies on is represented
    roles = {r for p in progs for r in p.roles}
    assert {"collect", "program", "round", "train_round", "donated",
            "train_body", "gs_body"} <= roles
    findings = contracts.run_rules(progs)
    assert findings == [], "\n".join(
        format_finding(f, github=False) for f in findings)


@pytest.mark.slow
def test_kernel_dtype_contracts_clean():
    """Regression for the two dtype-drift bugs the analyzer flagged:
    the GAE oracle used to crash tracing under bf16 (carry dtype
    desync) and the GAE kernel path silently returned f32."""
    from repro.analysis import programs
    findings = contracts.run_rules(programs.kernel_dtype_programs())
    assert findings == [], "\n".join(
        format_finding(f, github=False) for f in findings)


def test_reshard_collectives_token_classifier():
    find = contracts.ReshardCollectives._collectives_in_text
    hlo = ("%ag = f32[8,3] all-gather-start(f32[2,3] %p), dims={0}\n"
           "%cp = f32[2,3] collective-permute(f32[2,3] %x)")
    assert find(hlo) == ["all-gather", "collective-permute"]
    assert find("%r = f32[] all-reduce(f32[] %x)") == ["all-reduce"]
    # token boundaries: no spurious match inside identifiers
    assert find("my-all-reduce-like-name %all-gatherer") == []
    assert find("no collectives here") == []
    assert "ReshardCollectives" in {r.name for r in contracts.DEFAULT_RULES}


@pytest.mark.slow
def test_recovery_resume_programs_clean():
    """The PR-8 standing rule applied to the resume path: the restore /
    re-shard programs registered by ``recovery_programs`` must stay free
    of banned collectives (all-reduce, all-to-all, ...) — re-sharding a
    checkpoint onto a shrunken mesh is data movement (all-gather /
    collective-permute at most), never a reduction."""
    from repro.analysis import programs
    progs = programs.recovery_programs("traffic")
    names = {p.name for p in progs}
    assert any(n.endswith("/resume_round") for n in names)
    assert {"reshard_place", "reshard_fetch"} <= \
        {n.rsplit("/", 1)[-1] for n in names}
    roles = {r for p in progs for r in p.roles}
    assert "reshard" in roles and "round" in roles
    # and they ride along in the default registry next to the drivers
    all_names = {p.name for p in programs.all_programs(["traffic"])}
    assert any(n.startswith("recovery/traffic@") for n in all_names)
    findings = contracts.run_rules(progs)
    assert findings == [], "\n".join(
        format_finding(f, github=False) for f in findings)
