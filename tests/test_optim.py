"""Optimizer substrate: AdamW math, clipping, schedules, int8 compression
with error feedback, and the DIALS-outer (pod-local) optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra absent: property tests skip
    from _hypothesis_stub import given, settings, st


from repro.optim import adamw, clip, compress, outer, schedule


def test_adamw_matches_manual_math():
    cfg = adamw.AdamWConfig()
    # 2-D param -> decoupled weight decay applies
    params = {"w": jnp.array([[1.0, -2.0, 3.0]])}
    g = np.array([[0.1, 0.2, -0.3]])
    grads = {"w": jnp.asarray(g, jnp.float32)}
    state = adamw.init(params)
    new_master, new_state = adamw.update(grads, state, 1e-2, cfg)

    m = (1 - cfg.b1) * g
    v = (1 - cfg.b2) * g ** 2
    mhat = m / (1 - cfg.b1)
    vhat = v / (1 - cfg.b2)
    delta = mhat / (np.sqrt(vhat) + cfg.eps) \
        + cfg.weight_decay * np.array([[1.0, -2.0, 3.0]])
    expect = np.array([[1.0, -2.0, 3.0]]) - 1e-2 * delta
    np.testing.assert_allclose(new_master["w"], expect, rtol=1e-5)
    assert int(new_state["step"]) == 1


def test_adamw_no_decay_on_vectors():
    cfg = adamw.AdamWConfig()
    params = {"b": jnp.array([2.0])}          # 1-D: no decay
    grads = {"b": jnp.array([0.0])}
    master, _ = adamw.update(grads, adamw.init(params), 1e-2, cfg)
    np.testing.assert_allclose(master["b"], 2.0, atol=1e-7)


def test_adamw_bf16_params_fp32_master():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4, 4), 1e-3, jnp.bfloat16)}
    master, state = adamw.update(grads, state, 1e-3)
    assert master["w"].dtype == jnp.float32     # master stays fp32
    cast = adamw.cast_like(master, params)
    assert cast["w"].dtype == jnp.bfloat16
    for _ in range(5):
        master, state = adamw.update(grads, state, 1e-3)
    assert not np.allclose(np.asarray(state["master"]["w"]), 1.0)


def test_clip_by_global_norm():
    tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip.clip_by_global_norm(tree, 1.0)
    assert norm == pytest.approx(5.0)
    total = jnp.sqrt((clipped["a"] ** 2 + clipped["b"] ** 2).sum())
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    # under the cap: unchanged
    same, _ = clip.clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(same["a"], tree["a"])


def test_sanitize_kills_nans():
    tree = {"a": jnp.array([1.0, jnp.nan, jnp.inf])}
    out = clip.sanitize(tree)
    assert np.all(np.isfinite(np.asarray(out["a"])))


def test_schedules():
    f = schedule.warmup_cosine(1.0, warmup=10, total=100)
    assert float(f(0)) == pytest.approx(0.0, abs=1e-6)
    assert float(f(10)) == pytest.approx(1.0, rel=1e-5)
    assert float(f(100)) == pytest.approx(0.0, abs=1e-5)
    assert float(f(55)) < 1.0
    g = schedule.warmup_linear(2.0, warmup=4, total=8)
    assert float(g(4)) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# int8 compression + error feedback
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compress_roundtrip_bounded_error(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (8, 16)) * 3.0
    err0 = jnp.zeros_like(x)
    q, scale, err = compress.compress(x, err0)
    assert q.dtype == jnp.int8
    deq = compress.decompress(q, scale, x.shape)
    # per-row max error <= scale/2 (+ rounding slack)
    row_max = np.abs(np.asarray(x)).max(axis=1)
    bound = row_max / 127.0 * 0.51 + 1e-6
    assert np.all(np.abs(np.asarray(deq - x)).max(axis=1) <= bound * 1.5)
    # error feedback: err == x - deq
    np.testing.assert_allclose(err, x - deq, atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """Summing compressed values with EF tracks the true sum (the defining
    property of error feedback)."""
    key = jax.random.PRNGKey(0)
    xs = jax.random.normal(key, (50, 4, 8)) * 0.1
    err = jnp.zeros((4, 8))
    acc = jnp.zeros((4, 8))
    for i in range(50):
        q, s, err = compress.compress(xs[i], err)
        acc = acc + compress.decompress(q, s, (4, 8))
    true = xs.sum(0)
    # residual error is the final err, bounded by one quantization step
    np.testing.assert_allclose(np.asarray(acc + err), np.asarray(true),
                               atol=1e-4)


def test_tree_compress_roundtrip():
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.linspace(-1, 1, 8)}}
    err = compress.init_error(tree)
    q, s, err2 = compress.tree_compress(tree, err)
    back = compress.tree_decompress(q, s, tree)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        x, y, atol=2e-2), tree, back)


# ---------------------------------------------------------------------------
# DIALS-outer optimizer
# ---------------------------------------------------------------------------
def test_outer_step_moves_anchor_toward_local():
    cfg = outer.OuterConfig(outer_lr=1.0, momentum=0.0, nesterov=False,
                            compress_int8=False)
    params = {"w": jnp.ones((4,))}
    state = outer.init(params)
    local = {"w": jnp.full((4,), 2.0)}      # local made +1 of progress
    new_params, state2, _ = outer.outer_step(local, state, cfg)
    # delta = anchor - local = -1; anchor' = anchor - lr*delta = 2
    np.testing.assert_allclose(new_params["w"], 2.0, atol=1e-6)


def test_outer_step_momentum_accumulates():
    cfg = outer.OuterConfig(outer_lr=0.5, momentum=0.9, nesterov=True,
                            compress_int8=False)
    params = {"w": jnp.zeros((2,))}
    state = outer.init(params)
    p = params
    for step in range(3):
        local = jax.tree.map(lambda x: x - 1.0, p)   # constant descent
        p, state, _ = outer.outer_step(local, state, cfg)
    # with momentum, displacement exceeds plain 3 * lr * 1
    assert float(-p["w"][0]) > 1.5


def test_outer_step_int8_path_close_to_fp32():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (16, 16))}
    local = jax.tree.map(lambda x: x - 0.01 * jnp.sign(x), params)
    cfg_fp = outer.OuterConfig(compress_int8=False)
    cfg_q = outer.OuterConfig(compress_int8=True)
    p_fp, _, _ = outer.outer_step(local, outer.init(params), cfg_fp)
    p_q, _, err = outer.outer_step(local, outer.init(params), cfg_q)
    np.testing.assert_allclose(np.asarray(p_fp["w"]), np.asarray(p_q["w"]),
                               atol=1e-3)
    assert err is not None


def test_outer_step_cross_pod_mean_under_shard_map():
    """Multi-pod reconciliation: 1-device mesh sanity (the collective path
    compiles and equals the local path when P=1)."""
    from jax.sharding import Mesh
    import numpy as onp
    mesh = Mesh(onp.array(jax.devices()[:1]), ("pod",))
    params = {"w": jnp.ones((8,))}
    local = {"w": jnp.full((8,), 1.5)}
    cfg = outer.OuterConfig(compress_int8=True)
    state = outer.init(params)

    from functools import partial
    from jax.sharding import PartitionSpec as P

    import inspect

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:            # older jax: pre-promotion location
        from jax.experimental.shard_map import shard_map
    # the replication-check kwarg was renamed check_rep -> check_vma
    # independently of the promotion; key on the signature, not the location
    _kw = ("check_vma" if "check_vma"
           in inspect.signature(shard_map).parameters else "check_rep")
    shard_map = partial(shard_map, **{_kw: False})

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=P())
    def f(lp, anchor_vel_w):
        st = {"anchor": {"w": anchor_vel_w[0]},
              "velocity": {"w": anchor_vel_w[1]}}
        new_p, _, _ = outer.outer_step({"w": lp}, st, cfg, pod_axis="pod")
        return new_p["w"]

    got = f(local["w"], jnp.stack([state["anchor"]["w"],
                                   state["velocity"]["w"]]))
    want, _, _ = outer.outer_step(local, state, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want["w"]),
                               atol=1e-3)
