"""Executable checks of the paper's Section-4 theory on exact tabular
IALMs (Lemma 1 / Corollary 1 / Lemma 2 / Theorem 1)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # dev extra absent: property tests skip
    from _hypothesis_stub import given, settings, st


from repro.core import ialm, theory


def _uniform_policy(na):
    return lambda l: np.full((na,), 1.0 / na)


def _const_influence(nu, p=None):
    if p is None:
        p = np.full((nu,), 1.0 / nu)
    return lambda l: p


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_exact_influence_is_distribution(seed):
    rng = np.random.default_rng(seed)
    T1, T2, R, pi2, b0 = ialm.random_system(rng)
    infl = ialm.exact_influence(T1, T2, pi2, b0)
    # probe a few short histories
    for l in [(0,), (1,), (0, 0, 1), (1, 1, 0), (0, 1, 1, 0, 0)]:
        p = infl(l)
        assert p.shape == (T1.shape[1],)
        assert np.all(p >= -1e-12)
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-9)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_lemma1_same_policy_same_influence(seed):
    """Lemma 1: one joint policy induces exactly one influence — the
    filter is a deterministic function of (T1, T2, pi2, b0)."""
    rng = np.random.default_rng(seed)
    T1, T2, R, pi2, b0 = ialm.random_system(rng)
    i1 = ialm.exact_influence(T1, T2, pi2, b0)
    i2 = ialm.exact_influence(T1.copy(), T2.copy(), pi2.copy(), b0.copy())
    for l in [(0,), (0, 1, 1), (1, 0, 0, 1, 1)]:
        np.testing.assert_allclose(i1(l), i2(l), atol=1e-12)


def test_corollary1_transition_independence():
    """Corollary 1: if u is independent of the other agent's actions
    (T2 doesn't depend on a2 ⇒ x2 evolves autonomously), every pi2 gives
    the SAME influence distribution."""
    rng = np.random.default_rng(0)
    T1, T2, R, _, b0 = ialm.random_system(rng)
    # make region 2's dynamics action-independent
    T2 = np.repeat(T2[:, :, :1, :], T2.shape[2], axis=2)
    pi_a = np.array([[1.0, 0.0], [1.0, 0.0]])
    pi_b = np.array([[0.0, 1.0], [0.5, 0.5]])
    ia = ialm.exact_influence(T1, T2, pi_a, b0)
    ib = ialm.exact_influence(T1, T2, pi_b, b0)
    for l in [(0,), (1, 0, 1), (0, 1, 1, 0, 0)]:
        np.testing.assert_allclose(ia(l), ib(l), atol=1e-10)


@given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.3))
@settings(max_examples=15, deadline=None)
def test_lemma2_bound_holds(seed, eps):
    """|Q_M1 - Q_M2| <= R̄ (H-t)(H-t+1)/2 · ξ for perturbed influences."""
    rng = np.random.default_rng(seed)
    T1, _, R, _, _ = ialm.random_system(rng)
    nu = T1.shape[1]
    p1 = np.full((nu,), 1.0 / nu)
    p2 = p1.copy()
    p2[0] = min(1.0, p1[0] + eps)
    p2 = p2 / p2.sum()
    cert = theory.lemma2_certificate(
        T1, R, horizon=4, influence1=_const_influence(nu, p1),
        influence2=_const_influence(nu, p2),
        policy=_uniform_policy(T1.shape[2]))
    assert cert["holds"], cert
    assert cert["lhs"] <= cert["bound"] + 1e-9


def test_lemma2_bound_tightness_zero_perturbation():
    rng = np.random.default_rng(7)
    T1, _, R, _, _ = ialm.random_system(rng)
    nu = T1.shape[1]
    cert = theory.lemma2_certificate(
        T1, R, horizon=4, influence1=_const_influence(nu),
        influence2=_const_influence(nu), policy=_uniform_policy(T1.shape[2]))
    assert cert["xi"] == pytest.approx(0.0, abs=1e-12)
    assert cert["lhs"] == pytest.approx(0.0, abs=1e-12)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_theorem1_small_perturbation_same_optimal_policy(seed):
    """Theorem 1's conclusion: when the action gap dominates 2Δ, the
    optimal policies of the two IALMs coincide on every history where the
    gap condition holds."""
    rng = np.random.default_rng(seed)
    T1, _, R, _, _ = ialm.random_system(rng)
    nu = T1.shape[1]
    p1 = np.full((nu,), 1.0 / nu)
    p2 = p1 + np.linspace(-1e-4, 1e-4, nu)
    p2 = np.abs(p2) / np.abs(p2).sum()
    cert = theory.theorem1_certificate(
        T1, R, horizon=4, influence1=_const_influence(nu, p1),
        influence2=_const_influence(nu, p2))
    # Theorem 1: gap > 2Δ ⇒ shared optimal policy
    if cert["condition_met"]:
        assert cert["same_optimal"], cert
