"""NN substrate consistency: attention (chunked==full, decode==prefill,
GQA, RoPE), SSD (chunked==recurrent), MoE invariants, layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as attn
from repro.nn import layers, moe as moe_mod, ssm as ssm_mod


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def test_attend_chunked_equals_full():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, t, h, d = 2, 256, 4, 32
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, d), jnp.float32)
    full = attn.attend(q, k, v, causal=True)
    chunked = attn.attend_chunked(q, k, v, causal=True, block_k=64)
    np.testing.assert_allclose(chunked, full, atol=2e-5, rtol=2e-5)


def test_gqa_equals_repeated_mha():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, t, h, hkv, d = 1, 64, 8, 2, 16
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, hkv, d), jnp.float32)
    gqa = attn.attend(q, k, v)
    k_rep = jnp.repeat(k, h // hkv, axis=2)
    v_rep = jnp.repeat(v, h // hkv, axis=2)
    mha = attn.attend(q, k_rep, v_rep)
    np.testing.assert_allclose(gqa, mha, atol=1e-6)


def test_rope_preserves_norm_and_relative_positions():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 8, 2, 64), jnp.float32)
    pos = jnp.arange(8)
    y = attn.apply_rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 64))
    def dot_at(p):
        rq = attn.apply_rope(q, jnp.array([p]), theta=10_000.0)
        rv = attn.apply_rope(v, jnp.array([p + 5]), theta=10_000.0)
        return float(jnp.sum(rq * rv))
    assert dot_at(0) == pytest.approx(dot_at(17), rel=1e-4)


@pytest.mark.parametrize("kv_heads,window,softcap", [
    (4, None, None), (2, None, None), (4, 16, None), (4, None, 30.0),
])
def test_decode_matches_prefill(kv_heads, window, softcap):
    """Step-by-step KV-cache decode must reproduce full-sequence attention
    — the core serving-correctness invariant."""
    cfg = attn.AttentionConfig(d_model=64, num_heads=4,
                               num_kv_heads=kv_heads,
                               sliding_window=window, attn_softcap=softcap,
                               dtype=jnp.float32)
    key = jax.random.PRNGKey(5)
    params = attn.attention_init(key, cfg)
    b, t = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(6), (b, t, 64), jnp.float32)
    full = attn.self_attention(params, x, cfg,
                               positions=jnp.arange(t))
    cache = attn.init_kv_cache(cfg, b, window or t)
    outs = []
    for i in range(t):
        o, cache = attn.decode_self_attention(
            params, x[:, i:i + 1], cache, jnp.asarray(i, jnp.int32), cfg)
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(stepped, full, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# SSD / Mamba2
# ---------------------------------------------------------------------------
def test_ssd_chunked_equals_recurrent():
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    b, t, h, p, n = 2, 32, 2, 8, 16
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))) * 0.2
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, t, n), jnp.float32)
    cc = jax.random.normal(ks[4], (b, t, n), jnp.float32)
    y_chunk, final = ssm_mod.ssd_chunked(x, dt, a, bb, cc, chunk=8)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for i in range(t):
        y, state = ssm_mod.ssd_recurrent_step(
            state, x[:, i], dt[:, i], a, bb[:, i], cc[:, i])
        ys.append(y[:, None])
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_rec, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(final, state, atol=1e-4, rtol=1e-4)


def test_ssm_layer_decode_matches_prefill():
    cfg = ssm_mod.SSMConfig(d_model=32, state=16, head_dim=8, expand=2,
                            chunk=8, dtype=jnp.float32)
    params = ssm_mod.ssm_init(jax.random.PRNGKey(8), cfg)
    b, t = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(9), (b, t, 32), jnp.float32)
    full = ssm_mod.ssm_layer(params, x, cfg)
    cache = ssm_mod.init_ssm_cache(cfg, b, dtype=jnp.float32)
    outs = []
    for i in range(t):
        y, cache = ssm_mod.ssm_decode_step(params, x[:, i:i + 1], cache, cfg)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(stepped, full, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def _moe_cfg(**kw):
    d = dict(d_model=16, d_ff=32, num_experts=4, top_k=2,
             capacity_factor=2.0, dtype=jnp.float32)
    d.update(kw)
    return moe_mod.MoEConfig(**d)


def test_moe_output_shape_and_aux():
    cfg = _moe_cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    y, aux = moe_mod.moe_layer(params, x, cfg)
    assert y.shape == x.shape
    assert float(aux["load_balance"]) >= 1.0 - 1e-5   # >= 1 by Cauchy-Schwarz
    assert float(aux["z_loss"]) >= 0.0
    assert not jnp.any(jnp.isnan(y))


def test_moe_capacity_drops_tokens():
    """With capacity 1 token/expert, most tokens are dropped and the layer
    output for them is 0 (residual carries them)."""
    cfg = _moe_cfg(capacity_factor=0.05, top_k=1)
    params = moe_mod.moe_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 16), jnp.float32)
    y, _ = moe_mod.moe_layer(params, x, cfg)
    # capacity rounds to >= 8/expert: 4*8 = 32 kept, >= 32 of 64 dropped
    zero_rows = np.sum(np.all(np.abs(np.asarray(y[0])) < 1e-9, axis=-1))
    assert zero_rows >= 32


def test_moe_router_prob_simplex():
    cfg = _moe_cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 16), jnp.float32)
    probs, _ = moe_mod.router_probs(params, x, cfg)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------
def test_rmsnorm_unit_scale():
    p = layers.rmsnorm_init(8)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8)) * 10
    y = layers.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_softcap_bounds():
    x = jnp.linspace(-100, 100, 64)
    y = layers.softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(layers.softcap(x, None), x)


def test_embedding_tied_logits():
    p = layers.embedding_init(jax.random.PRNGKey(0), vocab=11, dim=8)
    ids = jnp.array([[0, 3, 10]])
    e = layers.embedding_lookup(p, ids)
    assert e.shape == (1, 3, 8)
    logits = layers.embedding_logits(p, e)
    assert logits.shape == (1, 3, 11)


def test_moe_gather_dispatch_equals_dense():
    """The scatter/gather MoE (§Perf optimization) must be numerically
    identical to the one-hot einsum form."""
    import dataclasses
    for top_k, capf in ((2, 2.0), (1, 1.25), (4, 4.0)):
        cfg_d = _moe_cfg(top_k=top_k, capacity_factor=capf)
        cfg_g = dataclasses.replace(cfg_d, dispatch="gather")
        params = moe_mod.moe_init(jax.random.PRNGKey(6), cfg_d)
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 16),
                              jnp.float32)
        yd, auxd = moe_mod.moe_layer(params, x, cfg_d)
        yg, auxg = moe_mod.moe_layer(params, x, cfg_g)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(float(auxd["load_balance"]),
                                   float(auxg["load_balance"]), rtol=1e-6)


def test_moe_gather_dispatch_drops_same_tokens():
    import dataclasses
    cfg_d = _moe_cfg(capacity_factor=0.05, top_k=1)
    cfg_g = dataclasses.replace(cfg_d, dispatch="gather")
    params = moe_mod.moe_init(jax.random.PRNGKey(8), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 64, 16), jnp.float32)
    yd, _ = moe_mod.moe_layer(params, x, cfg_d)
    yg, _ = moe_mod.moe_layer(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                               atol=2e-5, rtol=2e-5)


def test_moe_gather_sharded_equals_dense_when_ample():
    """With ample capacity (no drops) group-local routing must reproduce
    the dense layer exactly (positions differ, outputs do not)."""
    import dataclasses
    cfg_d = _moe_cfg(top_k=2, capacity_factor=8.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(10), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(11), (4, 16, 16), jnp.float32)
    yd, _ = moe_mod.moe_layer(params, x, cfg_d)
    for shards in (1, 4):
        cfg_g = dataclasses.replace(cfg_d, dispatch="gather",
                                    token_shards=shards)
        yg, _ = moe_mod.moe_layer(params, x, cfg_g)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"shards={shards}")


def test_decode_sharded_softmax_matches_attend():
    """The distributed-softmax decode path (identity constraint on 1
    device) must equal the plain attend() decode path."""
    for window, softcap in ((None, None), (16, None), (None, 30.0)):
        cfg = attn.AttentionConfig(d_model=64, num_heads=4, num_kv_heads=2,
                                   sliding_window=window,
                                   attn_softcap=softcap, dtype=jnp.float32)
        params = attn.attention_init(jax.random.PRNGKey(12), cfg)
        b, t = 2, 24
        x = jax.random.normal(jax.random.PRNGKey(13), (b, t, 64),
                              jnp.float32)
        c1 = attn.init_kv_cache(cfg, b, window or t)
        c2 = attn.init_kv_cache(cfg, b, window or t)
        for i in range(t):
            idx = jnp.asarray(i, jnp.int32)
            o1, c1 = attn.decode_self_attention(params, x[:, i:i+1], c1,
                                                idx, cfg)
            o2, c2 = attn.decode_self_attention(
                params, x[:, i:i+1], c2, idx, cfg,
                logits_constraint=lambda z: z)
            np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                       atol=3e-5, rtol=3e-5)
