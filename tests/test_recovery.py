"""Post-loss re-bootstrap (``repro.distributed.recovery``).

Unit half: the supervisor's pure pieces — survivor re-ranking with
coordinator failover (``shrink_config``), bounded-retry bootstrap with
exponential backoff, the env/exec contract of ``reexec``, the
``raising_gate`` adapter, and the full ``recover`` flow against test
doubles.

E2E half (real subprocess groups, the ISSUE's acceptance scenario): a
2-process ``jax.distributed`` group in which a chaos schedule SIGKILLs
one rank mid-checkpoint — (A) the non-primary dies after preparing its
slice, so rank 0 commits and recovers; (B) rank 0 dies between prepare
and commit, so the survivor *finalizes the pending commit* (takeover)
before recovering. Either way the survivor re-execs as a solo group,
resumes from the committed distributed checkpoint, and its final params
must match an uninterrupted single-process run at the PR-2/PR-6
tolerances (AIP 1e-6, policy params 1e-2). The merged telemetry must
tell the whole story (``tools.telemetry_report --check
--expect-recovery``).
"""
import json
import os
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.distributed import bootstrap, recovery

CHECK = os.path.join(os.path.dirname(__file__), "_recovery_check.py")


class _Rec:
    def __init__(self):
        self.events = []

    def emit(self, event, **fields):
        self.events.append({"event": event, **fields})

    def close(self):
        pass


# ---------------------------------------------------------------------------
# shrink_config
# ---------------------------------------------------------------------------
def _cfg(n=2, pid=0, port=5000):
    return bootstrap.BootstrapConfig(coordinator=f"127.0.0.1:{port}",
                                     num_processes=n, process_id=pid)


def test_shrink_to_solo_returns_none():
    assert recovery.shrink_config(_cfg(2, 0), dead=[1],
                                  new_generation=1) is None


def test_shrink_reranks_survivors_with_coordinator_failover():
    # 3-process group loses its coordinator (rank 0): survivors 1, 2
    # re-rank to 0, 1 and the new coordinator port avoids the old one
    new = recovery.shrink_config(_cfg(3, 1), dead=[0], new_generation=1)
    assert new.num_processes == 2 and new.process_id == 0
    assert new.coordinator == "127.0.0.1:5017"     # 5000 + 1 * 17
    new2 = recovery.shrink_config(_cfg(3, 2), dead=[0], new_generation=2,
                                  port_stride=10)
    assert new2.process_id == 1
    assert new2.coordinator == "127.0.0.1:5020"    # 5000 + 2 * 10


def test_shrink_rejects_dead_self():
    with pytest.raises(ValueError, match="among the dead"):
        recovery.shrink_config(_cfg(2, 0), dead=[0], new_generation=1)


# ---------------------------------------------------------------------------
# bootstrap_with_retry
# ---------------------------------------------------------------------------
def test_bootstrap_retry_backs_off_then_succeeds():
    calls, slept = [], []
    sentinel = object()

    def flaky(cfg, init_timeout_s=None, peer_death_grace_s=None):
        calls.append((init_timeout_s, peer_death_grace_s))
        if len(calls) < 3:
            raise RuntimeError("coordinator not up yet")
        return sentinel

    rec = _Rec()
    ctx, attempts = recovery.bootstrap_with_retry(
        _cfg(), reco=recovery.RecoveryConfig(init_timeout_s=7.0,
                                             peer_death_grace_s=300.0),
        telemetry=rec, sleep=slept.append, _bootstrap=flaky)
    assert ctx is sentinel and attempts == 3
    assert calls == [(7.0, 300.0)] * 3
    assert slept == [0.5, 1.0]               # backoff_s * 2**attempt
    assert [e["event"] for e in rec.events] == ["bootstrap_retry"] * 2


def test_bootstrap_retry_exhaustion_reraises():
    slept = []

    def never(cfg, init_timeout_s=None, peer_death_grace_s=None):
        raise OSError("bind failed")

    with pytest.raises(OSError, match="bind failed"):
        recovery.bootstrap_with_retry(
            _cfg(), reco=recovery.RecoveryConfig(retries=2),
            sleep=slept.append, _bootstrap=never)
    assert slept == [0.5, 1.0]               # no sleep after the last try


# ---------------------------------------------------------------------------
# reexec / raising_gate / generation
# ---------------------------------------------------------------------------
def test_reexec_env_contract():
    env = {"DIALS_COORDINATOR": "127.0.0.1:5000",
           "DIALS_NUM_PROCESSES": "2", "DIALS_PROCESS_ID": "1",
           "OTHER": "kept"}
    execs = []
    recovery.reexec(None, 1, environ=env, argv=["tests/x.py", "--f"],
                    execv=lambda p, a: execs.append((p, a)))
    # solo resume: the group declaration is cleared, generation stamped
    assert "DIALS_COORDINATOR" not in env
    assert "DIALS_NUM_PROCESSES" not in env
    assert "DIALS_PROCESS_ID" not in env
    assert env["DIALS_RECOVERY_GENERATION"] == "1" and env["OTHER"] == "kept"
    assert execs == [(sys.executable,
                      [sys.executable, "tests/x.py", "--f"])]

    env2 = {"DIALS_PROCESS_ID": "2"}
    recovery.reexec(_cfg(2, 1, port=5017), 1, environ=env2, argv=["x"],
                    execv=lambda p, a: None)
    assert env2["DIALS_COORDINATOR"] == "127.0.0.1:5017"
    assert env2["DIALS_NUM_PROCESSES"] == "2"
    assert env2["DIALS_PROCESS_ID"] == "1"   # re-ranked, not the old id


def test_raising_gate_converts_death_verdicts():
    class Mon:
        def __init__(self, dead):
            self._dead = dead

        def gate(self, rnd):
            return self._dead

    assert recovery.raising_gate(Mon(()))(3) == ()
    with pytest.raises(recovery.HostLossDetected) as ei:
        recovery.raising_gate(Mon((1, 0)))(4)
    assert ei.value.round == 4 and ei.value.dead == (0, 1)


def test_grace_kwargs_scale_missing_heartbeats():
    kw = bootstrap.grace_kwargs(600.0)
    assert kw["service_max_missing_heartbeats"] == 60     # 600 s / 10 s
    assert kw["client_max_missing_heartbeats"] == 60
    assert kw["service_heartbeat_interval_seconds"] == 10
    # sub-interval grace still keeps a sane floor of 2 missed beats
    assert bootstrap.grace_kwargs(1.0)["service_max_missing_heartbeats"] == 2
    # non-multiples round UP — grace is a lower bound
    assert bootstrap.grace_kwargs(25.0)["client_max_missing_heartbeats"] == 3


def test_is_peer_failure_marker_matching():
    assert recovery.is_peer_failure(RuntimeError(
        "FAILED_PRECONDITION: Buffer Definition Event: Gloo collective "
        "permute failed: Read error [127.0.0.1]:10157: "
        "Connection reset by peer"))
    assert recovery.is_peer_failure(RuntimeError(
        "Task /job:jax_worker/replica:0/task:1 heartbeat timeout"))
    assert not recovery.is_peer_failure(ValueError("shape mismatch"))
    assert not recovery.is_peer_failure(ZeroDivisionError("div by zero"))


class _StubGate:
    """Stands in for raising_gate's closure: scripted verdict per call."""

    def __init__(self, dead, last_round=4):
        self.round = last_round
        self.monitor = object()
        self.calls = []
        self._dead = dead

    def __call__(self, rnd):
        self.calls.append(rnd)
        if self._dead:
            raise recovery.HostLossDetected(rnd, self._dead)
        return ()


def test_diagnose_passes_through_host_loss():
    loss = recovery.HostLossDetected(3, (1,))
    assert recovery.diagnose(loss, None) is loss


def test_diagnose_collective_wreckage_asks_the_monitor():
    gate, rec = _StubGate(dead=(1,)), _Rec()
    err = RuntimeError("Gloo collective permute failed: "
                       "Connection reset by peer")
    loss = recovery.diagnose(err, gate, telemetry=rec)
    # the verdict round is the one the dead peer can never beat
    assert gate.calls == [5] and loss.round == 5 and loss.dead == (1,)
    assert [e["event"] for e in rec.events] == ["collective_failure"]
    assert rec.events[0]["round"] == 4


def test_diagnose_reraises_program_errors_and_live_peers():
    # not a peer failure: never consults the monitor
    gate = _StubGate(dead=(1,))
    with pytest.raises(ValueError, match="shape"):
        recovery.diagnose(ValueError("shape mismatch"), gate)
    assert gate.calls == []
    # peer failure but everyone beats: the original error stays fatal
    gate2 = _StubGate(dead=())
    err = RuntimeError("connection reset by peer")
    with pytest.raises(RuntimeError, match="connection reset"):
        recovery.diagnose(err, gate2)
    assert gate2.calls == [5]
    # no gate at all (solo run): nothing to diagnose
    with pytest.raises(RuntimeError, match="connection reset"):
        recovery.diagnose(RuntimeError("connection reset by peer"), None)


def test_raising_gate_tracks_rounds_for_post_mortem():
    class Mon:
        def gate(self, rnd):
            return ()

    mon = Mon()
    gate = recovery.raising_gate(mon)
    assert gate.round == 0 and gate.monitor is mon
    gate(3)
    gate(7)
    assert gate.round == 7


def _touch(path, age_s=0.0):
    with open(path, "w") as f:
        f.write("x")
    if age_s:
        t = time.time() - age_s
        os.utime(path, (t, t))


def test_deadman_silent_peer_detection(tmp_path):
    d = recovery.Deadman(str(tmp_path), host=0, n_hosts=3,
                         on_loss=lambda loss: None, silence_s=5.0)
    d._born = time.time() - 120.0        # watchdog has been up a while
    # peer 1 pulsed long ago -> silent; peer 2 never pulsed -> still
    # bootstrapping, which is the init timeout's failure mode, not ours
    _touch(str(tmp_path / "live-1"), age_s=60.0)
    assert d.silent_peers() == (1,)
    # a fresh pulse clears the verdict
    _touch(str(tmp_path / "live-1"))
    assert d.silent_peers() == ()


def test_deadman_ignores_previous_incarnation_pulses(tmp_path):
    # the beat dir survives execv and re-ranked ids alias old ones: a
    # pulse file older than this watchdog's birth is not evidence
    d = recovery.Deadman(str(tmp_path), host=0, n_hosts=2,
                         on_loss=lambda loss: None, silence_s=0.1)
    _touch(str(tmp_path / "live-1"), age_s=60.0)
    assert d.silent_peers() == ()


def test_deadman_recovers_from_watch_thread(tmp_path):
    fired, rec = [], _Rec()
    d = recovery.Deadman(str(tmp_path), host=0, n_hosts=2,
                         on_loss=fired.append, current_round=lambda: 7,
                         interval_s=0.02, silence_s=0.2, telemetry=rec)
    _touch(str(tmp_path / "live-1"))     # peer pulses once, then dies
    d.start()
    deadline = time.time() + 10.0
    while not fired and time.time() < deadline:
        time.sleep(0.02)
    d.stop()
    assert fired and fired[0].dead == (1,) and fired[0].round == 7
    # our own pulse was being published all along
    assert os.path.exists(tmp_path / "live-0")
    ev = [e for e in rec.events if e["event"] == "host_death"]
    assert ev and ev[0]["dead_hosts"] == [1] \
        and ev[0]["detector"] == "deadman"
    # the watchdog claimed the latch: a racing main-thread path loses
    assert not d.claim()


def test_deadman_claim_is_exclusive(tmp_path):
    d = recovery.Deadman(str(tmp_path), host=0, n_hosts=1,
                         on_loss=lambda loss: None)
    assert d.claim()
    assert not d.claim()


def test_generation_reads_env():
    assert recovery.generation({}) == 0
    assert recovery.generation({"DIALS_RECOVERY_GENERATION": ""}) == 0
    assert recovery.generation({"DIALS_RECOVERY_GENERATION": "2"}) == 2


def test_recover_flow_with_doubles():
    env = {"DIALS_RECOVERY_GENERATION": "0", "DIALS_PROCESS_ID": "0"}
    rec, execs = _Rec(), []
    ctx = bootstrap.DistContext(process_id=0, num_processes=2,
                                coordinator="127.0.0.1:5000",
                                initialized=False)
    recovery.recover(
        recovery.HostLossDetected(2, (1,)), ctx, cfg=_cfg(2, 0),
        environ=env, telemetry=rec,
        execv=lambda p, a: execs.append((p, a)))
    kinds = [e["event"] for e in rec.events]
    assert kinds == ["recovery_begin", "recovery_exec"]
    assert rec.events[0]["generation"] == 1 and rec.events[0]["dead"] == [1]
    assert rec.events[1]["num_processes"] == 1   # 2 -> 1: solo resume
    assert env["DIALS_RECOVERY_GENERATION"] == "1"
    assert "DIALS_PROCESS_ID" not in env
    assert len(execs) == 1


# ---------------------------------------------------------------------------
# E2E: SIGKILL one rank of a real 2-process group, survive, resume
# ---------------------------------------------------------------------------
def _telemetry_dir(tmp_path, name):
    base = os.environ.get("DIALS_TELEMETRY_DIR") or str(tmp_path)
    path = os.path.join(base, name)
    os.makedirs(path, exist_ok=True)
    return path


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _env(*, group=None, rank=0):
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src",
           "JAX_PLATFORMS": "cpu"}
    env.pop("DIALS_RECOVERY_GENERATION", None)
    env.pop("DIALS_COORDINATOR_EXTERNAL", None)
    if group is not None:
        # external coordinator: the service must not die with rank 0 —
        # a worker-hosted service collapses every survivor's
        # coordination channel the instant the host rank dies
        env.update({"DIALS_COORDINATOR": f"127.0.0.1:{group}",
                    "DIALS_COORDINATOR_EXTERNAL": "1",
                    "DIALS_NUM_PROCESSES": "2",
                    "DIALS_PROCESS_ID": str(rank)})
    return env


def _wait(proc, timeout=1500):
    out, _ = proc.communicate(timeout=timeout)
    return proc.returncode, out


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """The uninterrupted 1-process 4-shard run both scenarios compare
    against (computed once)."""
    out = str(tmp_path_factory.mktemp("recovery-ref") / "ref.json")
    rc, log = _wait(subprocess.Popen(
        [sys.executable, CHECK, "--mode", "reference", "--out", out],
        cwd="/root/repo", env=_env(), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True))
    assert rc == 0 and "RECOVERY-OK" in log, log[-3000:]
    with open(out) as f:
        return json.load(f)


def _launch_group(tmp_path, *, out, tel_dir, spec):
    port = _free_port()
    ready = str(tmp_path / "coordinator.ready")
    coordinator = subprocess.Popen(
        [sys.executable, "-m", "repro.distributed.coordinator",
         "--bind", f"127.0.0.1:{port}", "--num-processes", "2",
         "--ready-file", ready, "--timeout-s", "1500"],
        cwd="/root/repo", env=_env(), stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)
    for _ in range(600):                      # wait for the listener
        if os.path.exists(ready) or coordinator.poll() is not None:
            break
        time.sleep(0.05)
    assert os.path.exists(ready), "external coordinator never came up"
    args = [sys.executable, CHECK, "--mode", "worker", "--out", out,
            "--beat-dir", str(tmp_path / "beats"),
            "--ckpt-dir", str(tmp_path / "ck"),
            "--telemetry-dir", tel_dir, "--chaos", spec]
    workers = [subprocess.Popen(args, cwd="/root/repo",
                                env=_env(group=port, rank=rank),
                                stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)
               for rank in (0, 1)]
    return workers, coordinator


def _assert_recovery(results, *, dead_rank, ref, out, tel_dir,
                     resume_rounds):
    survivor = 1 - dead_rank
    rc_dead, log_dead = results[dead_rank]
    rc_live, log_live = results[survivor]
    # the doomed rank really died by SIGKILL mid-write, no cleanup
    assert rc_dead == -9, f"rc={rc_dead}\n{log_dead[-3000:]}"
    # the survivor's Popen handle followed it through os.execv (same
    # pid): rc/stdout are the RE-EXECUTED generation-1 run's
    assert rc_live == 0 and "RECOVERY-OK" in log_live, log_live[-5000:]
    assert "NO-FAULT" not in log_live

    with open(out) as f:
        got = json.load(f)
    # resumed exactly from the committed step, on the solo 4-shard mesh
    assert [r["round"] for r in got["history"]] == resume_rounds, \
        got["history"]
    assert all(r["n_shards"] == 4 for r in got["history"])
    # final params match the uninterrupted run: AIPs to 1e-6, policy
    # params to optimizer-step tolerance (PR-2/PR-6 contract)
    for a, b in zip(ref["aips"], got["aips"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, err_msg="AIP params")
    for a, b in zip(ref["params"], got["params"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-2, err_msg="policy params")
    # the merged event log tells the whole story, in causal order —
    # the same gate CI runs (--check --expect-recovery)
    from tools import telemetry_report
    events = telemetry_report.load_events(tel_dir)
    assert telemetry_report.check(events) == [], \
        telemetry_report.check(events)
    assert telemetry_report.check_recovery(events) == [], \
        telemetry_report.check_recovery(events)
    injected = [e for e in events if e.get("event") == "chaos_inject"]
    assert any(e["kind"] == "writer_crash" and e.get("host") == dead_rank
               for e in injected), injected
    death = [e for e in events if e.get("event") == "host_death"]
    assert death and death[0]["dead_hosts"] == [dead_rank], death
    reboot = [e for e in events if e.get("event") == "rebootstrap"]
    assert reboot and reboot[0]["generation"] == 1 \
        and reboot[0]["num_processes"] == 1, reboot
    return events


@pytest.mark.timeout(2400)
def test_nonprimary_death_recovers_from_rank0_commit(tmp_path, reference):
    """Scenario A: rank 1's writer SIGKILLs right after preparing its
    step-2 slice (a heartbeat_delay parks its main thread so it never
    beats round 2 and dies outside any collective). Rank 0 commits step
    2, times out the gate, and re-execs solo — resuming at round 2."""
    out = str(tmp_path / "got.json")
    tel_dir = _telemetry_dir(tmp_path, "recovery-kill1")
    spec = ("crash@2:host=1:phase=prepared,"
            "delay@2:host=1:delay_s=30")
    workers, coordinator = _launch_group(tmp_path, out=out,
                                         tel_dir=tel_dir, spec=spec)
    try:
        results = [_wait(p) for p in workers]
    finally:
        coordinator.terminate()
        try:
            coordinator.wait(timeout=30)
        except subprocess.TimeoutExpired:
            coordinator.kill()
            coordinator.wait()
    _assert_recovery(results, dead_rank=1, ref=reference, out=out,
                     tel_dir=tel_dir, resume_rounds=[2, 3, 4])


@pytest.mark.timeout(2400)
def test_primary_death_finalized_by_survivor_takeover(tmp_path, reference):
    """Scenario B: rank 0 dies at ``pre_commit`` of step 3 — after every
    slice verified, before COMMIT. The survivor's recover() finalizes
    the pending commit (takeover), so the solo resume starts at round 3,
    losing NO completed round to the primary's death."""
    out = str(tmp_path / "got.json")
    tel_dir = _telemetry_dir(tmp_path, "recovery-commit0")
    spec = ("crash@3:host=0:phase=pre_commit,"
            "delay@3:host=0:delay_s=30")
    workers, coordinator = _launch_group(tmp_path, out=out,
                                         tel_dir=tel_dir, spec=spec)
    try:
        results = [_wait(p) for p in workers]
    finally:
        coordinator.terminate()
        try:
            coordinator.wait(timeout=30)
        except subprocess.TimeoutExpired:
            coordinator.kill()
            coordinator.wait()
    events = _assert_recovery(results, dead_rank=0, ref=reference, out=out,
                              tel_dir=tel_dir, resume_rounds=[3, 4])
    # the takeover really happened: the survivor finalized step 3
    fin = [e for e in events if e.get("event") == "recovery_finalize"]
    assert fin and fin[0]["step"] == 3, fin
