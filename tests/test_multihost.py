"""Real multi-process ``jax.distributed`` gate for the sharded runtime.

Spawns coordinated subprocess groups (2 processes × 4 forced host
devices — the main pytest process keeps its single CPU device, see
conftest.py) running ``tests/_multihost_check.py``:

* the 2-process 4-shard powergrid run must match the 1-process run to
  the PR-2 tolerances (AIP 1e-6, policy params to optimizer-step
  tolerance) — the halo exchange and dataset plumbing really cross the
  process boundary;
* killing one host mid-run (SIGKILL, no cleanup) must trigger elastic
  shard reassignment: the survivor times out the heartbeat, adopts the
  dead host's agent blocks on a shrunken mesh, and finishes training;
* both runs emit per-process typed telemetry (``repro.obs``) into a
  shared directory; the primary merges it into ``telemetry.jsonl`` and
  the test re-validates the merged log here — schema-clean round
  records from every process, and for the host drop the
  ``host_death``/``elastic_reassign`` incident events (the dead peer's
  possibly-truncated JSONL must still merge). ``DIALS_TELEMETRY_DIR``
  (set by CI) redirects the logs to an uploadable artifact directory.
"""
import json
import os
import socket
import subprocess
import sys

import numpy as np
import pytest

CHECK = os.path.join(os.path.dirname(__file__), "_multihost_check.py")


def _telemetry_dir(tmp_path, name):
    """Shared telemetry directory for one run: CI points
    DIALS_TELEMETRY_DIR at an uploadable artifact root; locally the
    logs land under tmp_path."""
    base = os.environ.get("DIALS_TELEMETRY_DIR") or str(tmp_path)
    path = os.path.join(base, name)
    os.makedirs(path, exist_ok=True)
    return path


def _check_telemetry(tel_dir, *, procs):
    """Validate the primary-merged telemetry.jsonl with the same code CI
    runs (tools.telemetry_report --check)."""
    from tools import telemetry_report
    merged = os.path.join(tel_dir, "telemetry.jsonl")
    assert os.path.exists(merged), os.listdir(tel_dir)
    events = telemetry_report.load_events(merged)
    assert telemetry_report.check(events) == [], \
        telemetry_report.check(events)
    got = {e.get("proc") for e in events if e.get("event") == "round"}
    assert got == set(procs), (got, procs)
    return events


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _env(tmp_path, *, group=None, rank=0):
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src",
           "JAX_PLATFORMS": "cpu"}
    if group is not None:
        env.update({"DIALS_COORDINATOR": f"127.0.0.1:{group}",
                    "DIALS_NUM_PROCESSES": "2",
                    "DIALS_PROCESS_ID": str(rank)})
    return env


def _launch_pair(tmp_path, mode, out, extra=()):
    """Start both ranks of a 2-process group; return the Popen pair."""
    port = _free_port()
    procs = []
    for rank in (0, 1):
        procs.append(subprocess.Popen(
            [sys.executable, CHECK, "--mode", mode, "--out", out, *extra],
            cwd="/root/repo", env=_env(tmp_path, group=port, rank=rank),
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True))
    return procs


def _wait(proc, what, timeout=1500):
    out, _ = proc.communicate(timeout=timeout)
    return proc.returncode, out


@pytest.mark.timeout(2400)
def test_two_process_sharded_matches_single_process(tmp_path):
    ref_out = str(tmp_path / "ref.json")
    sh_out = str(tmp_path / "sharded.json")

    rc, log = _wait(subprocess.Popen(
        [sys.executable, CHECK, "--mode", "reference", "--out", ref_out],
        cwd="/root/repo", env=_env(tmp_path), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True), "reference")
    assert rc == 0 and "MULTIHOST-OK" in log, log[-3000:]

    tel_dir = _telemetry_dir(tmp_path, "sharded")
    procs = _launch_pair(tmp_path, "sharded", sh_out,
                         extra=("--telemetry-dir", tel_dir))
    results = [_wait(p, f"rank{i}") for i, p in enumerate(procs)]
    for i, (rc, log) in enumerate(results):
        assert rc == 0, f"rank {i} failed:\n{log[-3000:]}"
    assert "MULTIHOST-OK" in results[0][1], results[0][1][-3000:]

    # both ranks' per-process logs merged rank-0-side; schema-clean
    _check_telemetry(tel_dir, procs=(0, 1))

    with open(ref_out) as f:
        ref = json.load(f)
    with open(sh_out) as f:
        got = json.load(f)

    # PR-2 tolerances: AIPs trained on GS data to 1e-6, policy params to
    # optimizer-step tolerance
    for a, b in zip(ref["aips"], got["aips"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg="AIP params (2-proc vs 1-proc)")
    for a, b in zip(ref["params"], got["params"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2,
                                   err_msg="policy params (2-proc vs 1-proc)")
    for r1, r2 in zip(ref["history"], got["history"]):
        np.testing.assert_allclose(r1["aip_ce_after"], r2["aip_ce_after"],
                                   atol=1e-5, err_msg="held-out CE")
        np.testing.assert_allclose(r1["gs_return"], r2["gs_return"],
                                   atol=5e-2, err_msg="gs_return")


@pytest.mark.timeout(2400)
def test_host_drop_triggers_elastic_reassignment(tmp_path):
    out = str(tmp_path / "hostdrop.json")
    beat_dir = str(tmp_path / "beats")
    tel_dir = _telemetry_dir(tmp_path, "hostdrop")
    procs = _launch_pair(tmp_path, "hostdrop", out,
                         extra=("--beat-dir", beat_dir,
                                "--telemetry-dir", tel_dir))
    results = [_wait(p, f"rank{i}") for i, p in enumerate(procs)]

    rc0, log0 = results[0]
    rc1, _ = results[1]
    assert rc0 == 0 and "MULTIHOST-OK" in log0, log0[-3000:]
    # rank 1 really died by SIGKILL, not a clean exit
    assert rc1 == -9, f"expected rank 1 killed by SIGKILL, rc={rc1}"

    with open(out) as f:
        got = json.load(f)
    hist = got["history"]
    assert [r["n_shards"] for r in hist] == [4, 4, 2, 2], hist
    assert hist[2]["dead_hosts"] == [1]
    assert hist[2]["reassigned"] == 2
    assert all(r["reassigned"] == 0 for r in hist if r["round"] != 2)
    assert all(np.isfinite(r["gs_return"]) for r in hist), hist
    # training really continued post-drop: params present and finite
    assert all(np.isfinite(np.asarray(p)).all() for p in got["params"])

    # the incident is reconstructable from the merged event log alone:
    # the SIGKILLed rank's (possibly truncated) JSONL still merged, and
    # the death + replan events are in the stream
    events = _check_telemetry(tel_dir, procs=(0, 1))
    death = [e for e in events if e.get("event") == "host_death"]
    assert death and death[0]["dead_hosts"] == [1], death
    replan = [e for e in events if e.get("event") == "elastic_reassign"]
    assert replan, "no elastic_reassign event"
    assert replan[0]["old_shards"] == 4 and replan[0]["new_shards"] == 2
    assert replan[0]["moved"] == {"2": 1, "3": 1}, replan[0]
    # rank 1 died at the top of round 2: its last round record is 1
    r1_rounds = [e["round"] for e in events
                 if e.get("event") == "round" and e.get("proc") == 1]
    assert r1_rounds and max(r1_rounds) == 1, r1_rounds
