"""Paper Figure 3 in miniature: train a 4-agent networked system with
(a) the global simulator, (b) DIALS, (c) untrained-DIALS, and compare
final returns and wall time — the paper's three-way comparison on one CPU.
Defaults to the 2x2 traffic grid; any registered env name works.

Run:  PYTHONPATH=src python examples/traffic_gs_vs_dials.py [--rounds N]
          [--env traffic] [--shards N] [--async-collect]

``--shards N`` forces the agent-sharded fused runtime (needs N XLA
devices — e.g. XLA_FLAGS=--xla_force_host_platform_device_count=4);
by default the driver picks it automatically when >1 device is visible.
``--async-collect`` overlaps each round's GS collect with the previous
round's inner steps (one-round dataset lag, bounded by
``max_aip_staleness``).
"""
import argparse
import time

import jax

from repro.core import dials, influence
from repro.envs import registry
from repro.launch import variants
from repro.marl import policy, ppo, runner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--inner", type=int, default=20)
    ap.add_argument("--env", default="traffic", choices=registry.names())
    ap.add_argument("--shards", type=int, default=None,
                    help="DIALS runtime shard count (None = auto)")
    ap.add_argument("--async-collect", action="store_true",
                    help="double-buffered overlapped GS collect")
    args = ap.parse_args()

    env_mod, env_cfg = registry.make(args.env, side=2, horizon=32)
    info = env_cfg.info()
    pc = policy.PolicyConfig(obs_dim=info.obs_dim,
                             n_actions=info.n_actions, hidden=(64, 64))
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind="fnn",
                             hidden=(32, 32), epochs=10, batch=64, lr=1e-3)
    ppo_cfg = ppo.PPOConfig()
    results = {}

    for untrained in (False, True):
        name = "untrained-DIALS" if untrained else "DIALS"
        cfg = dials.DIALSConfig(
            outer_rounds=args.rounds, aip_refresh=args.inner,
            collect_envs=8, collect_steps=64, n_envs=8, rollout_steps=16,
            untrained=untrained, eval_episodes=8,
            **variants.dials_variant_for(args.shards, args.async_collect))
        t0 = time.time()
        _, hist = dials.DIALSTrainer(
            env_mod, env_cfg, pc, ac, ppo_cfg, cfg).run(
            jax.random.PRNGKey(0))
        results[name] = (hist[-1]["gs_return"], time.time() - t0)

    # GS baseline: the same number of PPO iterations, on the global sim
    init_fn, train_fn, eval_fn = runner.make_gs_trainer(
        env_mod, env_cfg, pc, ppo_cfg,
        runner.RunConfig(n_envs=8, rollout_steps=16))
    state = init_fn(jax.random.PRNGKey(0))
    t0 = time.time()
    for _ in range(args.rounds * args.inner):
        state, _ = train_fn(state)
    ret = float(eval_fn(state["params"], jax.random.PRNGKey(1), episodes=8))
    results["GS"] = (ret, time.time() - t0)

    print(f"\n{'simulator':<18}{'final GS return':>16}{'wall s':>10}")
    for name, (r, w) in results.items():
        print(f"{name:<18}{r:>16.4f}{w:>10.1f}")
    print("\nThe paper's claims in miniature: DIALS ≈ or > GS return; "
          "untrained-DIALS trails (learned influence matters).")


if __name__ == "__main__":
    main()
