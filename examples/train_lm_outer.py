"""End-to-end LM pretraining driver with the DIALS-outer optimizer —
the paper's pattern (local regions + periodic compact reconciliation)
applied to the multi-pod training layer.

Trains a ~small tinyllama-family model on synthetic zipf data for a few
hundred steps on CPU, with:
  * AdamW inner steps (the "local region" work — on a real 2-pod mesh
    these carry NO cross-pod collective),
  * every F steps a DIALS-outer reconciliation (int8-compressed delta
    exchange + Nesterov outer step — the only cross-pod traffic),
  * gradient clipping, warmup-cosine schedule, checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm_outer.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data import pipeline
from repro.models import api
from repro.optim import adamw, clip, outer, schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--sync-every", type=int, default=25)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    spec = registry.get(args.arch, reduced=True)
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    params = api.init(jax.random.PRNGKey(0), spec)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} (reduced): {n_params/1e6:.2f}M params")

    opt = adamw.init(params)
    out_state = outer.init(params)
    err = None
    lr_fn = schedule.warmup_cosine(3e-3, warmup=20, total=args.steps)
    loss_fn = api.loss_fn(spec)
    mgr = CheckpointManager(args.ckpt, keep=2)

    @jax.jit
    def train_step(params, opt, batch, lr):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        grads, gnorm = clip.clip_by_global_norm(clip.sanitize(grads), 1.0)
        master, opt = adamw.update(grads, opt, lr)
        return adamw.cast_like(master, params), opt, loss, gnorm

    it = pipeline.lm_iterator(seed=0, batch=args.batch, seq=args.seq,
                              vocab=cfg.vocab)
    # restart support: resume from the newest valid checkpoint
    state_tree = {"params": params, "opt": opt, "outer": out_state}
    restored, start = mgr.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_tree))
    if restored is not None:
        params, opt, out_state = (restored["params"], restored["opt"],
                                  restored["outer"])
        print(f"resumed from step {start}")
    start = max(0, start)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(it)
        params, opt, loss, gnorm = train_step(
            params, opt, batch, lr_fn(step))
        if (step + 1) % args.sync_every == 0:
            # DIALS-outer reconciliation (pod_axis=None on 1 host: the
            # compression/outer math runs; on the 2x16x16 mesh this is the
            # only cross-pod collective)
            params, out_state, err = outer.outer_step(
                params, out_state, outer.OuterConfig(
                    sync_every=args.sync_every), err_tree=err)
            mgr.save(step + 1, {"params": params, "opt": opt,
                                "outer": out_state})
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"gnorm {float(gnorm):.2f}  "
                  f"({(time.time()-t0):.0f}s)")
    mgr.wait()
    print("done — final loss should be well below ln(vocab) =",
          f"{jnp.log(jnp.asarray(float(cfg.vocab))):.2f}")


if __name__ == "__main__":
    main()
