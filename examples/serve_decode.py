"""Batched serving example: prefill a prompt batch, then decode tokens
with the position-tracking KV cache — the path the decode_32k/long_500k
dry-run cells lower at production shape.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch tinyllama-1.1b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api, lm as lm_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    spec = registry.get(args.arch, reduced=True)
    if not spec.has_decode or spec.kind == "encdec":
        raise SystemExit(f"{args.arch} has no plain LM decode path")
    cfg = spec.cfg
    params = api.init(jax.random.PRNGKey(0), spec)
    max_len = args.prompt_len + args.new_tokens

    binp = {}
    if spec.kind == "vlm":
        binp["patches"] = jnp.zeros(
            (args.batch, spec.n_patches, spec.vision_dim), jnp.bfloat16)
    caches = api.init_caches(params, spec, args.batch, max_len,
                             batch_inputs=binp)

    @jax.jit
    def decode(params, token, caches, index):
        return lm_mod.decode_step(params, token, caches, index, cfg)

    # "prefill" by decoding the prompt token-by-token (tiny model: fine;
    # production prefill lowers the dedicated prefill_32k program)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, caches = decode(params, prompt[:, i:i + 1],
                                caches, jnp.asarray(i, jnp.int32))
    print(f"prefilled {args.prompt_len} positions in {time.time()-t0:.1f}s")

    # greedy decode
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.prompt_len, max_len - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"decoded {seqs.shape[1]} tokens x {args.batch} seqs "
          f"in {dt:.1f}s ({args.batch*seqs.shape[1]/dt:.0f} tok/s)")
    print("sample ids:", seqs[0, :12].tolist())


if __name__ == "__main__":
    main()
