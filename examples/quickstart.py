"""Quickstart: train a 4-agent networked system with DIALS in ~2 minutes
on CPU.

The three moving parts of the paper, end to end:
  1. a GLOBAL simulator (GS) used only to collect (ALSH, u) datasets,
  2. per-agent APPROXIMATE INFLUENCE PREDICTORS (AIPs) trained on them,
  3. per-agent LOCAL simulators (IALS) driven by the frozen AIPs, on which
     every agent trains PPO independently (and, in deployment, in
     parallel) for F steps between AIP refreshes.

Any registered environment works — the env resolves by name through
``repro.envs.registry`` (traffic, warehouse, powergrid, supplychain, or
your own).

Run:  PYTHONPATH=src python examples/quickstart.py [--env warehouse]
"""
import argparse

import jax

from repro.core import dials, influence
from repro.envs import registry
from repro.marl import policy, ppo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="warehouse", choices=registry.names())
    ap.add_argument("--side", type=int, default=2,
                    help="uniform size knob (side=2 -> 4 agents)")
    args = ap.parse_args()

    env_mod, env_cfg = registry.make(args.env, side=args.side, horizon=32)
    info = env_cfg.info()

    policy_cfg = policy.PolicyConfig(
        obs_dim=info.obs_dim, n_actions=info.n_actions, hidden=(64, 64))
    aip_cfg = influence.AIPConfig(
        in_dim=info.alsh_dim, n_sources=info.n_influence,
        kind="fnn", hidden=(32, 32), epochs=10, batch=64, lr=1e-3)

    cfg = dials.DIALSConfig(
        outer_rounds=4,        # collect -> AIP train -> F inner steps, x4
        aip_refresh=20,        # F: PPO iterations between AIP refreshes
        collect_envs=8, collect_steps=64,
        n_envs=8, rollout_steps=16, eval_episodes=8)

    trainer = dials.DIALSTrainer(
        env_mod, env_cfg, policy_cfg, aip_cfg, ppo.PPOConfig(), cfg)

    print(f"training {info.n_agents} {args.env} agents with DIALS "
          f"(F={cfg.aip_refresh} PPO iters/refresh)")
    _, history = trainer.run(jax.random.PRNGKey(0), log=lambda r: print(
        f"  round {r['round']}: GS return {r['gs_return']:.4f}  "
        f"AIP CE {r['aip_ce_before']:.3f}->{r['aip_ce_after']:.3f}  "
        f"({r['wall_s']:.0f}s)"))

    first, last = history[0], history[-1]
    print(f"\nGS return {first['gs_return']:.4f} -> {last['gs_return']:.4f}")
    print("done.")


if __name__ == "__main__":
    main()
