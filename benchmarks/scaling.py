"""DIALS scaling benchmark — shard count × scenario sweep.

Measures, for every (registered scenario, shard count) cell:

* wall-clock per outer Algorithm-1 round (post-compilation), with the
  GS collect on the critical path (``round_s``) AND overlapped with the
  inner steps (``round_s_async`` — ``DIALSConfig.async_collect``, the
  double-buffered collect of repro.distributed.async_collect) plus
  their ratio ``overlap_speedup``,
* inner agent-env steps/s (F · n_envs · rollout_steps · N per round),
* speedup of the fused sharded runtime over the unfused python-loop
  path (``shards=1`` — the F+3-syncs-per-round baseline),
* the GS decomposition A/B: one replicated Algorithm-2 collect
  (``collect_s``) vs the region-decomposed ``shard_map``'d collect of
  ``repro.core.gs_sharded`` on the same mesh
  (``collect_s_sharded_gs`` / ``gs_speedup``; null where the env's
  ``region_partition`` cannot tile the shard count, e.g. a 2×2 grid on
  8 shards),
* with ``--streams S1,S2,...``, the large-batch collect curve: the
  loop path at collect width S (``DIALSConfig.collect_streams`` — the
  ring-buffer datasets feeding the fused AIP round), one row per S with
  ``env_steps_per_s = S * collect_steps / collect_s`` from a dedicated
  post-compile collect timing.

The default grid includes the side-4 (16-agent) cells at shards 8/16
(powergrid-ring16 / supplychain-line16 — contiguous-ring topologies that
decompose at every divisor). On forced host devices the shard-scaling
numbers are overhead-dominated (one physical CPU); the fused-vs-unfused
and sharded-GS columns are still meaningful A/Bs of program structure.

Writes ``experiments/bench/BENCH_dials_scaling.json`` — the perf
trajectory artifact CI uploads — plus ``name,metric,value`` CSV lines on
stdout.

Shard counts > 1 need multiple XLA devices; this script forces
``--xla_force_host_platform_device_count=<max shards>`` BEFORE importing
jax, so it must run as its own process:

    PYTHONPATH=src python -m benchmarks.scaling [--fast]
        [--shards 1,2,4,8,16] [--scenarios traffic-2x2,powergrid-ring16]

``--processes P1,P2,...`` additionally sweeps real multi-process
execution: for each P > 1 the script re-launches itself as P coordinated
``jax.distributed`` CPU processes (repro.launch.variants.launch_group /
repro.distributed.bootstrap — each process forces max_shards/P host
devices, so the global device count matches the single-process run) and
merges the measured rows, labelled ``{scenario}-s{shards}-p{P}`` with a
``processes`` column, into the same artifact. Shard counts that cannot
be balanced over P processes are skipped; the shards=1 unfused baseline
only exists at P=1.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

OUT_PATH = os.path.join("experiments", "bench", "BENCH_dials_scaling.json")


def _timed(fn, *args):
    import jax
    jax.block_until_ready(fn(*args))               # compile
    t0 = time.time()
    jax.block_until_ready(fn(*args))
    return time.time() - t0


def _make_collect_ab(env_mod, env_cfg, pc, *, n_envs, steps):
    """Per-scenario sharded-GS A/B: build + time the (shard-independent)
    replicated Algorithm-2 collect ONCE, return ``ab(shards)`` producing
    the per-cell columns — the region-decomposed collect re-times per
    mesh; the sharded columns are None where the env topology cannot
    tile that block count."""
    import jax
    from repro.core import gs as gs_mod, gs_sharded
    from repro.distributed import runtime
    from repro.marl import policy as policy_mod

    info = env_cfg.info()
    key = jax.random.PRNGKey(0)
    params = jax.vmap(lambda k: policy_mod.policy_init(k, pc))(
        jax.random.split(key, info.n_agents))
    rep = gs_mod.make_collector(env_mod, env_cfg, pc,
                                n_envs=n_envs, steps=steps)
    rep_s = _timed(rep, params, key)

    def ab(shards):
        out = {"collect_s": rep_s,
               "env_steps_per_s": n_envs * steps / rep_s,
               "collect_s_sharded_gs": None, "gs_speedup": None}
        ok, _why = gs_sharded.partition_supported(env_mod, env_cfg,
                                                  shards)
        if shards > 1 and ok:
            mesh = runtime.shard_mesh(shards)
            shc = gs_sharded.make_sharded_collector(
                env_mod, env_cfg, pc, n_envs=n_envs, steps=steps,
                mesh=mesh)
            sp = runtime.shard_agent_tree(params, mesh)
            out["collect_s_sharded_gs"] = _timed(shc, sp, key)
            out["gs_speedup"] = rep_s / out["collect_s_sharded_gs"]
        return out

    return ab


def _sweep(scenarios, shard_counts, *, rounds, inner, collect_steps,
           processes=1, telemetry_dir=None):
    # imported late: main() must set XLA_FLAGS first
    import jax
    from benchmarks.run import _setup
    from repro.core import dials
    from repro.launch import variants

    suffix = f"-p{processes}" if processes > 1 else ""
    rows = []
    for scenario in scenarios:
        env_name, side = variants.MARL_SCENARIOS[scenario]
        env_mod, env_cfg, info, pc, ac, ppo_cfg = _setup(env_name, side)
        n = info.n_agents
        collect_ab = _make_collect_ab(env_mod, env_cfg, pc, n_envs=4,
                                      steps=collect_steps)
        unfused_round_s = None
        for shards in shard_counts:
            if n % shards:
                print(f"# skip {scenario} shards={shards}: "
                      f"{n} agents not divisible")
                continue
            if shards % processes:
                print(f"# skip {scenario} shards={shards}: cannot "
                      f"balance over {processes} processes")
                continue
            # every cell runs twice: collect on the critical path
            # (async_collect=False) vs overlapped (True)
            steady_by_mode, total_by_mode = {}, {}
            for overlap in (False, True):
                # per-cell telemetry subdir: each (cell, mode) run gets
                # its own event log, so round indices stay monotone per
                # file and tools.telemetry_report --check passes per dir
                cell_tel = None
                if telemetry_dir:
                    cell_tel = os.path.join(
                        telemetry_dir,
                        f"{scenario}-s{shards}{suffix}-"
                        f"{'async' if overlap else 'sync'}")
                cfg = dials.DIALSConfig(
                    outer_rounds=rounds, aip_refresh=inner, collect_envs=4,
                    collect_steps=collect_steps, n_envs=8, rollout_steps=16,
                    eval_episodes=4, telemetry_dir=cell_tel,
                    **variants.dials_variant_for(shards, overlap))
                tr = dials.DIALSTrainer(env_mod, env_cfg, pc, ac,
                                        ppo_cfg, cfg)
                t0 = time.time()
                _, hist = tr.run(jax.random.PRNGKey(0))
                total_by_mode[overlap] = time.time() - t0
                # round 0 pays compilation (and async priming); measure
                # the steady-state rounds (with a single round, the
                # compile-inclusive time is all there is — still a valid
                # upper bound)
                steady_by_mode[overlap] = (
                    (hist[-1]["wall_s"] - hist[0]["wall_s"]) /
                    (len(hist) - 1)) if len(hist) > 1 \
                    else hist[0]["wall_s"]
            steady = steady_by_mode[False]
            inner_steps = cfg.aip_refresh * cfg.n_envs * \
                cfg.rollout_steps * n                  # F * E * T * N
            row = {"label": f"{scenario}-s{shards}{suffix}",
                   "scenario": scenario, "n_agents": n, "shards": shards,
                   "processes": processes, "streams": 4,
                   "fused": shards > 1,
                   "round_s": steady,
                   "round_s_async": steady_by_mode[True],
                   "overlap_speedup": steady / steady_by_mode[True],
                   "inner_steps_per_s": inner_steps / steady,
                   "inner_steps_per_s_async":
                       inner_steps / steady_by_mode[True],
                   "total_wall_s": total_by_mode[False],
                   "total_wall_s_async": total_by_mode[True],
                   **collect_ab(shards)}
            if shards == 1:
                unfused_round_s = steady
            if unfused_round_s is not None:
                row["speedup_vs_unfused"] = unfused_round_s / steady
            rows.append(row)
    return rows


def _stream_sweep(scenarios, streams_list, *, rounds, inner,
                  collect_steps, telemetry_dir=None):
    """Large-batch collect sweep: the loop (shards=1) path at stream
    widths S, first scenario only. Each cell runs the full DIALS round
    loop (ring-buffer collect feeding the fused AIP round) sync and
    async, plus a dedicated post-compile collect timing that gives the
    ``env_steps_per_s`` throughput curve the large-batch claim rests on
    (the in-loop collect span includes dispatch jitter; the dedicated
    timing is the apples-to-apples cell)."""
    import jax
    from benchmarks.run import _setup
    from repro.core import dials, gs as gs_mod
    from repro.launch import variants
    from repro.marl import policy as policy_mod

    scenario = scenarios[0]
    env_name, side = variants.MARL_SCENARIOS[scenario]
    env_mod, env_cfg, info, pc, ac, ppo_cfg = _setup(env_name, side)
    n = info.n_agents
    key = jax.random.PRNGKey(0)
    params = jax.vmap(lambda k: policy_mod.policy_init(k, pc))(
        jax.random.split(key, n))
    rows = []
    for streams in streams_list:
        coll = gs_mod.make_collector(env_mod, env_cfg, pc,
                                     n_envs=streams, steps=collect_steps)
        collect_s = _timed(coll, params, key)
        steady_by_mode, total_by_mode = {}, {}
        for overlap in (False, True):
            cell_tel = None
            if telemetry_dir:
                cell_tel = os.path.join(
                    telemetry_dir,
                    f"{scenario}-streams{streams}-"
                    f"{'async' if overlap else 'sync'}")
            cfg = dials.DIALSConfig(
                outer_rounds=rounds, aip_refresh=inner, collect_envs=4,
                collect_steps=collect_steps, n_envs=8, rollout_steps=16,
                eval_episodes=4, telemetry_dir=cell_tel,
                **variants.dials_variant_for(1, overlap,
                                             streams=streams))
            tr = dials.DIALSTrainer(env_mod, env_cfg, pc, ac,
                                    ppo_cfg, cfg)
            t0 = time.time()
            _, hist = tr.run(jax.random.PRNGKey(0))
            total_by_mode[overlap] = time.time() - t0
            steady_by_mode[overlap] = (
                (hist[-1]["wall_s"] - hist[0]["wall_s"]) /
                (len(hist) - 1)) if len(hist) > 1 \
                else hist[0]["wall_s"]
        steady = steady_by_mode[False]
        inner_steps = cfg.aip_refresh * cfg.n_envs * \
            cfg.rollout_steps * n
        rows.append({
            "label": f"{scenario}-streams{streams}",
            "scenario": scenario, "n_agents": n, "shards": 1,
            "processes": 1, "streams": streams, "fused": False,
            "round_s": steady,
            "round_s_async": steady_by_mode[True],
            "overlap_speedup": steady / steady_by_mode[True],
            "inner_steps_per_s": inner_steps / steady,
            "inner_steps_per_s_async":
                inner_steps / steady_by_mode[True],
            "total_wall_s": total_by_mode[False],
            "total_wall_s_async": total_by_mode[True],
            "collect_s": collect_s,
            "env_steps_per_s": streams * collect_steps / collect_s,
            "collect_s_sharded_gs": None, "gs_speedup": None,
        })
    return rows


def _spawn_group(args, processes, shard_counts, rows_path) -> None:
    """Re-launch this script as ``processes`` coordinated jax.distributed
    processes; rank 0 writes its rows to ``rows_path``."""
    from repro.launch import variants

    local = max(s for s in shard_counts if s % processes == 0) // processes
    argv = [sys.executable, "-m", "benchmarks.scaling",
            "--shards", args.shards, "--scenarios", args.scenarios,
            "--rows-out", rows_path]
    if args.rounds is not None:
        argv += ["--rounds", str(args.rounds)]
    if args.fast:
        argv.append("--fast")
    if args.telemetry_dir:
        # shared dir: every rank writes its own telemetry-p{rank}.jsonl
        argv += ["--telemetry-dir", args.telemetry_dir]
    # children must not inherit a forced device count from the parent's
    # own sweep: bootstrap sets their XLA_FLAGS from DIALS_LOCAL_DEVICES
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    procs = variants.launch_group(argv, processes=processes,
                                  local_devices=local, env=env)
    rcs = [p.wait() for p in procs]
    if any(rcs):
        raise SystemExit(
            f"--processes {processes} group failed, exit codes {rcs}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: fewer rounds/steps")
    ap.add_argument("--shards", default="1,2,4,8,16",
                    help="comma-separated shard counts (1 = unfused "
                         "python-loop baseline); counts that do not "
                         "divide a scenario's agent count are skipped")
    ap.add_argument("--scenarios",
                    default="traffic-2x2,supplychain-line4,"
                            "powergrid-ring16,supplychain-line16",
                    help="comma-separated names from "
                         "launch.variants.MARL_SCENARIOS (the ring16/"
                         "line16 defaults are the side-4 16-agent cells "
                         "exercising shards 8/16)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--streams", default=None,
                    help="comma-separated collect stream widths S — "
                         "sweeps the loop-path large-batch collect "
                         "(ring-buffer datasets, fused AIP round) on "
                         "the FIRST scenario, one row per S labelled "
                         "{scenario}-streams{S} with the "
                         "env_steps_per_s throughput column")
    ap.add_argument("--processes", default="1",
                    help="comma-separated process counts; each P > 1 "
                         "re-launches the sweep as P coordinated "
                         "jax.distributed CPU processes and merges the "
                         "rows (labelled -pP)")
    ap.add_argument("--telemetry-dir", default=None,
                    help="emit per-round typed telemetry (repro.obs) — "
                         "one subdirectory of JSONL event logs per "
                         "(cell, sync/async) run, merged to "
                         "telemetry.jsonl at the end; render/validate "
                         "with tools.telemetry_report")
    ap.add_argument("--profile-dir", default=None,
                    help="capture an XLA profiler trace of the "
                         "single-process sweep into this directory "
                         "(ignored for --processes > 1 groups)")
    ap.add_argument("--rows-out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    shard_counts = sorted({int(s) for s in args.shards.split(",")})
    scenarios = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    rounds = args.rounds if args.rounds is not None else \
        (2 if args.fast else 4)
    if rounds < 1:
        ap.error("--rounds must be >= 1")
    inner = 4 if args.fast else 20
    collect_steps = 32 if args.fast else 64

    from repro.distributed import bootstrap
    group = bootstrap.config_from_env()
    if group is not None:
        # child mode: one rank of a --processes group. bootstrap (which
        # applies the forced device count and joins the coordination
        # service) must run before the sweep's jax import.
        ctx = bootstrap.bootstrap(group)
        rows = _sweep(scenarios, shard_counts, rounds=rounds, inner=inner,
                      collect_steps=collect_steps,
                      processes=ctx.num_processes,
                      telemetry_dir=args.telemetry_dir)
        if ctx.is_primary:
            if not args.rows_out:
                raise SystemExit("group child needs --rows-out")
            with open(args.rows_out, "w") as f:
                json.dump(rows, f, default=float)
        return

    process_counts = sorted({int(p) for p in args.processes.split(",")})
    rows = []
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    for processes in process_counts:
        if processes <= 1:
            # in-process, exactly the historical single-process sweep;
            # multiple shards need multiple devices — force them before
            # jax loads
            n_dev = max(shard_counts)
            if n_dev > 1:
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "") +
                    f" --xla_force_host_platform_device_count={n_dev}"
                ).strip()
            from repro.obs import trace as obs_trace
            with obs_trace.profile(args.profile_dir):
                rows.extend(_sweep(scenarios, shard_counts, rounds=rounds,
                                   inner=inner,
                                   collect_steps=collect_steps,
                                   telemetry_dir=args.telemetry_dir))
                if args.streams:
                    streams_list = sorted(
                        {int(s) for s in args.streams.split(",")})
                    rows.extend(_stream_sweep(
                        scenarios, streams_list, rounds=rounds,
                        inner=inner, collect_steps=collect_steps,
                        telemetry_dir=args.telemetry_dir))
            continue
        if all(s % processes for s in shard_counts):
            print(f"# skip processes={processes}: no shard count "
                  f"balances over it")
            continue
        rows_path = os.path.join(os.path.dirname(OUT_PATH),
                                 f".rows-p{processes}.json")
        _spawn_group(args, processes, shard_counts, rows_path)
        with open(rows_path) as f:
            rows.extend(json.load(f))
        os.remove(rows_path)

    # schema gate before the artifact is written: every row must be a
    # valid typed scaling record (repro.obs.metrics.SCALING_ROW_SCHEMA) —
    # check_bench and live telemetry then share one vocabulary
    from repro.obs import metrics as obs_metrics
    problems = [p for r in rows
                for p in obs_metrics.validate_bench_row(
                    r, obs_metrics.SCALING_ROW_SCHEMA)]
    if problems:
        for p in problems:
            print(f"SCHEMA-INVALID {p}", file=sys.stderr)
        raise SystemExit(f"{len(problems)} scaling rows violate "
                         f"SCALING_ROW_SCHEMA")

    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print("name,metric,value")
    for r in rows:
        for k, v in r.items():
            if k not in ("label", "scenario"):
                print(f"dials_scaling.{r['label']},{k},{v}")
    print(f"# wrote {OUT_PATH}")

    if args.telemetry_dir:
        # merge every cell's per-process logs into a telemetry.jsonl so
        # the uploaded artifact is readable without this package
        from repro.obs import sinks as obs_sinks
        merged = 0
        for root, _dirs, files in sorted(os.walk(args.telemetry_dir)):
            if any(f.startswith("telemetry-p") and f.endswith(".jsonl")
                   for f in files):
                obs_sinks.merge_dir(root)
                merged += 1
        print(f"# merged telemetry in {merged} cell dir(s) under "
              f"{args.telemetry_dir}")


if __name__ == "__main__":
    main()
