"""Kernel benchmark suite — oracle vs Pallas on the DIALS hot spots.

Per-kernel microbenchmarks (gru, gae): forward and forward+backward
wall-clock for the pure-jnp oracle vs the Pallas kernel, swept over
(B, T, H) shapes drawn from the registered scenarios (the AIP-training
minibatch and the PPO rollout recompute of each env, agent axis folded
into the batch the way the vmapped trainers fold it) plus one headline
TPU-sized shape. Each row carries the TPU-v5e roofline terms for the
kernel's analytic FLOP/byte footprint (``benchmarks/roofline.py``) —
``roofline_fraction`` ≈ 1 means the fused scan would be MXU-bound on the
target, not memory-bound.

End-to-end A/B: a full ``train_aip`` (GRU AIP, grads through the
custom_vjp) and one IALS inner step (``ials_train``: rollout + GAE +
PPO with a GRU policy) with ``use_kernels`` off vs on.

On CPU the kernel columns run in Pallas INTERPRET mode — they measure
the interpreter, not the TPU, and will be slower than the oracle; the
point of the artifact on CPU is the oracle baselines, the roofline
numbers, and CI coverage of the full bench path. On a TPU backend the
same script emits the real A/B.

Usage:  PYTHONPATH=src python -m benchmarks.kernels [--fast]
Output: ``BENCH_kernels.json`` at the repo root (the first root-level
bench artifact) + ``name,metric,value`` CSV lines on stdout.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import roofline

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_kernels.json")


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------
def _time(fn, *args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# shape sweep: drawn from the registered scenarios
# ---------------------------------------------------------------------------
def swept_shapes(fast: bool):
    """(label, B, T, in, H) per scenario: the AIP-training minibatch
    (collect_envs × n_agents sequences of collect_steps, trunk width →
    gru_hidden) and the PPO recompute (n_envs × n_agents chunks of
    rollout_steps) — plus one headline TPU-sized shape."""
    from repro.core import dials, influence
    from repro.envs import registry
    from repro.marl import policy
    dcfg = dials.DIALSConfig()
    acfg = influence.AIPConfig(in_dim=1, n_sources=1)
    pcfg = policy.PolicyConfig(obs_dim=1, n_actions=1)
    t_collect = 16 if fast else dcfg.collect_steps
    t_roll = 8 if fast else dcfg.rollout_steps
    shapes = []
    for name in registry.names():
        info = registry.make(name, side=2)[1].info()
        shapes.append((f"{name}-aip", dcfg.collect_envs * info.n_agents,
                       t_collect, acfg.hidden[-1], acfg.gru_hidden))
        shapes.append((f"{name}-policy", dcfg.n_envs * info.n_agents,
                       t_roll, pcfg.hidden[-1], pcfg.gru_hidden))
    shapes.append(("headline", 32 if fast else 256, t_collect,
                   pcfg.hidden[-1], pcfg.gru_hidden))
    if fast:            # CI smoke: one aip + two policy shapes + headline
        shapes = shapes[:2] + shapes[3:4] + shapes[-1:]
    return shapes


# ---------------------------------------------------------------------------
# analytic roofline footprints (per call, fp32)
# ---------------------------------------------------------------------------
def _gru_roofline(b, t, din, h, *, backward: bool):
    inp = 2.0 * b * t * din * 3 * h            # x·W_i for all steps
    rec = 2.0 * b * t * h * 3 * h              # h·W_h, T sequential steps
    elem = 12.0 * b * t * h
    flops = inp + rec + elem
    if backward:
        # recompute gh + two adjoint matmuls per step; dx/dW_i adjoints
        flops += 3 * rec + 2 * inp + 2 * elem
    bytes_ = 4.0 * (b * t * din + din * 3 * h + 2 * b * t * 3 * h
                    + h * 3 * h + b * t * h)
    if backward:
        bytes_ *= 3
    return roofline.terms(flops=flops, bytes_accessed=bytes_,
                          collective_bytes=0.0, n_devices=1,
                          peak_flops=roofline.PEAK_FLOPS_FP32)


def _gae_roofline(b, t, *, backward: bool):
    flops = 9.0 * b * t * (2.0 if backward else 1.0)
    bytes_ = 4.0 * 5 * b * t * (2.0 if backward else 1.0)
    return roofline.terms(flops=flops, bytes_accessed=bytes_,
                          collective_bytes=0.0, n_devices=1,
                          peak_flops=roofline.PEAK_FLOPS_FP32)


# ---------------------------------------------------------------------------
# microbenchmarks
# ---------------------------------------------------------------------------
def bench_gru(fast: bool):
    from repro.kernels.gru import ops as gru_ops
    from repro.kernels.gru import ref as gru_ref
    from repro.nn import gru as gru_mod
    iters = 2 if fast else 10
    rows = []
    for label, b, t, din, h in swept_shapes(fast):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        params = gru_mod.gru_init(ks[0],
                                  gru_mod.GRUConfig(in_dim=din, hidden=h))
        xs = jax.random.normal(ks[1], (b, t, din), jnp.float32)
        resets = jax.random.bernoulli(ks[2], 0.1, (b, t)) \
            .astype(jnp.float32)

        def fwd(seq_fn):
            return jax.jit(lambda p, x: seq_fn(p, x)[0].sum())

        def fwdbwd(seq_fn):
            return jax.jit(jax.grad(lambda p, x: (seq_fn(p, x)[0] ** 2)
                                    .sum()))

        k_seq = lambda p, x: gru_ops.gru_sequence(p, x, reset_mask=resets)
        r_seq = lambda p, x: gru_ref.gru_sequence(p, x, reset_mask=resets)
        row = {"kernel": "gru", "label": label, "B": b, "T": t,
               "in": din, "H": h,
               "fwd_oracle_s": _time(fwd(r_seq), params, xs, iters=iters),
               "fwd_kernel_s": _time(fwd(k_seq), params, xs, iters=iters),
               "fwdbwd_oracle_s": _time(fwdbwd(r_seq), params, xs,
                                        iters=iters),
               "fwdbwd_kernel_s": _time(fwdbwd(k_seq), params, xs,
                                        iters=iters),
               "roofline_fwd": _gru_roofline(b, t, din, h, backward=False),
               "roofline_fwdbwd": _gru_roofline(b, t, din, h,
                                                backward=True)}
        row["speedup_fwd"] = row["fwd_oracle_s"] / row["fwd_kernel_s"]
        row["speedup_fwdbwd"] = (row["fwdbwd_oracle_s"]
                                 / row["fwdbwd_kernel_s"])
        rows.append(row)
    return rows


def bench_gae(fast: bool):
    from repro.kernels.gae import ops as gae_ops
    from repro.kernels.gae import ref as gae_ref
    iters = 2 if fast else 20
    rows = []
    # GAE only runs on the PPO recompute batch (n_envs × n_agents,
    # rollout_steps) — bench the '-policy' shapes (+ headline), not the
    # AIP-collect shapes it never sees
    shapes = [(lbl, b, t) for lbl, b, t, _, _ in swept_shapes(fast)
              if not lbl.endswith("-aip")]
    for label, b, t in shapes:
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        rw = jax.random.normal(ks[0], (b, t))
        vl = jax.random.normal(ks[1], (b, t))
        dn = jax.random.bernoulli(ks[2], 0.1, (b, t)).astype(jnp.float32)
        lv = jax.random.normal(ks[3], (b,))

        def fwd(gae_fn):
            return jax.jit(lambda r, v: gae_fn(r, v)[0].sum())

        def fwdbwd(gae_fn):
            return jax.jit(jax.grad(lambda r, v: (gae_fn(r, v)[0] ** 2)
                                    .sum(), argnums=(0, 1)))

        k_fn = lambda r, v: gae_ops.gae(r, v, dn, lv)
        r_fn = lambda r, v: gae_ref.gae(r, v, dn, lv)
        row = {"kernel": "gae", "label": label, "B": b, "T": t,
               "fwd_oracle_s": _time(fwd(r_fn), rw, vl, iters=iters),
               "fwd_kernel_s": _time(fwd(k_fn), rw, vl, iters=iters),
               "fwdbwd_oracle_s": _time(fwdbwd(r_fn), rw, vl, iters=iters),
               "fwdbwd_kernel_s": _time(fwdbwd(k_fn), rw, vl, iters=iters),
               "roofline_fwd": _gae_roofline(b, t, backward=False),
               "roofline_fwdbwd": _gae_roofline(b, t, backward=True)}
        row["speedup_fwd"] = row["fwd_oracle_s"] / row["fwd_kernel_s"]
        row["speedup_fwdbwd"] = (row["fwdbwd_oracle_s"]
                                 / row["fwdbwd_kernel_s"])
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# end-to-end A/B: the two inner-loop programs that own the hot spots
# ---------------------------------------------------------------------------
def bench_end_to_end(fast: bool):
    import dataclasses
    from repro.core import ials as ials_mod
    from repro.core import influence
    from repro.envs import registry
    from repro.marl import policy, ppo
    env_mod, env_cfg = registry.make("warehouse", side=2, horizon=32)
    info = env_cfg.info()
    rows = []

    # --- train_aip: GRU AIP, grads through the sequence scan
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    s, t = (8, 16) if fast else (32, 64)
    base_ac = influence.AIPConfig(
        in_dim=info.alsh_dim, n_sources=info.n_influence, kind="gru",
        hidden=(32,), gru_hidden=32, epochs=2 if fast else 10, batch=8)
    data = {"feats": jax.random.normal(ks[0], (s, t, info.alsh_dim)),
            "u": jax.random.bernoulli(
                ks[1], 0.4, (s, t, info.n_influence)).astype(jnp.float32),
            "resets": jax.random.bernoulli(
                ks[2], 0.1, (s, t)).astype(jnp.float32)}
    params = influence.aip_init(ks[3], base_ac)
    times = {}
    for mode in ("off", "on"):
        ac = dataclasses.replace(base_ac, use_kernels=mode)
        fn = jax.jit(lambda p, d, k, _ac=ac: influence.train_aip(
            p, d, k, _ac))
        times[mode] = _time(fn, params, data, jax.random.PRNGKey(3),
                            iters=1 if fast else 3)
    rows.append({"program": "train_aip", "label": f"warehouse-S{s}-T{t}",
                 "oracle_s": times["off"], "kernel_s": times["on"],
                 "speedup": times["off"] / times["on"]})

    # --- one IALS inner step: rollout + GAE + PPO (GRU policy)
    pc_base = policy.PolicyConfig(obs_dim=info.obs_dim,
                                  n_actions=info.n_actions, kind="gru",
                                  hidden=(32,), gru_hidden=16)
    n_envs, roll = (2, 8) if fast else (8, 16)
    times = {}
    for mode in ("off", "on"):
        pc = dataclasses.replace(pc_base, use_kernels=mode)
        ac = dataclasses.replace(base_ac, use_kernels=mode)
        ppo_cfg = ppo.PPOConfig(epochs=1, minibatches=2, use_kernels=mode)
        init_fn, train_fn = ials_mod.make_ials_trainer(
            env_mod, env_cfg, pc, ac, ppo_cfg, n_envs=n_envs,
            rollout_steps=roll)
        state = init_fn(jax.random.PRNGKey(4))
        aips = jax.vmap(lambda k: influence.aip_init(k, ac))(
            jax.random.split(jax.random.PRNGKey(5), info.n_agents))
        times[mode] = _time(lambda s_, a_: train_fn(s_, a_)[0]["params"],
                            state, aips, iters=1 if fast else 3)
    rows.append({"program": "ials_inner_step",
                 "label": f"warehouse-E{n_envs}-T{roll}",
                 "oracle_s": times["off"], "kernel_s": times["on"],
                 "speedup": times["off"] / times["on"]})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced shapes/iters (CI smoke)")
    args = ap.parse_args()

    from repro.kernels import dispatch
    decision = dispatch.resolve("on")
    record = {
        "backend": jax.default_backend(),
        "interpret": decision.interpret,
        "note": ("kernel columns ran under the Pallas interpreter "
                 "(non-TPU backend); oracle columns and roofline terms "
                 "are the meaningful numbers here"
                 if decision.interpret else
                 "compiled Pallas kernels"),
        "fast": bool(args.fast),
        "micro": bench_gru(args.fast) + bench_gae(args.fast),
        "end_to_end": bench_end_to_end(args.fast),
    }
    # schema gate before the artifact is written (same typed vocabulary
    # check_bench.py validates against)
    from repro.obs import metrics as obs_metrics
    problems = [p for r in record["micro"]
                for p in obs_metrics.validate_bench_row(
                    r, obs_metrics.KERNELS_MICRO_SCHEMA)]
    problems += [p for r in record["end_to_end"]
                 for p in obs_metrics.validate_bench_row(
                     r, obs_metrics.KERNELS_E2E_SCHEMA)]
    if problems:
        for p in problems:
            print(f"SCHEMA-INVALID {p}")
        raise SystemExit(f"{len(problems)} kernel bench rows violate "
                         f"the KERNELS_* schemas")
    with open(OUT_PATH, "w") as f:
        json.dump(record, f, indent=1, default=float)
    print("name,metric,value")
    for r in record["micro"]:
        for k in ("fwd_oracle_s", "fwd_kernel_s", "fwdbwd_oracle_s",
                  "fwdbwd_kernel_s", "speedup_fwd", "speedup_fwdbwd"):
            print(f"kernels.{r['kernel']}-{r['label']},{k},{r[k]}")
    for r in record["end_to_end"]:
        for k in ("oracle_s", "kernel_s", "speedup"):
            print(f"kernels.{r['program']}-{r['label']},{k},{r[k]}")
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
