"""Roofline arithmetic for the TPU v5e target.

The three terms (seconds) for one compiled step on an N-chip mesh:

  compute_s    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory_s     = HLO_bytes / (chips * HBM_BW)
  collective_s = collective_bytes / (chips * ICI_BW)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device program ×
device count is already folded in by the dry-run, which records per-device
numbers — pass per-device values with chips=1, or totals with the mesh
size). ``collective_bytes`` is parsed from the post-SPMD HLO by
``repro.launch.dryrun.collective_bytes``.
"""
from __future__ import annotations

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (TPU v5e)
PEAK_FLOPS_FP32 = PEAK_FLOPS / 2   # fp32 programs run at half the bf16 MXU rate
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 link claimed)


def terms(*, flops: float, bytes_accessed: float, collective_bytes: float,
          n_devices: int, peak_flops: float = PEAK_FLOPS) -> dict:
    """``peak_flops`` defaults to the bf16 peak; pass ``PEAK_FLOPS_FP32``
    when the FLOP count describes an fp32 program (the MARL kernels)."""
    compute_s = flops / (n_devices * peak_flops)
    memory_s = bytes_accessed / (n_devices * HBM_BW)
    collective_s = collective_bytes / (n_devices * ICI_BW)
    bottleneck = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    step_s = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "step_s": step_s,
        # fraction of roofline the *compute* term occupies — the score:
        # 1.0 means the step is pure MXU with nothing else dominant.
        "roofline_fraction": compute_s / step_s if step_s > 0 else 0.0,
    }


def model_flops(n_params_active: float, tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for a train step;
    2·N·D for inference-only steps (pass the matching factor)."""
    return 6.0 * n_params_active * tokens


def per_device(rec: dict) -> dict:
    """Extract per-device roofline inputs from a dry-run JSON record.
    cost_analysis FLOPs/bytes are per-device for SPMD programs; so is the
    parsed per-device HLO collective footprint — use chips=1."""
    return {
        "flops": rec["cost"]["flops"],
        "bytes_accessed": rec["cost"]["bytes_accessed"],
        "collective_bytes": rec["collectives"]["total_bytes"],
        "n_devices": 1,
    }
