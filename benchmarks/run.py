"""Benchmark harness — one function per paper table/figure, plus the
roofline table from the dry-run artifacts.

  fig3_learning      GS vs DIALS vs untrained-DIALS on the 4-agent envs
                     (paper Fig. 3 1a/1b, CPU-scaled).
  fig3_scalability   total runtime vs system size for GS vs DIALS
                     (paper Fig. 3 3a/3b + Tables 1-2, CPU-scaled).
  fig4_f_sweep       AIP refresh frequency F sweep + influence CE
                     (paper Fig. 4).
  table_lemma2       Lemma-2 bound certificate sweep (paper Sec. 4.1.2).
  table_memory       per-process memory split GS vs DIALS (paper Table 3,
                     proxied by simulator state sizes).
  roofline           §Roofline terms for every dry-run cell on disk.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
Output: ``name,metric,value`` CSV lines + JSON records in
        experiments/bench/.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _emit(rows, name):
    os.makedirs("experiments/bench", exist_ok=True)
    with open(f"experiments/bench/{name}.json", "w") as f:
        json.dump(rows, f, indent=1, default=float)
    for r in rows:
        for k, v in r.items():
            if k in ("name", "label"):
                continue
            print(f"{name}.{r.get('label', '')},{k},{v}")


# ---------------------------------------------------------------------------
# shared tiny-scale MARL setup (CPU-budget versions of the paper envs).
# Envs resolve through repro.envs.registry, so every registered scenario
# automatically inherits every benchmark below.
# ---------------------------------------------------------------------------
def _env_names():
    from repro.envs import registry
    return registry.names()


def _setup(env_name, n_side, *, horizon=32):
    from repro.core import influence
    from repro.envs import registry
    from repro.marl import policy, ppo
    env_mod, env_cfg = registry.make(env_name, side=n_side, horizon=horizon)
    info = env_cfg.info()
    pc = policy.PolicyConfig(obs_dim=info.obs_dim, n_actions=info.n_actions,
                             hidden=(64, 64))
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence,
                             kind="fnn", hidden=(32, 32), epochs=10,
                             batch=64, lr=1e-3)
    ppo_cfg = ppo.PPOConfig()
    return env_mod, env_cfg, info, pc, ac, ppo_cfg


def fig3_learning(fast: bool = False, shards=None, async_collect=False,
                  use_kernels="auto", sharded_gs="auto",
                  collect_streams=None):
    """GS vs DIALS vs untrained-DIALS mean return (4-agent envs)."""
    from repro.core import dials
    from repro.launch import variants
    from repro.marl import runner
    rows = []
    rounds = 3 if fast else 10
    inner = 10 if fast else 40
    for env_name in _env_names():
        env_mod, env_cfg, info, pc, ac, ppo_cfg = _setup(env_name, 2)
        # --- DIALS and untrained-DIALS
        for untrained in (False, True):
            cfg = dials.DIALSConfig(
                outer_rounds=rounds, aip_refresh=inner, collect_envs=8,
                collect_steps=64, n_envs=8, rollout_steps=16,
                untrained=untrained, eval_episodes=8,
                use_kernels=use_kernels,
                **variants.dials_variant_for(shards, async_collect,
                                             sharded_gs,
                                             streams=collect_streams))
            tr = dials.DIALSTrainer(env_mod, env_cfg, pc, ac, ppo_cfg, cfg)
            t0 = time.time()
            _, hist = tr.run(jax.random.PRNGKey(0))
            label = ("untrained-DIALS" if untrained else "DIALS") \
                + f"-{env_name}"
            rows.append({"label": label,
                         "final_gs_return": hist[-1]["gs_return"],
                         "best_gs_return": max(h["gs_return"] for h in hist),
                         "aip_ce_final": hist[-1]["aip_ce_after"],
                         "wall_s": time.time() - t0})
        # --- GS baseline: same number of env steps
        init_fn, train_fn, eval_fn = runner.make_gs_trainer(
            env_mod, env_cfg, pc, ppo_cfg,
            runner.RunConfig(n_envs=8, rollout_steps=16))
        state = init_fn(jax.random.PRNGKey(0))
        t0 = time.time()
        for _ in range(rounds * inner):
            state, _m = train_fn(state)
        ret = float(eval_fn(state["params"], jax.random.PRNGKey(1),
                            episodes=8))
        rows.append({"label": f"GS-{env_name}", "final_gs_return": ret,
                     "wall_s": time.time() - t0})
    _emit(rows, "fig3_learning")
    return rows


def fig3_scalability(fast: bool = False):
    """Per-iteration runtime vs number of agents. The paper's claim:
    GS cost grows with system size; DIALS per-agent work is ~flat (the
    agent axis is vmapped/shardable, and between AIP refreshes there is
    zero cross-agent work)."""
    from repro.core import ials as ials_mod, influence
    from repro.marl import runner
    rows = []
    sides = (2, 3) if fast else (2, 3, 4, 5)
    for env_name in _env_names():
        for side in sides:
            env_mod, env_cfg, info, pc, ac, ppo_cfg = _setup(env_name, side)
            n = info.n_agents
            # GS trainer iteration
            init_fn, train_fn, _ = runner.make_gs_trainer(
                env_mod, env_cfg, pc, ppo_cfg,
                runner.RunConfig(n_envs=4, rollout_steps=16))
            state = init_fn(jax.random.PRNGKey(0))
            state, _ = train_fn(state)                  # compile
            t0 = time.time()
            for _ in range(3):
                state, _ = train_fn(state)
            jax.block_until_ready(state["params"])
            gs_it = (time.time() - t0) / 3
            # IALS trainer iteration (the DIALS inner loop)
            iinit, itrain = ials_mod.make_ials_trainer(
                env_mod, env_cfg, pc, ac, ppo_cfg, n_envs=4,
                rollout_steps=16)
            istate = iinit(jax.random.PRNGKey(0))
            aips = jax.vmap(lambda k: influence.aip_init(k, ac))(
                jax.random.split(jax.random.PRNGKey(1), n))
            istate, _ = itrain(istate, aips)            # compile
            t0 = time.time()
            for _ in range(3):
                istate, _ = itrain(istate, aips)
            jax.block_until_ready(istate["params"])
            ials_it = (time.time() - t0) / 3
            rows.append({"label": f"{env_name}-{n}agents",
                         "n_agents": n,
                         "gs_iter_s": gs_it,
                         "dials_iter_s": ials_it,
                         # per-agent: the distributed-deployment number —
                         # one process per agent runs 1/n of this program
                         "dials_iter_per_agent_s": ials_it / n,
                         "speedup_at_scale": gs_it / (ials_it / n)})
    _emit(rows, "fig3_scalability")
    return rows


def fig4_f_sweep(fast: bool = False, shards=None, async_collect=False,
                 use_kernels="auto", sharded_gs="auto"):
    """AIP training frequency F: returns + influence CE (paper Fig. 4)."""
    from repro.core import dials
    from repro.launch import variants
    rows = []
    total_inner = 12 if fast else 60
    sweeps = ((2, 6), (6, 2), (total_inner, 1)) if fast else \
        ((5, 12), (15, 4), (30, 2), (60, 1))
    env_mod, env_cfg, info, pc, ac, ppo_cfg = _setup("warehouse", 2)
    for refresh, rounds in sweeps:
        cfg = dials.DIALSConfig(
            outer_rounds=rounds, aip_refresh=refresh, collect_envs=8,
            collect_steps=64, n_envs=8, rollout_steps=16, eval_episodes=8,
            use_kernels=use_kernels,
            **variants.dials_variant_for(shards, async_collect,
                                             sharded_gs))
        tr = dials.DIALSTrainer(env_mod, env_cfg, pc, ac, ppo_cfg, cfg)
        t0 = time.time()
        _, hist = tr.run(jax.random.PRNGKey(0))
        rows.append({"label": f"F={refresh}x{rounds}",
                     "refresh": refresh,
                     "final_gs_return": hist[-1]["gs_return"],
                     "aip_ce_final": hist[-1]["aip_ce_after"],
                     "wall_s": time.time() - t0})
    _emit(rows, "fig4_f_sweep")
    return rows


def table_lemma2(fast: bool = False):
    """Empirical Lemma-2 certificates: ξ vs |Q1-Q2| vs bound."""
    from repro.core import ialm, theory
    rows = []
    rng = np.random.default_rng(0)
    T1, T2, R, pi2, b0 = ialm.random_system(rng)
    base = ialm.exact_influence(T1, T2, pi2, b0)
    nu = T1.shape[1]
    for eps in (0.0, 0.05, 0.1, 0.2, 0.4):
        pert = theory.perturbed_influence(base, eps, nu)
        cert = theory.lemma2_certificate(
            T1, R, horizon=4, influence1=base, influence2=pert,
            policy=lambda l: np.full((T1.shape[2],), 1 / T1.shape[2]))
        rows.append({"label": f"eps={eps}", "xi": cert["xi"],
                     "lhs_maxQdiff": cert["lhs"], "bound": cert["bound"],
                     "holds": int(cert["holds"])})
    _emit(rows, "table_lemma2")
    return rows


def table_memory(fast: bool = False):
    """Paper Table 3 analogue: state bytes of GS vs per-agent LS."""
    from repro.envs import registry
    rows = []
    for side in (2, 5, 7, 10):
        for env_name in _env_names():
            mod, cfg = registry.make(env_name, side=side)
            gs = mod.gs_init(jax.random.PRNGKey(0), cfg)
            ls = mod.ls_init(jax.random.PRNGKey(0), cfg)
            bytes_of = lambda t: sum(x.size * x.dtype.itemsize
                                     for x in jax.tree.leaves(t))
            n = cfg.n_agents
            rows.append({"label": f"{env_name}-{n}agents",
                         "n_agents": n,
                         "gs_state_bytes": bytes_of(gs),
                         "ls_state_bytes_per_agent": bytes_of(ls),
                         "ls_total_bytes": bytes_of(ls) * n})
    _emit(rows, "table_memory")
    return rows


def roofline_table(fast: bool = False):
    """§Roofline: three terms per dry-run cell on disk (experiments/dryrun)."""
    from benchmarks import roofline
    rows = []
    for fn in sorted(glob.glob("experiments/dryrun/*.json")):
        rec = json.load(open(fn))
        if rec.get("status") != "ok":
            continue
        t = roofline.terms(**roofline.per_device(rec))
        rows.append({"label": os.path.basename(fn)[:-5],
                     "arch": rec["arch"], "shape": rec["shape"],
                     "mesh": rec["mesh"], "variant": rec.get("variant"),
                     **{k: v for k, v in t.items()}})
    _emit(rows, "roofline")
    return rows


BENCHES = {
    "fig3_learning": fig3_learning,
    "fig3_scalability": fig3_scalability,
    "fig4_f_sweep": fig4_f_sweep,
    "table_lemma2": table_lemma2,
    "table_memory": table_memory,
    "roofline": roofline_table,
}


def main() -> None:
    import inspect
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="reduced iteration counts (CI mode)")
    ap.add_argument("--shards", type=int, default=None,
                    help="DIALS runtime shard count (needs that many XLA "
                         "devices; None = auto, 1 = unfused path)")
    ap.add_argument("--async-collect", action="store_true",
                    help="overlap each round's GS collect with the "
                         "previous round's inner steps (one-round "
                         "dataset lag, bounded by max_aip_staleness)")
    ap.add_argument("--use-kernels", default="auto",
                    choices=("auto", "on", "off"),
                    help="Pallas fast paths for the AIP/policy GRU and "
                         "GAE (auto = kernel on TPU, oracle elsewhere; "
                         "on = interpret-mode kernels off-TPU)")
    ap.add_argument("--sharded-gs", default="auto",
                    choices=("auto", "on", "off"),
                    help="region-decomposed GS collect/eval on the mesh "
                         "(auto = whenever the env partition supports "
                         "the shard count)")
    ap.add_argument("--collect-streams", type=int, default=None,
                    help="GS env-stream count S for the DIALS cells "
                         "(wide vmapped collect; None = collect_envs)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture an XLA profiler trace of the whole "
                         "sweep into this directory "
                         "(jax.profiler.start_trace; inspect with "
                         "TensorBoard/xprof — repro.obs.trace spans "
                         "appear as TraceAnnotations)")
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("name,metric,value")
    from repro.obs import trace as obs_trace
    with obs_trace.profile(args.profile_dir):
        for n in names:
            fn = BENCHES[n]
            kw = {"fast": args.fast}
            if "shards" in inspect.signature(fn).parameters:
                kw["shards"] = args.shards
            if "async_collect" in inspect.signature(fn).parameters:
                kw["async_collect"] = args.async_collect
            if "use_kernels" in inspect.signature(fn).parameters:
                kw["use_kernels"] = args.use_kernels
            if "sharded_gs" in inspect.signature(fn).parameters:
                kw["sharded_gs"] = args.sharded_gs
            if "collect_streams" in inspect.signature(fn).parameters:
                kw["collect_streams"] = args.collect_streams
            fn(**kw)
    if args.profile_dir:
        print(f"# profiler trace written to {args.profile_dir}")


if __name__ == "__main__":
    main()
