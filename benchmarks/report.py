"""Render the §Dry-run / §Roofline markdown tables from the dry-run JSON
records (experiments/dryrun/*.json).

Usage: PYTHONPATH=src python -m benchmarks.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json

from benchmarks import roofline

ARCH_ORDER = ["yi-34b", "gemma2-9b", "tinyllama-1.1b", "qwen1.5-32b",
              "zamba2-1.2b", "granite-moe-1b-a400m", "dbrx-132b",
              "whisper-tiny", "llama-3.2-vision-90b", "mamba2-780m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def load(mesh: str, variant: str = "baseline"):
    recs = {}
    for fn in glob.glob(f"experiments/dryrun/*__{mesh}__{variant}.json"):
        r = json.load(open(fn))
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_row(rec):
    e = rec.get("cost_extrapolated", {})
    if "flops" not in e:                    # fall back to raw (non-scan)
        e = {"flops": rec["cost"]["flops"],
             "bytes_accessed": rec["cost"]["bytes_accessed"],
             "collective_bytes": rec["collectives"]["total_bytes"]}
    t = roofline.terms(flops=e["flops"], bytes_accessed=e["bytes_accessed"],
                       collective_bytes=e["collective_bytes"], n_devices=1)
    mf = rec.get("model_flops_global")
    ratio = (mf / rec["n_devices"] / e["flops"]) if mf else None
    return t, ratio


def dryrun_table(mesh):
    recs = load(mesh)
    print(f"\n### Dry-run — {mesh} mesh "
          f"({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips)\n")
    print("| arch | shape | status | compile_s | temp GiB/dev |"
          " HLO GFLOPs/dev (scan-corrected) | collective GiB/dev |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | SKIP — {r['reason']} | | | | |")
                continue
            e = r.get("cost_extrapolated", {})
            fl = e.get("flops", r["cost"]["flops"])
            cb = e.get("collective_bytes",
                       r["collectives"]["total_bytes"])
            print(f"| {a} | {s} | ok | {r['compile_s']} |"
                  f" {r['memory']['temp_bytes']/2**30:.2f} |"
                  f" {fl/1e9:,.0f} | {cb/2**30:.2f} |")


def roofline_table(mesh):
    recs = load(mesh)
    print(f"\n### Roofline — {mesh} mesh, per-device terms\n")
    print("| arch | shape | compute | memory | collective | bottleneck |"
          " MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None or r["status"] != "ok":
                continue
            t, ratio = roofline_row(r)
            print(f"| {a} | {s} | {_fmt_s(t['compute_s'])} |"
                  f" {_fmt_s(t['memory_s'])} |"
                  f" {_fmt_s(t['collective_s'])} | {t['bottleneck']} |"
                  f" {ratio:.3f} |" if ratio is not None else
                  f"| {a} | {s} | ... |", end="")
            print(f" {t['roofline_fraction']:.3f} |")


def variant_compare(arch, shape, mesh, variants):
    print(f"\n### {arch} × {shape} × {mesh} — variants\n")
    print("| variant | compute | memory | collective | bottleneck |")
    print("|---|---|---|---|---|")
    for v in variants:
        try:
            r = json.load(open(
                f"experiments/dryrun/{arch}__{shape}__{mesh}__{v}.json"))
        except FileNotFoundError:
            continue
        if r["status"] != "ok":
            continue
        t, _ = roofline_row(r)
        print(f"| {v} | {_fmt_s(t['compute_s'])} | {_fmt_s(t['memory_s'])} |"
              f" {_fmt_s(t['collective_s'])} | {t['bottleneck']} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--what", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    if args.what in ("all", "dryrun"):
        dryrun_table(args.mesh)
    if args.what in ("all", "roofline"):
        roofline_table(args.mesh)


if __name__ == "__main__":
    main()
