"""Benchmark regression gate — freshly produced bench JSON vs committed
baseline.

CI produces ``BENCH_dials_scaling.json`` / ``BENCH_kernels.json`` with
the smoke runs and then calls this script; it fails (exit 1) when

* a row present in the baseline is missing from the fresh artifact
  (unless ``--subset`` — the kernels ``--fast`` smoke legitimately runs
  fewer shapes than the committed full run),
* a column present in a baseline row is missing from the matching fresh
  row, or a cell that is non-null in the baseline comes back null
  (a silently vanished measurement — e.g. the sharded-GS column going
  null because a partition stopped tiling),
* a fresh row violates its typed schema from ``repro.obs.metrics``
  (``SCALING_ROW_SCHEMA`` / ``KERNELS_MICRO_SCHEMA`` /
  ``KERNELS_E2E_SCHEMA``) — unknown columns, missing required columns,
  nulls or wrong types where the schema forbids them; the gate and
  live runtime telemetry validate against the same module,
* throughput regresses by more than ``--max-regression`` (default 25%)
  on any comparable cell. Time-valued cells are compared as 1/t.
  Cells are comparable only when the rows agree on their shape/config
  columns (``B/T/in/H`` for kernel micro rows; scaling rows and
  end-to-end kernel rows embed sizes in the label) — a ``--fast`` row
  that re-uses a label at a smaller shape is structure-checked, never
  time-compared. Every regression message carries the row's phase
  breakdown (``metrics.phase_breakdown``) so the report says *where*
  the regressed cell's time goes, not just that it regressed.

Baselines default to ``git show HEAD:<path>`` so the gate always diffs
against what the commit under test claims; ``--baseline FILE`` overrides
for local experiments.

    PYTHONPATH=src python -m benchmarks.check_bench --which scaling
    PYTHONPATH=src python -m benchmarks.check_bench --which kernels --subset
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import metrics  # noqa: E402

SPECS = {
    "scaling": {
        "path": os.path.join("experiments", "bench",
                             "BENCH_dials_scaling.json"),
        "key": lambda r: r["label"],
        "rows": lambda doc: doc,
        # higher-better cells gated on regression; everything else is
        # structure-checked only (ratio columns bounce with machine
        # load; a vanished cell is the real signal)
        "throughput": ("inner_steps_per_s", "inner_steps_per_s_async",
                       "env_steps_per_s"),
        "times": (),
        "shape_cols": ("n_agents", "shards", "processes", "streams"),
        "schema": lambda r: metrics.SCALING_ROW_SCHEMA,
    },
    "kernels": {
        "path": "BENCH_kernels.json",
        "key": lambda r: (r.get("kernel") or r.get("program"), r["label"]),
        "rows": lambda doc: doc["micro"] + doc["end_to_end"],
        "throughput": (),
        # lower-better: compared as 1/t
        "times": ("fwd_kernel_s", "fwdbwd_kernel_s", "kernel_s"),
        "shape_cols": ("B", "T", "in", "H"),
        # micro rows carry "kernel", end-to-end rows carry "program"
        "schema": lambda r: (metrics.KERNELS_MICRO_SCHEMA
                             if "kernel" in r else
                             metrics.KERNELS_E2E_SCHEMA),
    },
}


def _load_baseline(path: str, baseline: str):
    if baseline != "git:HEAD":
        with open(baseline) as f:
            return json.load(f)
    out = subprocess.run(["git", "show", f"HEAD:{path}"],
                         capture_output=True, text=True)
    if out.returncode != 0:
        raise SystemExit(f"no committed baseline for {path}: "
                         f"{out.stderr.strip()}")
    return json.loads(out.stdout)


def _shapes_match(spec, base_row, fresh_row) -> bool:
    return all(base_row.get(c) == fresh_row.get(c)
               for c in spec["shape_cols"])


def check(which: str, fresh_path: str, baseline: str, *,
          max_regression: float, subset: bool):
    spec = SPECS[which]
    with open(fresh_path) as f:
        fresh_doc = json.load(f)
    base_doc = _load_baseline(spec["path"], baseline)
    fresh = {spec["key"](r): r for r in spec["rows"](fresh_doc)}
    base = {spec["key"](r): r for r in spec["rows"](base_doc)}
    if not fresh:
        return [f"{fresh_path}: no rows produced"]

    problems = []
    # schema gate: every fresh row must be a valid typed record — an
    # unknown or missing column fails fast before any timing comparison
    for key, frow in sorted(fresh.items(), key=str):
        for p in metrics.validate_bench_row(frow, spec["schema"](frow)):
            problems.append(f"{key}: {p}")
    if problems:
        return problems
    compared = 0
    for key, brow in sorted(base.items(), key=str):
        frow = fresh.get(key)
        if frow is None:
            if not subset:
                problems.append(f"{key}: row missing from fresh artifact")
            continue
        for col, bval in brow.items():
            if col not in frow:
                problems.append(f"{key}: column {col!r} missing")
                continue
            if bval is not None and frow[col] is None:
                problems.append(f"{key}: cell {col!r} went null "
                                f"(baseline {bval})")
        if not _shapes_match(spec, brow, frow):
            continue                      # different shape: structure only
        for col, lower_better in (
                [(c, False) for c in spec["throughput"]] +
                [(c, True) for c in spec["times"]]):
            bval, fval = brow.get(col), frow.get(col)
            if not (isinstance(bval, (int, float)) and
                    isinstance(fval, (int, float)) and bval > 0 and
                    fval > 0):
                continue
            tp_base, tp_fresh = ((1.0 / bval, 1.0 / fval)
                                 if lower_better else (bval, fval))
            regression = 1.0 - tp_fresh / tp_base
            compared += 1
            if regression > max_regression:
                problems.append(
                    f"{key}: {col} regressed {regression:.0%} "
                    f"(baseline {bval:.6g}, fresh {fval:.6g}, "
                    f"allowed {max_regression:.0%}; phases: "
                    f"{metrics.phase_breakdown(frow, spec['schema'](frow))})")
    print(f"# check_bench {which}: {len(base)} baseline rows, "
          f"{len(fresh)} fresh rows, {compared} timing cells compared")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", required=True, choices=sorted(SPECS))
    ap.add_argument("--fresh", default=None,
                    help="fresh artifact (default: the canonical output "
                         "path of the producing benchmark)")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="baseline file, or git:HEAD for the committed "
                         "artifact (default)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="maximum tolerated throughput regression "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--subset", action="store_true",
                    help="tolerate baseline rows absent from the fresh "
                         "artifact (smoke runs sweeping fewer shapes)")
    args = ap.parse_args()
    fresh_path = args.fresh or SPECS[args.which]["path"]
    problems = check(args.which, fresh_path, args.baseline,
                     max_regression=args.max_regression,
                     subset=args.subset)
    # shared formatter with the static-analysis gate: plain
    # TAG file [rule] lines locally, ::error annotations in CI
    from repro.analysis.report import Finding, emit
    if emit([Finding(tag="REGRESSION", rule="BenchRegression",
                     message=p, file=fresh_path) for p in problems]):
        return 1
    print(f"# check_bench {args.which}: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
