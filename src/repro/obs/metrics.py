"""Typed record schemas for DIALS runtime telemetry.

This module is the single source of truth for every record the runtime
emits: the per-round record both drivers produce, the envelope the
JSONL sinks wrap events in, and the benchmark-row schemas
``benchmarks/check_bench.py`` gates against. Free-form dicts drifted
between the loop and sharded drivers (the ``aip_refresh == 0`` loop
branch simply dropped keys); everything now goes through
:func:`round_record`, which enforces the exact key set and coerces
values to host scalars.

Round-record schema (one JSONL event per outer Algorithm-1 round, field
order is :data:`ROUND_FIELDS`):

======================  =======  ========  =====================================
field                   type     nullable  meaning
======================  =======  ========  =====================================
``round``               int      no        outer round index (0-based)
``gs_return``           float    no        mean GS evaluation return
``ials_reward``         float    yes       mean inner-loop reward of the last
                                           IALS step (null when
                                           ``aip_refresh == 0`` — no inner
                                           steps ran)
``aip_ce_before``       float    no        influence CE before the AIP refresh
``aip_ce_after``        float    no        influence CE after the AIP refresh
``data_round``          int      no        collection round of the dataset
                                           trained on this round
``forced_sync``         bool     no        async collect fell back to a
                                           synchronous collect
                                           (``max_aip_staleness`` exceeded)
``stale_forced``        int      no        agents force-refreshed by the
                                           freshness gate this round
``staleness_min``       int      no        min over agents of
                                           ``round - report_round`` (data-round
                                           lag), computed on-mesh
``staleness_mean``      float    no        mean data-round lag over agents
``staleness_max``       int      no        max data-round lag over agents
``n_shards``            int      no        shards in the mesh this round
                                           (1 on the unfused loop path)
``reassigned``          int      no        agent blocks moved by elastic
                                           replanning this round
``dead_hosts``          list     no        hosts declared dead this round
                                           (empty most rounds)
``kernels``             str      no        resolved kernel dispatch, e.g.
                                           ``policy=pallas,aip=oracle,...``
``collect_s``           float    yes       GS collect seconds (loop path: real
                                           span; sharded async: obtain wait;
                                           null when fused into the round
                                           program)
``env_steps_per_s``     float    yes       GS env-steps simulated per second,
                                           ``S * collect_steps / collect_s``
                                           (loop sync path only — null when
                                           the collect is async-overlapped or
                                           fused, where the span is not a
                                           throughput)
``aip_s``               float    yes       AIP-refresh seconds (loop path only)
``inner_s``             float    yes       F inner IALS+PPO steps seconds
                                           (loop path only)
``eval_s``              float    yes       GS evaluation seconds (loop path
                                           only)
``mirror_s``            float    yes       host-mirror ``fetch_tree`` seconds —
                                           the elasticity availability tax
                                           (null when elasticity is off)
``round_s``             float    no        wall seconds for this round
``wall_s``              float    no        cumulative wall seconds since run
                                           start (monotone per process)
======================  =======  ========  =====================================

Null phase columns are *explicit*: the sharded driver runs the whole
round as one fused jitted program, so per-phase host timings do not
exist there — the record says so with ``null`` rather than omitting the
key. Unfenced spans measure dispatch-enqueue time (JAX is async);
``DIALSConfig.telemetry_fence`` buys honest device timings at the cost
of extra host syncs and is therefore off by default.

Sink envelope: every JSONL line carries ``event`` (record type, e.g.
``"round"``, ``"host_death"``, ``"elastic_reassign"``), ``proc``
(emitting process index), ``seq`` (per-process monotone counter) and
``t`` (unix seconds) in addition to the payload —
:data:`ENVELOPE_FIELDS`, ignored by :func:`validate_round`.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

# (name, type, nullable) — order is the canonical (CSV) column order
ROUND_FIELDS: Tuple[Tuple[str, type, bool], ...] = (
    ("round", int, False),
    ("gs_return", float, False),
    ("ials_reward", float, True),
    ("aip_ce_before", float, False),
    ("aip_ce_after", float, False),
    ("data_round", int, False),
    ("forced_sync", bool, False),
    ("stale_forced", int, False),
    ("staleness_min", int, False),
    ("staleness_mean", float, False),
    ("staleness_max", int, False),
    ("n_shards", int, False),
    ("reassigned", int, False),
    ("dead_hosts", list, False),
    ("kernels", str, False),
    ("collect_s", float, True),
    ("env_steps_per_s", float, True),
    ("aip_s", float, True),
    ("inner_s", float, True),
    ("eval_s", float, True),
    ("mirror_s", float, True),
    ("round_s", float, False),
    ("wall_s", float, False),
)

ROUND_KEYS: Tuple[str, ...] = tuple(f[0] for f in ROUND_FIELDS)
ROUND_PHASES: Tuple[str, ...] = ("collect_s", "aip_s", "inner_s",
                                 "eval_s", "mirror_s")

ENVELOPE_FIELDS: Tuple[str, ...] = ("event", "proc", "seq", "t")


def _coerce(name: str, typ: type, value):
    if typ is bool:
        return bool(value)
    if typ is int:
        return int(value)
    if typ is float:
        return float(value)
    if typ is list:
        return [int(v) for v in value]
    return str(value)             # typ is str


def round_record(**fields) -> Dict:
    """Build a validated round record: the key set must be exactly
    :data:`ROUND_KEYS`, nulls only on nullable fields, values coerced to
    host scalars (device scalars accepted — ``int``/``float`` pull them
    to host, which is the driver's one deliberate sync point)."""
    extra = set(fields) - set(ROUND_KEYS)
    if extra:
        raise TypeError(f"unknown round-record fields: {sorted(extra)}")
    missing = set(ROUND_KEYS) - set(fields)
    if missing:
        raise TypeError(f"missing round-record fields: {sorted(missing)}")
    rec = {}
    for name, typ, nullable in ROUND_FIELDS:
        value = fields[name]
        if value is None:
            if not nullable:
                raise TypeError(f"round-record field {name!r} is not "
                                f"nullable")
            rec[name] = None
        else:
            rec[name] = _coerce(name, typ, value)
    return rec


def validate_round(rec: Dict, *, ignore=ENVELOPE_FIELDS) -> List[str]:
    """Problems (empty list = valid) with a round record, e.g. one read
    back from a JSONL sink. Envelope fields are ignored."""
    problems = []
    got = {k for k in rec if k not in ignore}
    for k in sorted(got - set(ROUND_KEYS)):
        problems.append(f"unknown field {k!r}")
    for k in sorted(set(ROUND_KEYS) - got):
        problems.append(f"missing field {k!r}")
    for name, typ, nullable in ROUND_FIELDS:
        if name not in rec:
            continue
        value = rec[name]
        if value is None:
            if not nullable:
                problems.append(f"field {name!r} is null but not nullable")
            continue
        ok = (isinstance(value, bool) if typ is bool else
              isinstance(value, int) and not isinstance(value, bool)
              if typ is int else
              isinstance(value, (int, float)) and not isinstance(value,
                                                                 bool)
              if typ is float else
              isinstance(value, typ))
        if not ok:
            problems.append(f"field {name!r}: expected {typ.__name__}, "
                            f"got {type(value).__name__} ({value!r})")
    return problems


def staleness_stats(reports, current_round):
    """Per-agent data-round lag distribution, as traced jnp scalars.

    ``reports`` is the on-mesh per-agent vector of collection rounds of
    the newest dataset each agent has trained on (see
    ``fault.freshness_gate``); the lag is ``current_round - reports``.
    Safe inside the fused round program *outside* the ``shard_map`` body
    (a cross-shard reduction, like the CE means) — the results ride the
    existing once-per-round record fetch, adding zero host syncs.
    """
    import jax.numpy as jnp
    lag = jnp.asarray(current_round, jnp.int32) - \
        jnp.asarray(reports, jnp.int32)
    return {"staleness_min": lag.min(), "staleness_mean":
            lag.astype(jnp.float32).mean(), "staleness_max": lag.max()}


def kernel_summary(policy_cfg, aip_cfg, ppo_cfg) -> str:
    """Resolved kernel-dispatch decisions as a compact string, e.g.
    ``"policy=pallas,aip=oracle,ppo=pallas-interpret"``."""
    from repro.kernels import dispatch

    def word(cfg):
        d = dispatch.resolve(cfg.use_kernels)
        if not d.use:
            return "oracle"
        return "pallas-interpret" if d.interpret else "pallas"

    return ",".join(f"{n}={word(c)}" for n, c in
                    (("policy", policy_cfg), ("aip", aip_cfg),
                     ("ppo", ppo_cfg)))


# ---------------------------------------------------------------------------
# benchmark-row schemas (gated by benchmarks/check_bench.py)
# ---------------------------------------------------------------------------
# column -> (allowed types, required, nullable)
_NUM = (int, float)

SCALING_ROW_SCHEMA = {
    "name": "scaling",
    "columns": {
        "label": (str, True, False),
        "scenario": (str, True, False),
        "n_agents": (int, True, False),
        "shards": (int, True, False),
        "processes": (int, True, False),
        "streams": (int, True, False),
        "fused": (bool, True, False),
        "round_s": (_NUM, True, False),
        "round_s_async": (_NUM, True, False),
        "overlap_speedup": (_NUM, True, False),
        "inner_steps_per_s": (_NUM, True, False),
        "inner_steps_per_s_async": (_NUM, True, False),
        "total_wall_s": (_NUM, True, False),
        "total_wall_s_async": (_NUM, True, False),
        "collect_s": (_NUM, True, False),
        "env_steps_per_s": (_NUM, True, False),
        # null where the env topology cannot tile the shard count
        "collect_s_sharded_gs": (_NUM, True, True),
        "gs_speedup": (_NUM, True, True),
        # only present once the shards=1 baseline has run (P=1 cells)
        "speedup_vs_unfused": (_NUM, False, False),
    },
    "phases": ("round_s", "round_s_async", "collect_s",
               "collect_s_sharded_gs"),
}

KERNELS_MICRO_SCHEMA = {
    "name": "kernels.micro",
    "columns": {
        "kernel": (str, True, False),
        "label": (str, True, False),
        "B": (int, True, False),
        "T": (int, True, False),
        # gru rows only; gae rows have no input/hidden width
        "in": (int, False, False),
        "H": (int, False, False),
        "fwd_oracle_s": (_NUM, True, False),
        "fwd_kernel_s": (_NUM, True, False),
        "fwdbwd_oracle_s": (_NUM, True, False),
        "fwdbwd_kernel_s": (_NUM, True, False),
        "speedup_fwd": (_NUM, True, False),
        "speedup_fwdbwd": (_NUM, True, False),
        "roofline_fwd": (dict, True, False),
        "roofline_fwdbwd": (dict, True, False),
    },
    "phases": ("fwd_oracle_s", "fwd_kernel_s", "fwdbwd_oracle_s",
               "fwdbwd_kernel_s"),
}

KERNELS_E2E_SCHEMA = {
    "name": "kernels.end_to_end",
    "columns": {
        "program": (str, True, False),
        "label": (str, True, False),
        "oracle_s": (_NUM, True, False),
        "kernel_s": (_NUM, True, False),
        "speedup": (_NUM, True, False),
    },
    "phases": ("oracle_s", "kernel_s"),
}


def validate_bench_row(row: Dict, schema: Dict) -> List[str]:
    """Problems with one benchmark row against a ``*_ROW_SCHEMA`` /
    ``KERNELS_*_SCHEMA``: unknown columns, missing required columns,
    non-null cells of the wrong type, nulls in non-nullable cells."""
    cols = schema["columns"]
    name = schema["name"]
    problems = []
    for k in sorted(set(row) - set(cols)):
        problems.append(f"[{name}] unknown column {k!r}")
    for k, (_, required, _n) in cols.items():
        if required and k not in row:
            problems.append(f"[{name}] missing column {k!r}")
    for k, value in row.items():
        if k not in cols:
            continue
        types, _required, nullable = cols[k]
        if value is None:
            if not nullable:
                problems.append(f"[{name}] column {k!r} is null")
            continue
        if types is bool or types is int:
            ok = isinstance(value, types) and (types is bool or
                                               not isinstance(value, bool))
        elif types is _NUM:
            ok = isinstance(value, _NUM) and not isinstance(value, bool)
        else:
            ok = isinstance(value, types)
        if not ok:
            tn = types.__name__ if isinstance(types, type) else "number"
            problems.append(f"[{name}] column {k!r}: expected {tn}, got "
                            f"{type(value).__name__} ({value!r})")
    return problems


def phase_breakdown(row: Dict, schema: Dict) -> str:
    """Compact ``col=value`` phase summary of a bench row, for
    regression messages ("which cell regressed, and where its time
    goes")."""
    parts = []
    for col in schema.get("phases", ()):
        v = row.get(col)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            parts.append(f"{col}={v:.6g}")
        else:
            parts.append(f"{col}={v}")
    return " ".join(parts)
