"""Lightweight span tracer for the DIALS runtime.

Three layers of the same idea — "name the time", at three costs:

* **Host spans** (:class:`Tracer.span`) — nested context-manager spans on
  a monotonic clock (``time.perf_counter``). Each span records
  ``(name, depth, t0, dur_s)``; :meth:`Tracer.phase_seconds` aggregates
  them into the per-phase seconds the typed round record
  (``repro.obs.metrics``) carries. JAX dispatch is asynchronous, so an
  unfenced span around a jitted call measures *enqueue* time; pass
  ``fence=True`` to the tracer and call ``sp.fence(outputs)`` inside the
  span to ``jax.block_until_ready`` before the clock stops — honest
  device timings, at the cost of a host sync per fenced span. The
  drivers default to unfenced (their one-sync-per-round contract is
  load-bearing); benchmarks fence.
* **Trace-time annotations** (:func:`annotate`) — ``jax.named_scope``
  pass-through for code *inside* jitted programs (the per-shard train
  body, the halo exchange). Zero runtime cost: the scope names travel
  into HLO metadata so the regions are attributable in an XLA profile.
* **Profiler sessions** (:func:`profile`) — an opt-in
  ``jax.profiler.start_trace`` window (``--profile-dir`` on
  ``benchmarks/run.py`` / ``benchmarks/scaling.py``); host spans
  additionally enter ``jax.profiler.TraceAnnotation`` while a session
  may be live, so the same span names land on the profiler timeline.

The disabled path is :data:`NULL_TRACER`: its :meth:`~NullTracer.span`
returns one shared no-op span object (context entry is a constant-time
attribute access, nothing is allocated or recorded), so leaving tracer
calls in place costs nothing when telemetry is off.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional


def _jax_profiler():
    try:
        import jax
        return jax.profiler
    except Exception:             # pragma: no cover - jax always present
        return None


def annotate(name: str):
    """Trace-time scope naming for jitted code: ``jax.named_scope``
    pass-through (a no-op context manager on jax builds without it).
    Adds HLO metadata only — never a primitive, so the collective
    audits of ``repro.distributed.runtime`` see identical programs."""
    try:
        import jax
        return jax.named_scope(name)
    except (ImportError, AttributeError):   # pragma: no cover
        return contextlib.nullcontext()


@contextlib.contextmanager
def profile(directory: Optional[str]):
    """Opt-in XLA profiler session writing to ``directory`` (TensorBoard
    / xprof format). ``None`` is a no-op, so call sites can thread the
    ``--profile-dir`` flag through unconditionally."""
    if not directory:
        yield
        return
    prof = _jax_profiler()
    if prof is None:              # pragma: no cover
        yield
        return
    prof.start_trace(directory)
    try:
        yield
    finally:
        prof.stop_trace()


class Span:
    """One live span. ``fence(x)`` optionally blocks on device values so
    the span's duration covers real execution, then returns ``x``."""

    __slots__ = ("_tracer", "name", "depth", "t0")

    def __init__(self, tracer: "Tracer", name: str, depth: int, t0: float):
        self._tracer, self.name, self.depth, self.t0 = \
            tracer, name, depth, t0

    def fence(self, value):
        if self._tracer.fenced:
            import jax
            jax.block_until_ready(value)
        return value


class Tracer:
    """Records nested host spans; see module docstring."""

    def __init__(self, *, fenced: bool = False, clock=time.perf_counter):
        self.fenced = bool(fenced)
        self._clock = clock
        self._depth = 0
        self.events: List[Dict] = []

    @property
    def enabled(self) -> bool:
        return True

    @contextlib.contextmanager
    def span(self, name: str):
        prof = _jax_profiler()
        ann = (prof.TraceAnnotation(name)
               if prof is not None and hasattr(prof, "TraceAnnotation")
               else contextlib.nullcontext())
        depth, self._depth = self._depth, self._depth + 1
        with ann:
            t0 = self._clock()
            sp = Span(self, name, depth, t0)
            try:
                yield sp
            finally:
                dur = self._clock() - t0
                self._depth = depth
                # appended at exit: children land before their parent,
                # report/asserts re-nest via (t0, depth)
                self.events.append({"name": name, "depth": depth,
                                    "t0": t0, "dur_s": dur})

    def reset(self) -> None:
        self.events.clear()

    def phase_seconds(self) -> Dict[str, float]:
        """Total seconds per span name (top-level occurrences of a name
        sum; a name nested under itself would double-count — the runtime
        never does that)."""
        out: Dict[str, float] = {}
        for e in self.events:
            out[e["name"]] = out.get(e["name"], 0.0) + e["dur_s"]
        return out


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    @staticmethod
    def fence(value):
        return value


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: one shared no-op span, no state, no recording."""

    fenced = False
    events: List[Dict] = []       # intentionally shared + always empty

    @property
    def enabled(self) -> bool:
        return False

    def span(self, name: str):
        return _NULL_SPAN

    def reset(self) -> None:
        pass

    def phase_seconds(self) -> Dict[str, float]:
        return {}


NULL_TRACER = NullTracer()
