"""Runtime observability for the DIALS runtime.

``Telemetry`` is the facade the drivers and the multi-host stack talk
to: it owns a span :class:`~repro.obs.trace.Tracer`, a per-process
JSONL sink (``telemetry-p{PID}.jsonl`` in a shared directory — the
``fault.HostMonitor`` heartbeat-dir pattern), and any extra sinks
(terminal summary, CSV). Every emitted event gets an envelope —
``event`` kind, ``proc``, per-process monotone ``seq``, unix ``t`` —
so rank 0 can merge all processes' files into one globally ordered
``telemetry.jsonl`` (:func:`repro.obs.sinks.merge_dir`).

The disabled instance is :data:`DISABLED` (also what
:func:`maybe` returns for a ``None`` directory): ``emit`` is a no-op,
``span`` is the shared null span, and **no files are created** — the
drivers keep their telemetry calls unconditionally and pay nothing
when it is off. Crucially, telemetry is host-side only: enabling it
never changes the traced round program, so the sharded driver's
once-per-round host-sync contract is untouched (the on-mesh scalars
it reports — staleness stats, CE — ride the round record the driver
already fetches).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.obs import metrics, sinks
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer, annotate,
                             profile)

__all__ = ["Telemetry", "DISABLED", "maybe", "Tracer", "NullTracer",
           "NULL_TRACER", "annotate", "profile", "metrics", "sinks"]


def _default_process_id() -> int:
    try:
        import jax
        return jax.process_index()
    except Exception:             # pragma: no cover - jax always present
        return 0


class Telemetry:
    """Live telemetry: span tracer + per-process event sinks."""

    enabled = True

    def __init__(self, directory: str, *, process_id: int,
                 tracer: Tracer, extra_sinks=()):
        self.directory = directory
        self.process_id = process_id
        self.tracer = tracer
        self._seq = 0
        self._sinks: List = [sinks.JsonlSink(
            sinks.proc_path(directory, process_id))]
        self._sinks.extend(extra_sinks)

    @classmethod
    def create(cls, directory: str, *, process_id: Optional[int] = None,
               terminal: bool = False, csv: Optional[str] = None,
               fence: bool = False) -> "Telemetry":
        import os
        os.makedirs(directory, exist_ok=True)
        extra = []
        if terminal:
            extra.append(sinks.TerminalSink())
        if csv:
            extra.append(sinks.CsvSink(csv))
        pid = process_id if process_id is not None \
            else _default_process_id()
        return cls(directory, process_id=pid, tracer=Tracer(fenced=fence),
                   extra_sinks=extra)

    def emit(self, event: str, **fields) -> Dict:
        """Wrap ``fields`` in the envelope and write to every sink."""
        rec = {"event": event, "proc": self.process_id, "seq": self._seq,
               "t": time.time(), **fields}
        self._seq += 1
        for s in self._sinks:
            s.write(rec)
        return rec

    def emit_round(self, rec: Dict) -> Dict:
        """Emit a (already :func:`metrics.round_record`-typed) round
        record as a ``"round"`` event."""
        return self.emit("round", **rec)

    def span(self, name: str):
        return self.tracer.span(name)

    def phase_seconds(self) -> Dict[str, float]:
        return self.tracer.phase_seconds()

    def reset_spans(self) -> None:
        self.tracer.reset()

    def merge(self) -> str:
        """Merge every process's event file in this directory (call on
        rank 0, after the run)."""
        return sinks.merge_dir(self.directory)

    def close(self) -> None:
        for s in self._sinks:
            s.close()


class _NullTelemetry:
    """Disabled telemetry: no files, no state, no-op everything."""

    enabled = False
    directory = None
    process_id = 0
    tracer = NULL_TRACER

    def emit(self, event: str, **fields) -> None:
        return None

    def emit_round(self, rec: Dict) -> None:
        return None

    def span(self, name: str):
        return NULL_TRACER.span(name)

    def phase_seconds(self) -> Dict[str, float]:
        return {}

    def reset_spans(self) -> None:
        pass

    def merge(self) -> None:
        return None

    def close(self) -> None:
        pass


DISABLED = _NullTelemetry()


def maybe(directory: Optional[str], **kwargs):
    """`Telemetry.create(directory, ...)` when ``directory`` is set,
    :data:`DISABLED` otherwise — the one-liner the drivers use to honor
    an optional ``telemetry_dir`` config field."""
    if not directory:
        return DISABLED
    return Telemetry.create(directory, **kwargs)
