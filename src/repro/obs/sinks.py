"""Telemetry sinks — per-process JSONL event logs and their merge.

Mirrors the ``fault.HostMonitor`` heartbeat-dir pattern: every process
appends to its own ``telemetry-p{PID}.jsonl`` in a shared directory
(one JSON object per line, flushed per line so a SIGKILL'd host's
events survive up to the final, possibly truncated, line), and rank 0
merges all per-process files into one ``telemetry.jsonl`` ordered by
``(t, proc, seq)``. No cross-process coordination is needed to write —
only the merge reads other processes' files.

Also here: a terminal sink (compact one-line summaries for interactive
runs) and a CSV sink (round events only, columns in
``metrics.ROUND_FIELDS`` order, for spreadsheet-style analysis).
"""
from __future__ import annotations

import csv
import json
import os
from typing import Dict, Iterable, List, Optional

from repro.obs import metrics

MERGED_NAME = "telemetry.jsonl"


def proc_path(directory: str, process_id: int) -> str:
    """Per-process event-log path inside the shared telemetry dir."""
    return os.path.join(directory, f"telemetry-p{process_id}.jsonl")


def read_jsonl(path: str) -> List[Dict]:
    """Parse a JSONL event file, tolerating a truncated final line (a
    host killed mid-write) — complete lines before it are kept."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                break             # truncated tail; nothing valid follows
    return events


def merge_dir(directory: str, *, out: Optional[str] = None) -> str:
    """Merge every ``telemetry-p*.jsonl`` in ``directory`` into one
    globally ordered file (sort key ``(t, proc, seq)``) and return its
    path. Rank 0 calls this after a run; re-merging is idempotent."""
    names = sorted(n for n in os.listdir(directory)
                   if n.startswith("telemetry-p") and
                   n.endswith(".jsonl"))
    events: List[Dict] = []
    for name in names:
        events.extend(read_jsonl(os.path.join(directory, name)))
    events.sort(key=lambda e: (e.get("t", 0.0), e.get("proc", 0),
                               e.get("seq", 0)))
    out = out or os.path.join(directory, MERGED_NAME)
    with open(out, "w") as f:
        for e in events:
            f.write(json.dumps(e, default=float) + "\n")
    return out


class JsonlSink:
    """Append-only per-process JSONL writer (line-buffered + flushed:
    crash-safe up to the last line)."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self._f = open(path, "a")

    def write(self, event: Dict) -> None:
        self._f.write(json.dumps(event, default=float) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class TerminalSink:
    """One compact line per event on stdout — the interactive view of
    the same stream the JSONL sink persists."""

    def __init__(self, prefix: str = "telemetry"):
        self.prefix = prefix

    def write(self, event: Dict) -> None:
        kind = event.get("event", "?")
        if kind == "round":
            phases = " ".join(
                f"{p}={event[p]:.3f}s" for p in metrics.ROUND_PHASES
                if isinstance(event.get(p), (int, float)))
            line = (f"round {event.get('round')} "
                    f"return={event.get('gs_return'):.3f} "
                    f"ce={event.get('aip_ce_after'):.4f} "
                    f"lag<={event.get('staleness_max')} "
                    f"shards={event.get('n_shards')} "
                    f"round_s={event.get('round_s'):.3f}"
                    + (f" {phases}" if phases else ""))
        elif kind == "host_death":
            line = (f"host death at round {event.get('round')}: "
                    f"dead={event.get('dead_hosts')}")
        elif kind == "elastic_reassign":
            line = (f"elastic replan: shards "
                    f"{event.get('old_shards')}->{event.get('new_shards')}"
                    f" moved={event.get('moved')}")
        else:
            payload = {k: v for k, v in event.items()
                       if k not in metrics.ENVELOPE_FIELDS}
            line = f"{kind} {payload}"
        print(f"[{self.prefix} p{event.get('proc', 0)}] {line}")

    def close(self) -> None:
        pass


class CsvSink:
    """Round events as CSV, columns in ``metrics.ROUND_FIELDS`` order
    (``dead_hosts`` serialized as ``;``-joined host indices). Non-round
    events are skipped."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self._f = open(path, "w", newline="")
        self._w = csv.writer(self._f)
        self._w.writerow(("proc",) + metrics.ROUND_KEYS)

    def write(self, event: Dict) -> None:
        if event.get("event") != "round":
            return
        row = [event.get("proc", 0)]
        for name in metrics.ROUND_KEYS:
            v = event.get(name)
            row.append(";".join(str(h) for h in v)
                       if isinstance(v, list) else v)
        self._w.writerow(row)
        self._f.flush()

    def close(self) -> None:
        self._f.close()


def write_events(events: Iterable[Dict], sink) -> None:
    """Replay an event stream (e.g. a merged file) through a sink."""
    for e in events:
        sink.write(e)
