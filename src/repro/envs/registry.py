"""Environment registry — the env analogue of ``repro.configs.registry``.

Every local-form fPOSG environment module self-registers here (see the
bottom of ``traffic.py``/``warehouse.py``/``powergrid.py``/
``supplychain.py``), after which the whole stack — benchmarks, examples,
smoke tests and the exactness/conformance property suite — resolves it by
name. Adding a scenario is therefore a one-file change: write the module,
call :func:`register` at its bottom, import it from ``repro.envs``.

``register(name, module, default_cfg)`` also takes an optional ``sizer``
callback ``(cfg, side) -> cfg`` mapping the benchmarks' uniform "side"
knob onto the env's own size field (traffic ``n=side`` ⇒ side² agents,
powergrid ``n_buses=side²`` — so agent counts stay comparable across
envs in the scalability sweeps).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """A registered environment: its module, default config, and sizer."""
    name: str
    module: Any                      # module following the base.py protocol
    default_cfg: Any                 # frozen dataclass with .info()
    sizer: Callable[[Any, int], Any]


_ENVS: dict = {}


def register(name: str, module, default_cfg, *,
             sizer: Optional[Callable] = None) -> None:
    """Register an env module under ``name``. Idempotent re-registration
    of the same module is allowed (module reloads); clashes raise."""
    prev = _ENVS.get(name)
    if prev is not None and prev.module.__name__ != module.__name__:
        raise ValueError(f"env {name!r} already registered "
                         f"by {prev.module.__name__}")
    if sizer is None:
        sizer = lambda cfg, side: cfg
    _ENVS[name] = EnvSpec(name, module, default_cfg, sizer)


def _ensure_builtins() -> None:
    # importing the package runs the built-in modules' register() calls
    import repro.envs  # noqa: F401


def names() -> list:
    """Sorted names of every registered environment."""
    _ensure_builtins()
    return sorted(_ENVS)


def get(name: str) -> EnvSpec:
    _ensure_builtins()
    try:
        return _ENVS[name]
    except KeyError:
        raise KeyError(f"unknown env {name!r}; registered: {names()}") \
            from None


def make(name: str, *, side: Optional[int] = None, **overrides):
    """Resolve ``name`` to ``(module, cfg)``.

    ``side`` applies the env's sizer (uniform scale knob across envs);
    ``overrides`` are ``dataclasses.replace`` field overrides applied
    after sizing (e.g. ``horizon=32``).
    """
    spec = get(name)
    cfg = spec.default_cfg
    if side is not None:
        cfg = spec.sizer(cfg, side)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return spec.module, cfg
