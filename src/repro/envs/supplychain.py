"""Supply-chain env — a production line of workcells with finite buffers.

``n_cells`` agents form a line; cell i holds raw parts in an input store
and finished parts in an output buffer, both capped at ``buf``. Each
step a cell first tries to hand its oldest finished part downstream
(blocked when the downstream input store is full), then — if the agent
chooses to work, has a raw part, has output space and its machine did
not break down this step — converts one raw part into a finished one.
The line head receives raw parts from an external arrival process; the
tail ships into an infinite sink. Reward = parts shipped minus a small
work-in-progress holding cost (throughput vs inventory).

Cells are coupled ONLY through part hand-offs, so agent i's influence
sources are the two bits ``[upstream_handoff, downstream_backpressure]``
— both computed from the PRE-step global state, so conditioning on u
d-separates the cell from the rest of the line.

The per-cell transition :func:`cell_step` is shared verbatim between GS
and LS ⇒ IBA exactness by construction.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.envs import registry
from repro.envs.base import EnvInfo, contiguous_partition


@dataclasses.dataclass(frozen=True)
class SupplyChainConfig:
    n_cells: int = 4              # line length = number of agents
    buf: int = 4                  # capacity of input store AND output buffer
    p_arrival: float = 0.6        # raw-part arrival probability at the head
    p_break: float = 0.1          # per-step machine breakdown probability
    hold_cost: float = 0.02      # WIP holding cost per stored part
    horizon: int = 100

    @property
    def n_agents(self) -> int:
        return self.n_cells

    def info(self) -> EnvInfo:
        obs_dim = 2 * (self.buf + 1)
        return EnvInfo(name="supplychain", n_agents=self.n_agents,
                       obs_dim=obs_dim, n_actions=2, n_influence=2,
                       horizon=self.horizon, alsh_dim=obs_dim + 2)


# ---------------------------------------------------------------------------
# Shared per-cell transition (the \dot{T}_i of the IALM)
# ---------------------------------------------------------------------------
def cell_step(store, buffer, action, u, breakdown, cfg: SupplyChainConfig):
    """One workcell for one step.

    store, buffer: () int32 in [0, buf]; action: () in {0: idle, 1: work};
    u: (2,) bool — [upstream hand-off arrives, downstream backpressure];
    breakdown: () bool — exogenous machine-failure draw.

    Returns (new_store, new_buffer, reward, shipped).

    Invariants (given u's GS semantics — a hand-off only arrives when the
    store had space pre-step): both levels stay in [0, buf].
    """
    ub = u.astype(bool)
    # Gate the hand-off on store space: a no-op under GS semantics (the GS
    # only raises the bit when the store had room pre-step), but the IALS
    # loop drives this with AIP-sampled u, which must not push the local
    # state out of its [0, buf] domain.
    handoff_in, bp = ub[0] & (store < cfg.buf), ub[1]
    ship = (buffer > 0) & ~bp
    buf_after = buffer - ship.astype(jnp.int32)
    work = ((action.astype(jnp.int32) == 1) & (store > 0)
            & (buf_after < cfg.buf) & ~breakdown.astype(bool))
    work_i = work.astype(jnp.int32)
    new_store = store - work_i + handoff_in.astype(jnp.int32)
    new_buffer = buf_after + work_i
    reward = (ship.astype(jnp.float32)
              - cfg.hold_cost * (new_store + new_buffer).astype(jnp.float32))
    return new_store, new_buffer, reward, ship


def _obs(store, buffer, cfg: SupplyChainConfig):
    return jnp.concatenate([
        jax.nn.one_hot(store, cfg.buf + 1, dtype=jnp.float32),
        jax.nn.one_hot(buffer, cfg.buf + 1, dtype=jnp.float32),
    ])


# ---------------------------------------------------------------------------
# Global simulator
# ---------------------------------------------------------------------------
def gs_init(key, cfg: SupplyChainConfig):
    k1, k2 = jax.random.split(key)
    n = cfg.n_agents
    return {"store": jax.random.randint(k1, (n,), 0, cfg.buf + 1),
            "buffer": jax.random.randint(k2, (n,), 0, cfg.buf + 1),
            "t": jnp.zeros((), jnp.int32)}


def gs_exo(key, cfg: SupplyChainConfig):
    """Exogenous draws: per-cell breakdowns (N,) + head arrival ()."""
    k1, k2 = jax.random.split(key)
    return {"breakdown": jax.random.bernoulli(
                k1, cfg.p_break, (cfg.n_agents,)),
            "arrival": jax.random.bernoulli(k2, cfg.p_arrival)}


def exo_locals(exo, cfg: SupplyChainConfig):
    """Per-region restriction: only the breakdown bit reaches a cell's
    transition directly (the head arrival enters through u)."""
    return exo["breakdown"]


def gs_influence(state, exo, cfg: SupplyChainConfig):
    """u (N, 2) from the PRE-step state: [hand-off in, backpressure]."""
    store, buffer = state["store"], state["buffer"]
    full = store >= cfg.buf                                  # (N,)
    # backpressure: downstream input store is full (tail ships to a sink)
    bp = jnp.concatenate([full[1:], jnp.zeros((1,), bool)])
    # every cell's outgoing hand-off this step, by the shared ship rule
    ship = (buffer > 0) & ~bp                                # (N,)
    head_in = exo["arrival"] & ~full[0]
    handoff_in = jnp.concatenate([head_in[None], ship[:-1]])
    return jnp.stack([handoff_in, bp], axis=-1)              # (N, 2)


def gs_step_given(state, actions, exo, cfg: SupplyChainConfig):
    """Deterministic GS step given the exogenous draws."""
    u = gs_influence(state, exo, cfg)                        # (N, 2)
    step_fn = jax.vmap(lambda s, b, a, uu, br: cell_step(s, b, a, uu,
                                                         br, cfg))
    new_store, new_buffer, rewards, _ship = step_fn(
        state["store"], state["buffer"], actions, u, exo["breakdown"])
    obs = jax.vmap(lambda s, b: _obs(s, b, cfg))(new_store, new_buffer)
    new_state = {"store": new_store, "buffer": new_buffer,
                 "t": state["t"] + 1}
    done = new_state["t"] >= cfg.horizon
    return new_state, obs, rewards, u.astype(jnp.float32), done


def region_partition(cfg: SupplyChainConfig, n_blocks: int):
    """Contiguous segments of the production line. Part hand-offs couple
    strictly i±1, so any equal split into contiguous segments satisfies
    one-hop block adjacency (the 0↔N-1 wraparound halo is unused — the
    head takes external arrivals, the tail ships to a sink)."""
    return contiguous_partition(cfg.n_agents, n_blocks)


def boundary_influence(states, actions, exo, cfg: SupplyChainConfig):
    """Agent-major restatement of the hand-off/backpressure influence:
    u (N, 2) from the pre-step store/buffer levels and the head-arrival
    draw. Row i reads only rows i-1, i, i+1; zero rows are inert (an
    empty buffer never hands off, an empty store never backpressures)."""
    del actions
    return gs_influence(states, exo, cfg).astype(jnp.float32)


def gs_step(state, actions, key, cfg: SupplyChainConfig):
    return gs_step_given(state, actions, gs_exo(key, cfg), cfg)


def gs_obs(state, cfg: SupplyChainConfig):
    return jax.vmap(lambda s, b: _obs(s, b, cfg))(
        state["store"], state["buffer"])


def gs_locals(state, cfg: SupplyChainConfig):
    """Per-agent local states (N, ...) for dataset collection."""
    return {"store": state["store"], "buffer": state["buffer"]}


# ---------------------------------------------------------------------------
# Local simulator (one workcell; hand-offs driven by the AIP)
# ---------------------------------------------------------------------------
def ls_init(key, cfg: SupplyChainConfig):
    k1, k2 = jax.random.split(key)
    return {"store": jax.random.randint(k1, (), 0, cfg.buf + 1),
            "buffer": jax.random.randint(k2, (), 0, cfg.buf + 1),
            "t": jnp.zeros((), jnp.int32)}


def ls_step_given(local, action, u, breakdown, cfg: SupplyChainConfig):
    """breakdown: () the region's exogenous machine-failure draw."""
    new_store, new_buffer, reward, _ = cell_step(
        local["store"], local["buffer"], action, u, breakdown, cfg)
    new = {"store": new_store, "buffer": new_buffer, "t": local["t"] + 1}
    done = new["t"] >= cfg.horizon
    return new, _obs(new_store, new_buffer, cfg), reward, done


def ls_step(local, action, u, key, cfg: SupplyChainConfig):
    """u: (2,) influence-source bits (sampled from the AIP)."""
    breakdown = jax.random.bernoulli(key, cfg.p_break)
    return ls_step_given(local, action, u, breakdown, cfg)


def ls_obs(local, cfg: SupplyChainConfig):
    return _obs(local["store"], local["buffer"], cfg)


registry.register(
    "supplychain", sys.modules[__name__], SupplyChainConfig(),
    sizer=lambda cfg, side: dataclasses.replace(cfg, n_cells=side * side))
