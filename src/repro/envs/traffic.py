"""Traffic-light control env — JAX queueing abstraction of the paper's
SUMO/Flow grid (Vinitsky et al. 2018 benchmark, multi-agent variant).

A n×n grid of intersections; each has 4 incoming lanes of L cells
(cellular-automaton traffic: a car advances iff the next cell is free; the
head car crosses iff its lane has green). A car that crosses continues
straight into the corresponding incoming lane of the neighbouring
intersection — this inter-region hand-off is the ONLY coupling, so the
influence sources of agent (i,j) are exactly the 4 binary "a car enters
lane ℓ this step" variables, matching the paper's description.

Lanes are ordered [N, E, S, W] (direction the car comes FROM). Phase 0 =
green for N/S, phase 1 = green for E/W; action 1 toggles the phase.
Reward = fraction of local cars that moved this step (≈ mean speed in the
neighbourhood, the paper's objective).

The per-intersection transition :func:`lane_step` is shared verbatim
between GS and LS ⇒ IBA exactness by construction.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.envs import registry
from repro.envs.base import EnvInfo, contiguous_partition


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n: int = 2                  # grid side; N = n*n agents
    lane_len: int = 8           # L
    p_in: float = 0.3           # boundary car-injection probability
    horizon: int = 100
    init_density: float = 0.2

    @property
    def n_agents(self) -> int:
        return self.n * self.n

    def info(self) -> EnvInfo:
        obs_dim = 4 * self.lane_len + 2
        return EnvInfo(name="traffic", n_agents=self.n_agents,
                       obs_dim=obs_dim, n_actions=2, n_influence=4,
                       horizon=self.horizon,
                       alsh_dim=obs_dim + 2)


# ---------------------------------------------------------------------------
# Shared per-intersection transition (the \dot{T}_i of the IALM)
# ---------------------------------------------------------------------------
def lane_step(lanes, green, inflow):
    """One intersection's lanes for one step.

    lanes: (4, L) bool — cell 0 is the region entry, cell L-1 the stop line.
    green: (4,) bool — may the head car cross.
    inflow: (4,) bool — does a car enter cell 0 (the influence sources).

    Returns (new_lanes, out (4,) bool crossed cars, moved (), count ()).
    """
    lanes = lanes.astype(bool)
    ahead_free = jnp.concatenate(
        [~lanes[:, 1:], green[:, None].astype(bool)], axis=1)   # (4, L)
    move = lanes & ahead_free
    shifted = jnp.concatenate(
        [jnp.zeros((4, 1), bool), move[:, :-1]], axis=1)
    new = (lanes & ~move) | shifted
    out = move[:, -1]
    # inflow enters cell 0 if it is free after the shift
    enter = inflow.astype(bool) & ~new[:, 0]
    new = new.at[:, 0].set(new[:, 0] | enter)
    moved = move.sum()                 # mean-speed proxy over pre-step cars
    count = lanes.sum()
    return new, out, moved.astype(jnp.float32), count.astype(jnp.float32)


def _green(phase):
    """phase () int -> (4,) bool for lanes [N, E, S, W]."""
    ns = phase == 0
    return jnp.stack([ns, ~ns, ns, ~ns], axis=-1)


def _obs(lanes, phase):
    return jnp.concatenate([
        lanes.reshape(-1).astype(jnp.float32),
        jax.nn.one_hot(phase, 2, dtype=jnp.float32),
    ])


# ---------------------------------------------------------------------------
# Global simulator
# ---------------------------------------------------------------------------
def gs_init(key, cfg: TrafficConfig):
    k1, k2 = jax.random.split(key)
    lanes = jax.random.bernoulli(
        k1, cfg.init_density, (cfg.n, cfg.n, 4, cfg.lane_len))
    phase = jax.random.randint(k2, (cfg.n, cfg.n), 0, 2)
    return {"lanes": lanes, "phase": phase, "t": jnp.zeros((), jnp.int32)}


def gs_inflow(out, inject, cfg: TrafficConfig):
    """Wire crossed cars into neighbours. out, inject: (n, n, 4)."""
    n = cfg.n
    z = jnp.zeros((1, n), bool)
    zc = jnp.zeros((n, 1), bool)
    # lane 0 (from N, heading S): inflow[i] = out[i-1]; row 0 injected
    in_n = jnp.concatenate([inject[:1, :, 0], out[:-1, :, 0]], axis=0)
    # lane 2 (from S, heading N): inflow[i] = out[i+1]; row n-1 injected
    in_s = jnp.concatenate([out[1:, :, 2], inject[-1:, :, 2]], axis=0)
    # lane 1 (from E, heading W): inflow[:, j] = out[:, j+1]; col n-1 injected
    in_e = jnp.concatenate([out[:, 1:, 1], inject[:, -1:, 1]], axis=1)
    # lane 3 (from W, heading E): inflow[:, j] = out[:, j-1]; col 0 injected
    in_w = jnp.concatenate([inject[:, :1, 3], out[:, :-1, 3]], axis=1)
    del z, zc
    return jnp.stack([in_n, in_e, in_s, in_w], axis=-1)        # (n, n, 4)


def gs_step_given(state, actions, inject, cfg: TrafficConfig):
    """Deterministic GS step given boundary-injection bits (n, n, 4)."""
    n = cfg.n
    phase = (state["phase"] + actions.reshape(n, n)) % 2
    green = _green(phase)                                      # (n, n, 4)

    lanes = state["lanes"]
    # First pass: who crosses (out bits depend only on pre-step state).
    ahead_free_head = green
    out = lanes[..., -1] & ahead_free_head                     # (n, n, 4)
    inflow = gs_inflow(out, inject, cfg)                       # (n, n, 4)

    step_fn = jax.vmap(jax.vmap(lane_step))
    new_lanes, out2, moved, count = step_fn(lanes, green, inflow)
    # out2 == out by construction (same formula); keep out for wiring.
    del out2

    rewards = (moved / jnp.maximum(count, 1.0)).reshape(-1)
    obs = jax.vmap(jax.vmap(_obs))(new_lanes, phase).reshape(cfg.n_agents, -1)
    u = inflow.reshape(cfg.n_agents, 4).astype(jnp.float32)
    new_state = {"lanes": new_lanes, "phase": phase, "t": state["t"] + 1}
    done = new_state["t"] >= cfg.horizon
    return new_state, obs, rewards, u, done


def gs_exo(key, cfg: TrafficConfig):
    """Exogenous draws: boundary car-injection bits (n, n, 4)."""
    return jax.random.bernoulli(key, cfg.p_in, (cfg.n, cfg.n, 4))


def exo_locals(inject, cfg: TrafficConfig):
    """Per-region restriction of the exogenous draws. Boundary injection
    reaches a region only through its inflow u, so the LS transition
    takes no direct exogenous input."""
    del inject
    return jnp.zeros((cfg.n_agents, 0))


def region_partition(cfg: TrafficConfig, n_blocks: int):
    """Contiguous row bands of the n×n intersection grid. A band's only
    inter-region couplings are the hand-offs to the rows directly above/
    below (adjacent band) and east/west within the band, so one-hop
    block adjacency holds iff bands are whole rows: ``n_blocks`` must
    divide the grid side."""
    if cfg.n % n_blocks:
        raise ValueError(
            f"traffic grid side {cfg.n} cannot split into {n_blocks} "
            f"row bands")
    return contiguous_partition(cfg.n_agents, n_blocks)


def boundary_influence(states, actions, inject, cfg: TrafficConfig):
    """Agent-major restatement of the realized inflow: u (N, 4) from the
    pre-step lanes/phases, the joint actions, and the boundary-injection
    draws. Row (i, j) reads only its grid neighbours' ``out`` bits (plus
    its own injection), so zero rows are inert — an empty lane never
    emits a crossing car."""
    n = cfg.n
    lanes = states["lanes"].reshape(n, n, 4, cfg.lane_len)
    phase = (states["phase"].reshape(n, n) + actions.reshape(n, n)) % 2
    green = _green(phase)                                      # (n, n, 4)
    out = lanes[..., -1].astype(bool) & green
    inflow = gs_inflow(out, inject, cfg)
    return inflow.reshape(cfg.n_agents, 4).astype(jnp.float32)


def gs_step(state, actions, key, cfg: TrafficConfig):
    return gs_step_given(state, actions, gs_exo(key, cfg), cfg)


def gs_obs(state, cfg: TrafficConfig):
    return jax.vmap(jax.vmap(_obs))(state["lanes"], state["phase"]) \
        .reshape(cfg.n_agents, -1)


def gs_locals(state, cfg: TrafficConfig):
    """Per-agent local states (N, ...) for dataset collection."""
    return {"lanes": state["lanes"].reshape(cfg.n_agents, 4, cfg.lane_len),
            "phase": state["phase"].reshape(cfg.n_agents)}


# ---------------------------------------------------------------------------
# Local simulator (one intersection; inflow driven by the AIP)
# ---------------------------------------------------------------------------
def ls_init(key, cfg: TrafficConfig):
    k1, k2 = jax.random.split(key)
    return {"lanes": jax.random.bernoulli(k1, cfg.init_density,
                                          (4, cfg.lane_len)),
            "phase": jax.random.randint(k2, (), 0, 2),
            "t": jnp.zeros((), jnp.int32)}


def ls_step_given(local, action, u, exo, cfg: TrafficConfig):
    """Uniform-protocol alias: the traffic LS takes no direct exogenous
    input (``exo`` is the empty per-region restriction)."""
    del exo
    return ls_step(local, action, u, None, cfg)


def ls_step(local, action, u, key, cfg: TrafficConfig):
    """u: (4,) influence-source bits (sampled from the AIP)."""
    del key
    phase = (local["phase"] + action) % 2
    green = _green(phase)
    new_lanes, _out, moved, count = lane_step(local["lanes"], green,
                                              u.astype(bool))
    reward = moved / jnp.maximum(count, 1.0)
    obs = _obs(new_lanes, phase)
    new = {"lanes": new_lanes, "phase": phase, "t": local["t"] + 1}
    done = new["t"] >= cfg.horizon
    return new, obs, reward, done


def ls_obs(local, cfg: TrafficConfig):
    return _obs(local["lanes"], local["phase"])


registry.register(
    "traffic", sys.modules[__name__], TrafficConfig(),
    sizer=lambda cfg, side: dataclasses.replace(cfg, n=side))
