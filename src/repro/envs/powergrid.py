"""Power-grid voltage control env — a ring of feeder buses.

``n_buses`` agents sit on a ring of distribution feeders; agent i owns a
feeder of ``feeder`` nodes whose discrete voltage levels drift under
random load fluctuations. The agent's on-load tap changer (action:
lower / hold / raise, a saturating integrator in [-tap_max, tap_max])
shifts its feeder's voltage; the reward is the fraction of nodes inside
the regulation band around nominal.

Buses are coupled ONLY through the tie-lines to their two electrical
neighbours: an over-voltage (under-voltage) excursion at a neighbour
pushes this feeder's voltage up (down) by one level. Agent i's influence
sources are therefore the four binary flags
``[left_over, left_under, right_over, right_under]`` of its neighbours —
computed from the PRE-step global state, so conditioning on u
d-separates the region from the rest of the ring.

The per-bus transition :func:`bus_step` is shared verbatim between GS
and LS ⇒ IBA exactness by construction.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.envs import registry
from repro.envs.base import EnvInfo, contiguous_partition

TAP_MAX = 2                       # tap positions in [-2, 2] -> 5 one-hot


@dataclasses.dataclass(frozen=True)
class PowerGridConfig:
    n_buses: int = 4              # ring length = number of agents
    feeder: int = 6               # nodes per feeder
    v_levels: int = 9             # discrete voltage levels [0, v_levels)
    band: int = 1                 # |v - nominal| <= band is in-band
    p_load: float = 0.4           # per-node load-fluctuation probability
    horizon: int = 100

    @property
    def n_agents(self) -> int:
        return self.n_buses

    @property
    def nominal(self) -> int:
        return (self.v_levels - 1) // 2

    def info(self) -> EnvInfo:
        obs_dim = self.feeder + (2 * TAP_MAX + 1)
        return EnvInfo(name="powergrid", n_agents=self.n_agents,
                       obs_dim=obs_dim, n_actions=3, n_influence=4,
                       horizon=self.horizon, alsh_dim=obs_dim + 3)


# ---------------------------------------------------------------------------
# Shared per-bus transition (the \dot{T}_i of the IALM)
# ---------------------------------------------------------------------------
def bus_step(volts, tap, action, u, load, cfg: PowerGridConfig):
    """One bus region for one step.

    volts: (F,) int32 node voltage levels; tap: () int32 in [-2, 2];
    action: () in {0: lower, 1: hold, 2: raise};
    u: (4,) bool — [left_over, left_under, right_over, right_under];
    load: (F,) int32 in {-1, 0, +1} — the exogenous load fluctuations.

    Returns (new_volts, new_tap, reward).
    """
    ub = u.astype(bool)
    new_tap = jnp.clip(tap + action.astype(jnp.int32) - 1, -TAP_MAX, TAP_MAX)
    # neighbour excursions propagate one level over the tie-lines
    push = ((ub[0].astype(jnp.int32) + ub[2])
            - (ub[1].astype(jnp.int32) + ub[3]))
    new_volts = jnp.clip(
        volts + load + (new_tap - tap) + push, 0, cfg.v_levels - 1)
    in_band = jnp.abs(new_volts - cfg.nominal) <= cfg.band
    reward = in_band.mean(dtype=jnp.float32)
    return new_volts, new_tap, reward


def _flags(volts, cfg: PowerGridConfig):
    """(..., F) volts -> (over (...,), under (...,)) excursion flags."""
    hi = cfg.nominal + cfg.band
    lo = cfg.nominal - cfg.band
    return volts.max(axis=-1) > hi, volts.min(axis=-1) < lo


def _obs(volts, tap, cfg: PowerGridConfig):
    return jnp.concatenate([
        volts.astype(jnp.float32) / (cfg.v_levels - 1),
        jax.nn.one_hot(tap + TAP_MAX, 2 * TAP_MAX + 1, dtype=jnp.float32),
    ])


# ---------------------------------------------------------------------------
# Global simulator
# ---------------------------------------------------------------------------
def gs_init(key, cfg: PowerGridConfig):
    nom = cfg.nominal
    volts = jax.random.randint(
        key, (cfg.n_agents, cfg.feeder), nom - 1, nom + 2)
    taps = jnp.zeros((cfg.n_agents,), jnp.int32)
    return {"volts": volts.astype(jnp.int32), "tap": taps,
            "t": jnp.zeros((), jnp.int32)}


def gs_exo(key, cfg: PowerGridConfig):
    """Exogenous load fluctuations, (N, F) int32 in {-1, 0, +1}."""
    k1, k2 = jax.random.split(key)
    hit = jax.random.bernoulli(k1, cfg.p_load, (cfg.n_agents, cfg.feeder))
    up = jax.random.bernoulli(k2, 0.5, (cfg.n_agents, cfg.feeder))
    return jnp.where(hit, jnp.where(up, 1, -1), 0).astype(jnp.int32)


def exo_locals(load, cfg: PowerGridConfig):
    """Per-region restriction of the exogenous draws (already per-bus)."""
    return load


def gs_influence(state, cfg: PowerGridConfig):
    """u (N, 4) from the PRE-step volts: neighbour excursion flags."""
    over, under = _flags(state["volts"], cfg)               # (N,), (N,)
    left = lambda x: jnp.roll(x, 1)                         # x[i-1 mod N]
    right = lambda x: jnp.roll(x, -1)                       # x[i+1 mod N]
    return jnp.stack(
        [left(over), left(under), right(over), right(under)], axis=-1)


def gs_step_given(state, actions, load, cfg: PowerGridConfig):
    """Deterministic GS step given the load draws (N, F)."""
    u = gs_influence(state, cfg)                            # (N, 4)
    step_fn = jax.vmap(lambda v, tp, a, uu, ld: bus_step(v, tp, a, uu,
                                                         ld, cfg))
    new_volts, new_taps, rewards = step_fn(
        state["volts"], state["tap"], actions, u, load)
    obs = jax.vmap(lambda v, tp: _obs(v, tp, cfg))(new_volts, new_taps)
    new_state = {"volts": new_volts, "tap": new_taps, "t": state["t"] + 1}
    done = new_state["t"] >= cfg.horizon
    return new_state, obs, rewards, u.astype(jnp.float32), done


def region_partition(cfg: PowerGridConfig, n_blocks: int):
    """Contiguous arcs of the bus ring. Tie-line coupling is strictly
    i±1 (mod N), so any equal split into contiguous arcs — including the
    0↔N-1 wraparound between the first and last block — satisfies
    one-hop block adjacency."""
    return contiguous_partition(cfg.n_agents, n_blocks)


def boundary_influence(states, actions, load, cfg: PowerGridConfig):
    """Agent-major restatement of the tie-line influence: u (N, 4) from
    the pre-step feeder voltages alone. Row i reads only rows i±1
    (mod N); zero rows are inert for any real agent's sources."""
    del actions, load
    return gs_influence(states, cfg).astype(jnp.float32)


def gs_step(state, actions, key, cfg: PowerGridConfig):
    return gs_step_given(state, actions, gs_exo(key, cfg), cfg)


def gs_obs(state, cfg: PowerGridConfig):
    return jax.vmap(lambda v, tp: _obs(v, tp, cfg))(
        state["volts"], state["tap"])


def gs_locals(state, cfg: PowerGridConfig):
    """Per-agent local states (N, ...) for dataset collection."""
    return {"volts": state["volts"], "tap": state["tap"]}


# ---------------------------------------------------------------------------
# Local simulator (one bus; neighbour flags driven by the AIP)
# ---------------------------------------------------------------------------
def ls_init(key, cfg: PowerGridConfig):
    nom = cfg.nominal
    return {"volts": jax.random.randint(
                key, (cfg.feeder,), nom - 1, nom + 2).astype(jnp.int32),
            "tap": jnp.zeros((), jnp.int32),
            "t": jnp.zeros((), jnp.int32)}


def ls_step_given(local, action, u, load, cfg: PowerGridConfig):
    """load: (F,) the region's exogenous draws."""
    new_volts, new_tap, reward = bus_step(
        local["volts"], local["tap"], action, u, load, cfg)
    new = {"volts": new_volts, "tap": new_tap, "t": local["t"] + 1}
    done = new["t"] >= cfg.horizon
    return new, _obs(new_volts, new_tap, cfg), reward, done


def ls_step(local, action, u, key, cfg: PowerGridConfig):
    """u: (4,) influence-source bits (sampled from the AIP)."""
    k1, k2 = jax.random.split(key)
    hit = jax.random.bernoulli(k1, cfg.p_load, (cfg.feeder,))
    up = jax.random.bernoulli(k2, 0.5, (cfg.feeder,))
    load = jnp.where(hit, jnp.where(up, 1, -1), 0).astype(jnp.int32)
    return ls_step_given(local, action, u, load, cfg)


def ls_obs(local, cfg: PowerGridConfig):
    return _obs(local["volts"], local["tap"], cfg)


registry.register(
    "powergrid", sys.modules[__name__], PowerGridConfig(),
    sizer=lambda cfg, side: dataclasses.replace(cfg, n_buses=side * side))
