"""Warehouse commissioning env (Suau et al. 2022b, multi-robot variant).

k×k robots, each confined to a 5×5 region with spacing 4, so each of the
four 3-cell item shelves on a region's edges is shared with the adjacent
region. Items appear with p=0.02 on empty shelf cells and age by 1 per
step; a robot collects the item under it and earns age/max_region_age ∈
(0, 1] (oldest-first shaping, as in the paper). Robots never observe each
other — neighbours influence a region ONLY by collecting shared items, so
agent i's influence sources are the 12 binary "another robot sits on my
item cell c" variables, matching the paper.

The per-region transition :func:`region_step` is shared verbatim between
GS and LS ⇒ IBA exactness by construction.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs import registry
from repro.envs.base import EnvInfo, contiguous_partition


@dataclasses.dataclass(frozen=True)
class WarehouseConfig:
    k: int = 2                   # k*k robots
    p_item: float = 0.02
    horizon: int = 100

    @property
    def n_agents(self) -> int:
        return self.k * self.k

    @property
    def grid(self) -> int:       # global grid side
        return 4 * self.k + 1

    def info(self) -> EnvInfo:
        obs_dim = 25 + 12
        return EnvInfo(name="warehouse", n_agents=self.n_agents,
                       obs_dim=obs_dim, n_actions=5, n_influence=12,
                       horizon=self.horizon, alsh_dim=obs_dim + 5)


def item_cells(cfg: WarehouseConfig) -> np.ndarray:
    """(N, 12, 2) absolute coords of each region's item cells.
    Order: north shelf (3), east (3), south (3), west (3)."""
    cells = np.zeros((cfg.n_agents, 12, 2), np.int32)
    for i in range(cfg.k):
        for j in range(cfg.k):
            r0, c0 = 4 * i, 4 * j
            cs = ([(r0, c0 + d) for d in (1, 2, 3)] +          # north
                  [(r0 + d, c0 + 4) for d in (1, 2, 3)] +      # east
                  [(r0 + 4, c0 + d) for d in (1, 2, 3)] +      # south
                  [(r0 + d, c0) for d in (1, 2, 3)])           # west
            cells[i * cfg.k + j] = np.array(cs, np.int32)
    return cells


def region_origin(cfg: WarehouseConfig) -> np.ndarray:
    """(N, 2) top-left corner of each region."""
    out = np.zeros((cfg.n_agents, 2), np.int32)
    for i in range(cfg.k):
        for j in range(cfg.k):
            out[i * cfg.k + j] = (4 * i, 4 * j)
    return out


_MOVES = np.array([[0, 0], [-1, 0], [0, 1], [1, 0], [0, -1]], np.int32)


# ---------------------------------------------------------------------------
# Shared per-region transition (the \dot{T}_i of the IALM)
# ---------------------------------------------------------------------------
def region_step(pos, ages, action, u, spawn):
    """One region for one step, in LOCAL coordinates.

    pos: (2,) robot position in [0,4]²; ages: (12,) item ages (0 = empty);
    action: () in [0,5); u: (12,) bool — another robot on item cell c;
    spawn: (12,) bool — item-appearance draws for this step.

    Returns (new_pos, new_ages, reward, on_item (12,) bool self-occupancy).
    """
    move = jnp.asarray(_MOVES)[action]
    new_pos = jnp.clip(pos + move, 0, 4)

    # local coords of the 12 item cells (same for every region)
    local_cells = jnp.asarray(
        [[0, 1], [0, 2], [0, 3], [1, 4], [2, 4], [3, 4],
         [4, 1], [4, 2], [4, 3], [1, 0], [2, 0], [3, 0]], jnp.int32)
    on_item = jnp.all(local_cells == new_pos[None, :], axis=1)   # (12,)

    active = ages > 0
    max_age = jnp.maximum(jnp.max(ages), 1).astype(jnp.float32)
    collected_self = on_item & active
    reward = jnp.sum(jnp.where(collected_self,
                               ages.astype(jnp.float32) / max_age, 0.0))

    removed = active & (on_item | u.astype(bool))
    ages = jnp.where(removed, 0, ages)
    ages = jnp.where(ages > 0, ages + 1, ages)                  # age
    ages = jnp.where((ages == 0) & spawn.astype(bool), 1, ages)  # spawn
    return new_pos, ages, reward, on_item


def _obs(pos, ages):
    pos_oh = jnp.zeros((5, 5), jnp.float32).at[pos[0], pos[1]].set(1.0)
    return jnp.concatenate([pos_oh.reshape(-1),
                            (ages > 0).astype(jnp.float32)])


# ---------------------------------------------------------------------------
# Global simulator
# ---------------------------------------------------------------------------
def gs_init(key, cfg: WarehouseConfig):
    k1, k2 = jax.random.split(key)
    pos = jax.random.randint(k1, (cfg.n_agents, 2), 0, 5)       # local coords
    cells = jnp.asarray(item_cells(cfg))
    g = cfg.grid
    spawn0 = jax.random.bernoulli(k2, 0.2, (g, g))
    shelf = jnp.zeros((g, g), bool)
    shelf = shelf.at[cells[..., 0].reshape(-1), cells[..., 1].reshape(-1)] \
        .set(True)
    ages = jnp.where(shelf & spawn0, 1, 0).astype(jnp.int32)
    return {"pos": pos, "ages": ages, "t": jnp.zeros((), jnp.int32)}


def _abs_pos(pos, cfg: WarehouseConfig):
    return pos + jnp.asarray(region_origin(cfg))                # (N, 2)


def gs_influence(pos, cfg: WarehouseConfig):
    """u (N, 12): another robot sits on region i's item cell c.
    Computed from CURRENT (post-move) absolute positions."""
    cells = jnp.asarray(item_cells(cfg))                        # (N, 12, 2)
    ap = _abs_pos(pos, cfg)                                     # (N, 2)
    same = jnp.all(cells[:, :, None, :] == ap[None, None, :, :], axis=-1)
    # exclude the region's own robot
    own = jnp.eye(cfg.n_agents, dtype=bool)[:, None, :]
    return jnp.any(same & ~own, axis=-1)                        # (N, 12)


def gs_step_given(state, actions, spawn_grid, cfg: WarehouseConfig):
    """spawn_grid: (G, G) bool item-appearance draws."""
    n = cfg.n_agents
    cells = jnp.asarray(item_cells(cfg))                        # (N, 12, 2)

    # 1. all robots move (region_step handles the local move; here we move
    #    globally first to compute the influence bits all regions agree on).
    move = jnp.asarray(_MOVES)[actions]
    new_pos = jnp.clip(state["pos"] + move, 0, 4)
    u = gs_influence(new_pos, cfg)                              # (N, 12)

    # 2. per-region transitions on region-local views of the item grid.
    region_ages = state["ages"][cells[..., 0], cells[..., 1]]   # (N, 12)
    spawn = spawn_grid[cells[..., 0], cells[..., 1]]            # (N, 12)
    rp, ra, rewards, on_item = jax.vmap(region_step)(
        state["pos"], region_ages, actions, u, spawn)
    assert rp.shape == new_pos.shape

    # 3. write back: shared cells receive identical values from both owners
    #    (same u/spawn/ages inputs), so scatter order is irrelevant.
    ages = state["ages"].at[cells[..., 0].reshape(-1),
                            cells[..., 1].reshape(-1)] \
        .set(ra.reshape(-1), mode="drop")

    obs = jax.vmap(_obs)(rp, ra)
    new_state = {"pos": rp, "ages": ages, "t": state["t"] + 1}
    done = new_state["t"] >= cfg.horizon
    return new_state, obs, rewards, u.astype(jnp.float32), done


def gs_exo(key, cfg: WarehouseConfig):
    """Exogenous draws: item-appearance bits on the global grid (G, G)."""
    g = cfg.grid
    return jax.random.bernoulli(key, cfg.p_item, (g, g))


def exo_locals(spawn_grid, cfg: WarehouseConfig):
    """Per-region restriction: each region's 12 item-cell spawn bits."""
    cells = jnp.asarray(item_cells(cfg))
    return spawn_grid[cells[..., 0], cells[..., 1]]          # (N, 12)


def region_partition(cfg: WarehouseConfig, n_blocks: int):
    """Contiguous row bands of the k×k region grid. Robots are confined
    to their own 5×5 region and shelves are shared only with 4-adjacent
    regions (diagonals can never reach a neighbour's item cells), so
    one-hop block adjacency holds iff bands are whole region rows:
    ``n_blocks`` must divide k."""
    if cfg.k % n_blocks:
        raise ValueError(
            f"warehouse region grid side {cfg.k} cannot split into "
            f"{n_blocks} row bands")
    return contiguous_partition(cfg.n_agents, n_blocks)


def boundary_influence(states, actions, spawn_grid, cfg: WarehouseConfig):
    """Agent-major restatement of the occupancy influence: u (N, 12)
    from post-move absolute positions. Zero rows are inert — a zeroed
    robot sits on its own region's corner, and corners (both coords ≡ 0
    mod 4) are never item cells (exactly one coord ≡ 0 mod 4)."""
    del spawn_grid
    move = jnp.asarray(_MOVES)[actions]
    new_pos = jnp.clip(states["pos"] + move, 0, 4)
    return gs_influence(new_pos, cfg).astype(jnp.float32)


def gs_step(state, actions, key, cfg: WarehouseConfig):
    return gs_step_given(state, actions, gs_exo(key, cfg), cfg)


def gs_obs(state, cfg: WarehouseConfig):
    cells = jnp.asarray(item_cells(cfg))
    region_ages = state["ages"][cells[..., 0], cells[..., 1]]
    return jax.vmap(_obs)(state["pos"], region_ages)


def gs_locals(state, cfg: WarehouseConfig):
    cells = jnp.asarray(item_cells(cfg))
    return {"pos": state["pos"],
            "ages": state["ages"][cells[..., 0], cells[..., 1]]}


# ---------------------------------------------------------------------------
# Local simulator
# ---------------------------------------------------------------------------
def ls_init(key, cfg: WarehouseConfig):
    k1, k2 = jax.random.split(key)
    return {"pos": jax.random.randint(k1, (2,), 0, 5),
            "ages": jnp.where(jax.random.bernoulli(k2, 0.2, (12,)), 1, 0)
            .astype(jnp.int32),
            "t": jnp.zeros((), jnp.int32)}


def ls_step(local, action, u, key, cfg: WarehouseConfig):
    spawn = jax.random.bernoulli(key, cfg.p_item, (12,))
    return ls_step_given(local, action, u, spawn, cfg)


def ls_step_given(local, action, u, spawn, cfg: WarehouseConfig):
    pos, ages, reward, _ = region_step(local["pos"], local["ages"],
                                       action, u, spawn)
    new = {"pos": pos, "ages": ages, "t": local["t"] + 1}
    done = new["t"] >= cfg.horizon
    return new, _obs(pos, ages), reward, done


def ls_obs(local, cfg: WarehouseConfig):
    return _obs(local["pos"], local["ages"])


registry.register(
    "warehouse", sys.modules[__name__], WarehouseConfig(),
    sizer=lambda cfg, side: dataclasses.replace(cfg, k=side))
