"""JAX-native networked multi-agent environments (local-form fPOSGs).

Each env module provides a **global simulator** (GS) and a **local
simulator** (LS) built from the *same* per-region transition function, so
the IBA exactness property — LS(x, a, u) == region-restriction of GS when
u equals the realized influence — holds by construction and is property-
tested.
"""
from repro.envs import base, traffic, warehouse  # noqa: F401
