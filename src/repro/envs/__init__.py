"""JAX-native networked multi-agent environments (local-form fPOSGs).

Each env module provides a **global simulator** (GS) and a **local
simulator** (LS) built from the *same* per-region transition function, so
the IBA exactness property — LS(x, a, u) == region-restriction of GS when
u equals the realized influence — holds by construction and is property-
tested.

Importing any env module registers it in :mod:`repro.envs.registry`;
importing this package registers all built-ins. Resolve by name with
``registry.make(name, side=..., **overrides) -> (module, cfg)``.
"""
from repro.envs import base, registry  # noqa: F401
from repro.envs import powergrid, supplychain, traffic, warehouse  # noqa: F401
