"""fPOSG environment interface.

An environment module (traffic, warehouse) exposes two simulator
namespaces with pure-JAX, jit/vmap-able step functions:

Global simulator (GS)
    ``gs_init(key, cfg) -> state``
    ``gs_step(state, actions (N,), key, cfg) ->
        (state', obs (N, O), rewards (N,), u (N, M), done ())``
    plus ``gs_locals(state, cfg)`` extracting the per-agent local states
    (used for dataset collection and the exactness property test).

Local simulator (LS) — single region
    ``ls_init(key, cfg) -> local``
    ``ls_step(local, action (), u (M,), key, cfg) ->
        (local', obs (O,), reward ())``

The influence sources ``u`` are binary vectors (length M): the paper's
traffic env has M=4 (car entering each incoming lane) and warehouse M=12
(neighbor robot on each shared item cell).
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class EnvInfo:
    """Static facts the MARL/DIALS stack needs about an env."""
    name: str
    n_agents: int
    obs_dim: int
    n_actions: int
    n_influence: int          # M: number of binary influence sources/agent
    horizon: int
    # ALSH feature size fed to the AIP (local state + last action one-hot)
    alsh_dim: int
