"""fPOSG environment interface.

An environment module (traffic, warehouse, powergrid, supplychain — see
``repro.envs.registry``) exposes two simulator namespaces with pure-JAX,
jit/vmap-able step functions:

Global simulator (GS)
    ``gs_init(key, cfg) -> state``
    ``gs_step(state, actions (N,), key, cfg) ->
        (state', obs (N, O), rewards (N,), u (N, M), done ())``
    plus ``gs_locals(state, cfg)`` extracting the per-agent local states
    (used for dataset collection and the exactness property test).

Local simulator (LS) — single region
    ``ls_init(key, cfg) -> local``
    ``ls_step(local, action (), u (M,), key, cfg) ->
        (local', obs (O,), reward (), done ())``

The influence sources ``u`` are binary vectors (length M): the paper's
traffic env has M=4 (car entering each incoming lane) and warehouse M=12
(neighbor robot on each shared item cell).

Exactness protocol (exercised generically by ``tests/test_registry.py``)
— every module also factors its randomness so GS and LS can be driven by
the *same* exogenous draws:

    ``gs_exo(key, cfg) -> exo``            sample the exogenous noise
    ``gs_step_given(state, actions, exo, cfg)``   deterministic GS step
    ``exo_locals(exo, cfg) -> (N, ...)``   per-region restriction of exo
    ``ls_step_given(local, action (), u (M,), exo_i, cfg)``
                                           deterministic LS step

and keeps ``gs_locals`` keys identical to the LS state keys (minus the
step counter ``t``), so replaying region i through ``ls_step_given``
with the realized ``u[i]`` and ``exo_locals(exo)[i]`` must reproduce the
GS's region-i restriction bit-for-bit — Definition 3 as an executable
property, for every registered env.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EnvInfo:
    """Static facts the MARL/DIALS stack needs about an env."""
    name: str
    n_agents: int
    obs_dim: int
    n_actions: int
    n_influence: int          # M: number of binary influence sources/agent
    horizon: int
    # ALSH feature size fed to the AIP (local state + last action one-hot)
    alsh_dim: int
