"""fPOSG environment interface.

An environment module (traffic, warehouse, powergrid, supplychain — see
``repro.envs.registry``) exposes two simulator namespaces with pure-JAX,
jit/vmap-able step functions:

Global simulator (GS)
    ``gs_init(key, cfg) -> state``
    ``gs_step(state, actions (N,), key, cfg) ->
        (state', obs (N, O), rewards (N,), u (N, M), done ())``
    plus ``gs_locals(state, cfg)`` extracting the per-agent local states
    (used for dataset collection and the exactness property test).

Local simulator (LS) — single region
    ``ls_init(key, cfg) -> local``
    ``ls_step(local, action (), u (M,), key, cfg) ->
        (local', obs (O,), reward (), done ())``

The influence sources ``u`` are binary vectors (length M): the paper's
traffic env has M=4 (car entering each incoming lane) and warehouse M=12
(neighbor robot on each shared item cell).

Exactness protocol (exercised generically by ``tests/test_registry.py``)
— every module also factors its randomness so GS and LS can be driven by
the *same* exogenous draws:

    ``gs_exo(key, cfg) -> exo``            sample the exogenous noise
    ``gs_step_given(state, actions, exo, cfg)``   deterministic GS step
    ``exo_locals(exo, cfg) -> (N, ...)``   per-region restriction of exo
    ``ls_step_given(local, action (), u (M,), exo_i, cfg)``
                                           deterministic LS step

and keeps ``gs_locals`` keys identical to the LS state keys (minus the
step counter ``t``), so replaying region i through ``ls_step_given``
with the realized ``u[i]`` and ``exo_locals(exo)[i]`` must reproduce the
GS's region-i restriction bit-for-bit — Definition 3 as an executable
property, for every registered env.

Spatial-decomposition protocol (the sharded-GS contract, exercised by
``tests/test_registry.py`` and consumed by ``repro.core.gs_sharded``) —
every module also exposes the two hooks that let the *global* rollout
itself run as region blocks over a device mesh:

    ``region_partition(cfg, n_blocks) -> (N,) int``
        Contiguous agent→block assignment (equal block sizes,
        non-decreasing — use :func:`contiguous_partition`) respecting the
        network topology: every agent's influence sources must be
        computable from the states/actions/exo of agents in its OWN
        block and the two ring-adjacent blocks (b±1 mod n_blocks).
        Raises ``ValueError`` for block counts the topology cannot
        support (e.g. a grid env needs ``n_blocks`` to divide the grid
        side so blocks are whole row bands).

    ``boundary_influence(states, actions, exo, cfg) -> u (N, M) f32``
        The incoming-u computation restated over *agent-major* inputs:
        ``states`` follows the ``gs_locals`` schema, ``actions`` is
        (N,), ``exo`` the full exogenous draw. On full global data it
        must reproduce ``gs_step_given``'s realized ``u`` bit-for-bit.
        Locality guarantee (what ``region_partition`` promises): row i
        of the result depends only on rows of one-hop topological
        neighbours — so a block can evaluate it on a zero-padded view
        holding only blocks {b-1, b, b+1} (the halo) and read off its
        own rows exactly. Zero rows must therefore be inert: they may
        never contribute influence to a real agent's sources.

Together with Definition-3 exactness this factors one GS step into
``u = boundary_influence(...)`` (one halo exchange) followed by N
independent ``ls_step_given`` region transitions — the decomposition
``repro.core.gs_sharded`` shard_maps over the mesh.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def contiguous_partition(n_agents: int, n_blocks: int) -> np.ndarray:
    """Equal-size contiguous agent→block assignment, the shape every
    env's ``region_partition`` returns after validating its own topology
    constraint. Raises when the agent axis cannot tile the blocks."""
    if n_blocks < 1:
        raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
    if n_agents % n_blocks:
        raise ValueError(
            f"{n_agents} agents cannot tile {n_blocks} blocks")
    return (np.arange(n_agents) // (n_agents // n_blocks)).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class EnvInfo:
    """Static facts the MARL/DIALS stack needs about an env."""
    name: str
    n_agents: int
    obs_dim: int
    n_actions: int
    n_influence: int          # M: number of binary influence sources/agent
    horizon: int
    # ALSH feature size fed to the AIP (local state + last action one-hot)
    alsh_dim: int
