"""whisper-tiny [audio] — enc-dec; conv frontend is a STUB (input_specs
provides precomputed frame embeddings (B, 1500, 384)). 4L d_model=384 6H
(kv=6) d_ff=1536 vocab=51865. [arXiv:2212.04356; unverified]

Mapping note: each whisper decoder layer (self-attn + cross-attn + MLP)
lowers as a period of two blocks [self/no-ffn, cross/mlp] — identical
compute graph, scan-friendly. RMSNorm replaces LayerNorm uniformly across
the framework (DESIGN.md §Assumptions).
"""
from repro.configs import common
from repro.models import api, blocks, encdec, lm

N_FRAMES = 1_500


def _dec_period(d, h, kv, ff, dh):
    self_l = blocks.LayerSpec(
        mixer="attn", attn=common.attn_cfg(d, h, kv, head_dim=dh),
        ffn="none", d_model=d)
    cross_l = blocks.LayerSpec(
        mixer="cross_attn", attn=common.attn_cfg(d, h, kv, head_dim=dh),
        ffn="mlp", mlp=common.mlp_cfg(d, ff, activation="gelu"),
        cross_kv_dim=d, d_model=d)
    return (self_l, cross_l)


def make(reduced: bool = False):
    if reduced:
        d, h, kv, ff, dh, layers_, enc_l, frames = 64, 4, 4, 128, 16, 2, 2, 32
        vocab = 256
    else:
        d, h, kv, ff, dh, layers_, enc_l, frames = 384, 6, 6, 1_536, 64, 4, 4, N_FRAMES
        vocab = 51_865
    dec = lm.ModelConfig(
        name="whisper-dec", vocab=vocab, d_model=d, n_layers=2 * layers_,
        period=_dec_period(d, h, kv, ff, dh), tie_embeddings=True,
        loss_chunk=256)
    enc_layer = blocks.LayerSpec(
        mixer="attn", attn=common.attn_cfg(d, h, kv, head_dim=dh,
                                           causal=False),
        ffn="mlp", mlp=common.mlp_cfg(d, ff, activation="gelu"), d_model=d)
    cfg = encdec.EncDecConfig(
        name="whisper-tiny" + ("-reduced" if reduced else ""),
        encoder_period=(enc_layer,), encoder_layers=enc_l, decoder=dec,
        d_model=d)
    return api.ArchSpec(arch_id="whisper-tiny", kind="encdec", cfg=cfg,
                        family="audio", n_frames=frames,
                        source="arXiv:2212.04356; unverified")
