"""llama-3.2-vision-90b [vlm] — cross-attn image layers; vision tower is a
STUB (input_specs provides patch embeddings). 100L d_model=8192 64H (kv=8)
d_ff=28672 vocab=128256. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Mapping: period of five = 4 self-attention blocks + 1 tanh-gated
cross-attention block (the released checkpoints' 4:1 self:cross ratio;
100 layers = 80 self + 20 cross).
"""
from repro.configs import common
from repro.models import api, blocks, lm

N_PATCHES = 1_601          # 1 tile × (224/14)² + cls, stubbed
VISION_DIM = 7_680


def make(reduced: bool = False):
    if reduced:
        d, h, kv, ff, vocab, vdim, patches = 64, 4, 2, 128, 256, 32, 16
        n_layers = 5
    else:
        d, h, kv, ff, vocab, vdim, patches = (8_192, 64, 8, 28_672,
                                              128_256, VISION_DIM, N_PATCHES)
        n_layers = 100
    self_l = common.dense_layer(d, h, kv, ff, theta=500_000.0)
    cross_l = blocks.LayerSpec(
        mixer="cross_attn", attn=common.attn_cfg(d, h, kv),
        ffn="mlp", mlp=common.mlp_cfg(d, ff), gated_cross=True,
        cross_kv_dim=vdim, d_model=d)
    cfg = lm.ModelConfig(
        name="llama-3.2-vision-90b" + ("-reduced" if reduced else ""),
        vocab=vocab, d_model=d, n_layers=n_layers,
        period=(self_l, self_l, self_l, self_l, cross_l),
        tie_embeddings=False, loss_chunk=1024)
    return api.ArchSpec(arch_id="llama-3.2-vision-90b", kind="vlm", cfg=cfg,
                        family="vlm", n_patches=patches, vision_dim=vdim,
                        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified")
