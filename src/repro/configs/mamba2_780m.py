"""mamba2-780m [ssm] — attention-free SSD. 48L d_model=1536 d_ff=0
vocab=50280, ssm_state=128. [arXiv:2405.21060; unverified]"""
from repro.configs import common
from repro.models import lm


def make(reduced: bool = False):
    if reduced:
        cfg = lm.ModelConfig(
            name="mamba2-reduced", vocab=256, d_model=64, n_layers=2,
            period=(common.ssm_layer(64, 16, head_dim=16, chunk=16),),
            tie_embeddings=True, loss_chunk=64)
    else:
        cfg = lm.ModelConfig(
            name="mamba2-780m", vocab=50_280, d_model=1_536, n_layers=48,
            period=(common.ssm_layer(1_536, 128, head_dim=64),),
            tie_embeddings=True, loss_chunk=2048)
    return common.lm_spec("mamba2-780m", "ssm", cfg, sub_quadratic=True,
                          source="arXiv:2405.21060; unverified")
