"""zamba2-1.2b [hybrid] — Mamba2 backbone + SHARED attention block.
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]

Mapping note (DESIGN.md §Arch-applicability): zamba2 interleaves one
weight-shared attention(+MLP) block among its mamba layers; here the 38
mamba2 layers lower as 19 scan repeats of period (ssm, ssm), and the
shared block applies once per repeat — same weight-sharing structure,
compile-time O(period).

long_500k eligibility: O(1) SSM state; the shared attention block's KV
cache is sequence-sharded under LONG_CONTEXT_RULES.
"""
from repro.configs import common
from repro.models import blocks, lm


def make(reduced: bool = False):
    if reduced:
        period = (common.ssm_layer(64, 16, head_dim=16, chunk=16),
                  common.ssm_layer(64, 16, head_dim=16, chunk=16))
        shared = common.dense_layer(64, 4, 4, 128)
        cfg = lm.ModelConfig(
            name="zamba2-reduced", vocab=256, d_model=64, n_layers=2,
            period=period, shared=shared, tie_embeddings=True,
            loss_chunk=64)
    else:
        period = (common.ssm_layer(2_048, 64, head_dim=64),
                  common.ssm_layer(2_048, 64, head_dim=64))
        shared = common.dense_layer(2_048, 32, 32, 8_192)
        cfg = lm.ModelConfig(
            name="zamba2-1.2b", vocab=32_000, d_model=2_048, n_layers=38,
            period=period, shared=shared, tie_embeddings=True,
            loss_chunk=2048)
    return common.lm_spec("zamba2-1.2b", "hybrid", cfg, sub_quadratic=True,
                          source="arXiv:2411.15242; hf")
