"""granite-moe-1b-a400m [moe] — 32 experts top-8, fine-grained d_ff=512.
24L d_model=1024 16H (kv=8) vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs import common
from repro.models import lm


def make(reduced: bool = False):
    if reduced:
        cfg = lm.ModelConfig(
            name="granite-moe-reduced", vocab=256, d_model=64, n_layers=2,
            period=(common.moe_layer(64, 4, 2, 64, 4, 2),),
            tie_embeddings=True, loss_chunk=64)
    else:
        cfg = lm.ModelConfig(
            name="granite-moe-1b-a400m", vocab=49_155, d_model=1_024,
            n_layers=24,
            period=(common.moe_layer(1_024, 16, 8, 512, 32, 8),),
            tie_embeddings=True, loss_chunk=2048)
    return common.lm_spec("granite-moe-1b-a400m", "moe", cfg,
                          source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf")
