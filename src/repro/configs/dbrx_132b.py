"""dbrx-132b [moe] — 16 experts top-4. 40L d_model=6144 48H (kv=8)
d_ff=10752 vocab=100352. [hf:databricks/dbrx-base; unverified]"""
from repro.configs import common
from repro.models import lm


def make(reduced: bool = False):
    if reduced:
        cfg = lm.ModelConfig(
            name="dbrx-reduced", vocab=256, d_model=64, n_layers=2,
            period=(common.moe_layer(64, 4, 2, 64, 4, 2),),
            tie_embeddings=False, loss_chunk=64)
    else:
        cfg = lm.ModelConfig(
            name="dbrx-132b", vocab=100_352, d_model=6_144, n_layers=40,
            period=(common.moe_layer(6_144, 48, 8, 10_752, 16, 4,
                                     theta=500_000.0),),
            tie_embeddings=False, loss_chunk=1024)
    return common.lm_spec("dbrx-132b", "moe", cfg,
                          source="hf:databricks/dbrx-base; unverified")
