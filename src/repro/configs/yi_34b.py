"""yi-34b [dense] — llama-arch GQA. 60L d_model=7168 56H (kv=8)
d_ff=20480 vocab=64000. [arXiv:2403.04652; hf]"""
from repro.configs import common
from repro.models import lm


def make(reduced: bool = False):
    if reduced:
        cfg = lm.ModelConfig(
            name="yi-34b-reduced", vocab=256, d_model=64, n_layers=2,
            period=(common.dense_layer(64, 4, 2, 128),),
            tie_embeddings=False, loss_chunk=64)
    else:
        cfg = lm.ModelConfig(
            name="yi-34b", vocab=64_000, d_model=7_168, n_layers=60,
            period=(common.dense_layer(7_168, 56, 8, 20_480,
                                       theta=5_000_000.0),),
            tie_embeddings=False, loss_chunk=2048)
    return common.lm_spec("yi-34b", "dense", cfg,
                          source="arXiv:2403.04652; hf")
