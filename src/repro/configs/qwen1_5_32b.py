"""qwen1.5-32b [dense] — QKV bias, near-MHA (kv=40). 64L d_model=5120 40H
(kv=40) d_ff=27392 vocab=152064. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs import common
from repro.models import lm


def make(reduced: bool = False):
    if reduced:
        cfg = lm.ModelConfig(
            name="qwen1.5-reduced", vocab=256, d_model=64, n_layers=2,
            period=(common.dense_layer(64, 4, 4, 128, bias=True),),
            tie_embeddings=False, loss_chunk=64)
    else:
        cfg = lm.ModelConfig(
            name="qwen1.5-32b", vocab=152_064, d_model=5_120, n_layers=64,
            period=(common.dense_layer(5_120, 40, 40, 27_392, bias=True,
                                       theta=1_000_000.0),),
            tie_embeddings=False, loss_chunk=1024)
    return common.lm_spec("qwen1.5-32b", "dense", cfg,
                          source="hf:Qwen/Qwen1.5-0.5B; hf")
