"""Architecture configs (assigned pool) + input shapes + registry."""
from repro.configs import registry, shapes  # noqa: F401
