"""tinyllama-1.1b [dense] — llama2-arch small. 22L d_model=2048 32H (kv=4)
d_ff=5632 vocab=32000. [arXiv:2401.02385; hf]"""
from repro.configs import common
from repro.models import lm


def make(reduced: bool = False):
    if reduced:
        cfg = lm.ModelConfig(
            name="tinyllama-reduced", vocab=256, d_model=64, n_layers=2,
            period=(common.dense_layer(64, 8, 2, 128),),
            tie_embeddings=False, loss_chunk=64)
    else:
        cfg = lm.ModelConfig(
            name="tinyllama-1.1b", vocab=32_000, d_model=2_048, n_layers=22,
            period=(common.dense_layer(2_048, 32, 4, 5_632),),
            tie_embeddings=False, loss_chunk=2048)
    return common.lm_spec("tinyllama-1.1b", "dense", cfg,
                          source="arXiv:2401.02385; hf")
