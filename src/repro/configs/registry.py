"""Architecture registry: ``get(arch_id, reduced=...)`` -> ArchSpec, plus
``input_specs`` producing ShapeDtypeStruct stand-ins for the dry-run and
concrete batches for smoke tests/examples."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import shapes as shapes_mod
from repro.configs import (dbrx_132b, gemma2_9b, granite_moe_1b,
                           llama32_vision_90b, mamba2_780m, qwen1_5_32b,
                           tinyllama_1_1b, whisper_tiny, yi_34b,
                           zamba2_1_2b)

ARCHS = {
    "yi-34b": yi_34b.make,
    "gemma2-9b": gemma2_9b.make,
    "tinyllama-1.1b": tinyllama_1_1b.make,
    "qwen1.5-32b": qwen1_5_32b.make,
    "zamba2-1.2b": zamba2_1_2b.make,
    "granite-moe-1b-a400m": granite_moe_1b.make,
    "dbrx-132b": dbrx_132b.make,
    "whisper-tiny": whisper_tiny.make,
    "llama-3.2-vision-90b": llama32_vision_90b.make,
    "mamba2-780m": mamba2_780m.make,
}


def get(arch_id: str, *, reduced: bool = False):
    return ARCHS[arch_id](reduced=reduced)


def list_archs():
    return sorted(ARCHS)


def _vocab(spec):
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    return cfg.vocab


def cell_supported(spec, shape: shapes_mod.Shape) -> tuple:
    """(supported, reason) — the brief's skip rules."""
    if shape.name == "long_500k" and not spec.sub_quadratic:
        return False, "full quadratic attention at 524k context"
    return True, ""


def input_specs(spec, shape: shapes_mod.Shape):
    """ShapeDtypeStruct stand-ins for every model input of the given shape
    cell (weak-type-correct, shardable, no allocation). For decode kinds
    this is the (token, index) pair — caches are built separately."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a seq_len cache
        batch = {"token": jax.ShapeDtypeStruct((b, 1), i32),
                 "index": jax.ShapeDtypeStruct((), i32)}
    if spec.kind == "encdec" and shape.kind != "decode":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, spec.n_frames, spec.cfg.d_model), jnp.bfloat16)
    if spec.kind == "vlm" and shape.kind != "decode":
        batch["patches"] = jax.ShapeDtypeStruct(
            (b, spec.n_patches, spec.vision_dim), jnp.bfloat16)
    return batch


def concrete_inputs(key, spec, shape: shapes_mod.Shape):
    """Small concrete batches for smoke tests (reduced shapes only)."""
    from repro.data import synthetic
    b, s = shape.global_batch, shape.seq_len
    vocab = _vocab(spec)
    if shape.kind == "train":
        batch = synthetic.lm_batch(key, b, s, vocab)
    elif shape.kind == "prefill":
        batch = {"tokens": synthetic.lm_batch(key, b, s, vocab)["tokens"]}
    else:
        batch = {"token": jnp.zeros((b, 1), jnp.int32),
                 "index": jnp.zeros((), jnp.int32)}
    if spec.kind == "encdec" and shape.kind != "decode":
        batch["frames"] = synthetic.frames(key, b, spec.n_frames,
                                           spec.cfg.d_model)
    if spec.kind == "vlm" and shape.kind != "decode":
        batch["patches"] = synthetic.patches(key, b, spec.n_patches,
                                             spec.vision_dim)
    return batch
