"""Assigned input shapes — 4 per LM-family architecture.

``decode_*`` and ``long_*`` lower ``serve_step`` (one new token against a
KV cache of the given length), not ``train_step``. ``long_500k`` requires
sub-quadratic attention and runs only for ssm/hybrid/local-attention archs
(skips recorded in the roofline table).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

REDUCED_SHAPES = {
    "train_4k": Shape("train_4k", 128, 2, "train"),
    "prefill_32k": Shape("prefill_32k", 256, 2, "prefill"),
    "decode_32k": Shape("decode_32k", 256, 2, "decode"),
    "long_500k": Shape("long_500k", 512, 1, "decode"),
}
