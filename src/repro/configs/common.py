"""Builders shared by the architecture config files."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.models import api, blocks, encdec, lm
from repro.nn import attention as attn_mod
from repro.nn import layers, moe as moe_mod, ssm as ssm_mod


def attn_cfg(d_model, heads, kv_heads, *, head_dim=None, bias=False,
             window=None, softcap=None, theta=10_000.0, causal=True):
    return attn_mod.AttentionConfig(
        d_model=d_model, num_heads=heads, num_kv_heads=kv_heads,
        head_dim=head_dim, use_qkv_bias=bias, sliding_window=window,
        attn_softcap=softcap, rope_theta=theta, causal=causal)


def mlp_cfg(d_model, d_ff, *, activation="swiglu"):
    return layers.MLPConfig(d_model=d_model, d_ff=d_ff, activation=activation)


def dense_layer(d_model, heads, kv_heads, d_ff, **kw):
    post_norm = kw.pop("post_norm", False)
    activation = kw.pop("activation", "swiglu")
    return blocks.LayerSpec(
        mixer="attn", attn=attn_cfg(d_model, heads, kv_heads, **kw),
        ffn="mlp", mlp=mlp_cfg(d_model, d_ff, activation=activation),
        post_norm=post_norm, d_model=d_model)


def moe_layer(d_model, heads, kv_heads, d_ff, n_experts, top_k, *,
              dispatch="gather", token_shards=16, **kw):
    # gather dispatch + group-local (data-shard) routing is the shipped
    # default (§Perf: the dense one-hot dispatch costs O(N·E·C·d) matmul
    # FLOPs and an SPMD-replicated capacity buffer). dispatch="dense" is
    # the Switch/Mesh-style ablation.
    return blocks.LayerSpec(
        mixer="attn", attn=attn_cfg(d_model, heads, kv_heads, **kw),
        ffn="moe",
        moe=moe_mod.MoEConfig(d_model=d_model, d_ff=d_ff,
                              num_experts=n_experts, top_k=top_k,
                              dispatch=dispatch, token_shards=token_shards),
        d_model=d_model)


def ssm_layer(d_model, state, *, head_dim=64, chunk=128):
    return blocks.LayerSpec(
        mixer="ssm",
        ssm=ssm_mod.SSMConfig(d_model=d_model, state=state,
                              head_dim=head_dim, chunk=chunk),
        ffn="none", d_model=d_model)


def lm_spec(arch_id, family, cfg, *, sub_quadratic=False, source="",
            **extra):
    return api.ArchSpec(arch_id=arch_id, kind="lm", cfg=cfg, family=family,
                        sub_quadratic=sub_quadratic, source=source, **extra)
