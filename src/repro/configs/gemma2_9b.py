"""gemma2-9b [dense] — local+global alternating attention, logit softcaps,
post-norms, tied embeddings with sqrt(d) scaling. 42L d_model=3584 16H
(kv=8, head_dim=256) d_ff=14336 vocab=256000. [arXiv:2408.00118; hf]

long_500k eligibility: half the layers are sliding-window-4096 (O(T·w));
the global layers use a sequence-sharded KV cache (LONG_CONTEXT_RULES).
"""
from repro.configs import common
from repro.models import lm

WINDOW = 4_096


def make(reduced: bool = False):
    if reduced:
        local = common.dense_layer(64, 4, 2, 128, head_dim=16, window=32,
                                   softcap=50.0, post_norm=True,
                                   activation="gelu")
        glob = common.dense_layer(64, 4, 2, 128, head_dim=16,
                                  softcap=50.0, post_norm=True,
                                  activation="gelu")
        cfg = lm.ModelConfig(
            name="gemma2-9b-reduced", vocab=256, d_model=64, n_layers=2,
            period=(local, glob), tie_embeddings=True, final_softcap=30.0,
            embed_scale=True, loss_chunk=64)
    else:
        local = common.dense_layer(3_584, 16, 8, 14_336, head_dim=256,
                                   window=WINDOW, softcap=50.0,
                                   post_norm=True, activation="gelu")
        glob = common.dense_layer(3_584, 16, 8, 14_336, head_dim=256,
                                  softcap=50.0, post_norm=True,
                                  activation="gelu")
        cfg = lm.ModelConfig(
            name="gemma2-9b", vocab=256_000, d_model=3_584, n_layers=42,
            period=(local, glob), tie_embeddings=True, final_softcap=30.0,
            embed_scale=True, loss_chunk=1024)
    return common.lm_spec("gemma2-9b", "dense", cfg, sub_quadratic=True,
                          source="arXiv:2408.00118; hf")
