"""Generic vectorized rollout: ``lax.scan`` over time of (policy step →
env step), with auto-reset at episode boundaries. Parameterized by
closures so the same machinery rolls the GS (joint multi-agent) and the
IALS (per-agent local sims driven by AIP samples).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Transition(NamedTuple):
    obs: jax.Array          # (..., O) observation BEFORE the step
    action: jax.Array
    logp: jax.Array
    value: jax.Array
    reward: jax.Array
    done: jax.Array         # episode ended at this step
    h_pre: jax.Array        # policy hidden BEFORE the step


def rollout(carry0, steps: int, step_fn: Callable):
    """carry0: rollout state; step_fn(carry, key) -> (carry, Transition).
    Returns (carry, traj) with traj leaves (T, ...)."""
    def body(carry, key):
        return step_fn(carry, key)

    carry, keys = carry0
    final, traj = jax.lax.scan(body, carry, keys)
    return final, traj


def time_major_to_env_major(traj):
    """(T, E, ...) -> (E, T, ...)."""
    return jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), traj)
