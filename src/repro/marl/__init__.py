"""Independent PPO (IPPO) MARL stack: actor-critic policies (FNN/GRU),
GAE, PPO updates, and batched multi-agent runners."""
from repro.marl import gae, policy, ppo, rollout, runner  # noqa: F401
