"""PPO (Schulman et al. 2017) with the paper's Table-6 hyperparameters.
Clipped surrogate + clipped value loss + entropy bonus; minibatch epochs
over parallel envs; GRU policies recompute through the rollout chunk from
the stored initial hidden state (reset at episode boundaries).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.marl import policy as policy_mod
from repro.optim import adamw, clip as clip_mod


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    lr: float = 2.5e-4
    gamma: float = 0.99
    lam: float = 0.95
    clip_eps: float = 0.1
    entropy_coef: float = 1e-2
    value_coef: float = 1.0
    epochs: int = 3
    minibatches: int = 4
    max_grad_norm: float = 0.5
    use_kernels: str = "auto"     # Pallas GAE reverse scan in the inner
    #                               step: auto (kernel on TPU) | on | off


def ppo_loss(params, batch, policy_cfg: policy_mod.PolicyConfig,
             cfg: PPOConfig):
    """batch: obs (B,T,O), actions (B,T), logp_old (B,T), adv (B,T),
    ret (B,T), values_old (B,T), h0 (B,H), resets (B,T)."""
    logits, values = policy_mod.policy_sequence(
        params, batch["obs"], batch["h0"], batch["resets"], policy_cfg)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch["actions"][..., None],
                               axis=-1)[..., 0]
    ratio = jnp.exp(logp - batch["logp_old"])
    adv = batch["adv"]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps) * adv
    pi_loss = -jnp.minimum(unclipped, clipped).mean()

    v_clip = batch["values_old"] + jnp.clip(
        values - batch["values_old"], -cfg.clip_eps, cfg.clip_eps)
    v_loss = 0.5 * jnp.maximum((values - batch["ret"]) ** 2,
                               (v_clip - batch["ret"]) ** 2).mean()

    entropy = -(jnp.exp(logp_all) * logp_all).sum(-1).mean()
    loss = pi_loss + cfg.value_coef * v_loss - cfg.entropy_coef * entropy
    return loss, {"pi_loss": pi_loss, "v_loss": v_loss, "entropy": entropy,
                  "ratio_max": ratio.max()}


def ppo_update(params, opt_state, traj, key,
               policy_cfg: policy_mod.PolicyConfig, cfg: PPOConfig):
    """traj leaves shaped (E, T, ...) (plus h0 (E, H)). Runs
    epochs × minibatches SGD. Returns (params, opt_state, metrics)."""
    n_envs = traj["obs"].shape[0]
    mb = max(1, n_envs // cfg.minibatches)

    def one_minibatch(carry, idx):
        params, opt_state = carry
        batch = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), traj)
        (loss, metrics), grads = jax.value_and_grad(
            ppo_loss, has_aux=True)(params, batch, policy_cfg, cfg)
        grads, gnorm = clip_mod.clip_by_global_norm(grads, cfg.max_grad_norm)
        master, opt_state = adamw.update(
            grads, opt_state, cfg.lr,
            adamw.AdamWConfig(b1=0.9, b2=0.999, weight_decay=0.0))
        params = adamw.cast_like(master, params)
        return (params, opt_state), {**metrics, "loss": loss, "gnorm": gnorm}

    def one_epoch(carry, ekey):
        perm = jax.random.permutation(ekey, n_envs)
        idxs = perm[:cfg.minibatches * mb].reshape(cfg.minibatches, mb)
        carry, metrics = jax.lax.scan(one_minibatch, carry, idxs)
        return carry, metrics

    (params, opt_state), metrics = jax.lax.scan(
        one_epoch, (params, opt_state), jax.random.split(key, cfg.epochs))
    metrics = jax.tree.map(lambda x: x.mean(), metrics)
    return params, opt_state, metrics
