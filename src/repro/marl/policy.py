"""Actor-critic policy networks (paper Table 5): FNN (traffic) and GRU
(warehouse), functional over pytrees. Per-agent parameter sets are stacked
along a leading agent axis and applied with ``vmap`` — N agents' policies
evaluate as one batched matmul program (the TPU analogue of the paper's
one-process-per-agent).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn import gru as gru_mod
from repro.nn import init as initializers


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    obs_dim: int
    n_actions: int
    kind: str = "fnn"             # fnn | gru
    hidden: Tuple[int, ...] = (256, 128)
    gru_hidden: int = 128
    use_kernels: str = "auto"     # Pallas GRU scan in policy_sequence:
    #                               auto (kernel on TPU) | on | off


def _dense_init(key, din, dout, scale=None):
    w = (initializers.orthogonal(scale)(key, (din, dout), jnp.float32)
         if scale is not None else
         initializers.orthogonal(jnp.sqrt(2.0))(key, (din, dout), jnp.float32))
    return {"w": w, "b": jnp.zeros((dout,), jnp.float32)}


def _dense(p, x):
    return x @ p["w"] + p["b"]


def policy_init(key, cfg: PolicyConfig):
    keys = jax.random.split(key, 6)
    params = {}
    din = cfg.obs_dim
    trunk = []
    for i, h in enumerate(cfg.hidden):
        trunk.append(_dense_init(keys[i], din, h))
        din = h
    params["trunk"] = trunk
    if cfg.kind == "gru":
        params["gru"] = gru_mod.gru_init(
            keys[3], gru_mod.GRUConfig(in_dim=din, hidden=cfg.gru_hidden))
        din = cfg.gru_hidden
    params["pi"] = _dense_init(keys[4], din, cfg.n_actions, scale=0.01)
    params["v"] = _dense_init(keys[5], din, 1, scale=1.0)
    return params


def initial_hidden(cfg: PolicyConfig, *batch) -> jax.Array:
    return jnp.zeros(tuple(batch) + (cfg.gru_hidden,), jnp.float32)


def _trunk(params, obs):
    x = obs
    for p in params["trunk"]:
        x = jax.nn.relu(_dense(p, x))
    return x


def policy_apply(params, obs, h, cfg: PolicyConfig):
    """One step. obs: (..., O); h: (..., H). Returns (logits, value, h')."""
    x = _trunk(params, obs)
    if cfg.kind == "gru":
        flat = x.reshape(-1, x.shape[-1])
        hf = h.reshape(-1, h.shape[-1])
        hf = gru_mod.gru_cell(params["gru"], hf, flat,
                              use_kernels=cfg.use_kernels)
        h = hf.reshape(h.shape)
        x = h
    logits = _dense(params["pi"], x)
    value = _dense(params["v"], x)[..., 0]
    return logits, value, h


def policy_sequence(params, obs_seq, h0, reset_mask, cfg: PolicyConfig):
    """Recompute over a rollout chunk for PPO. obs_seq: (B, T, O);
    h0: (B, H); reset_mask: (B, T). Returns (logits (B,T,A), values (B,T))."""
    x = _trunk(params, obs_seq)
    if cfg.kind == "gru":
        hs, _ = gru_mod.gru_sequence(params["gru"], x, h0,
                                     reset_mask=reset_mask,
                                     use_kernels=cfg.use_kernels)
        x = hs
    logits = _dense(params["pi"], x)
    values = _dense(params["v"], x)[..., 0]
    return logits, values


def sample_action(key, logits):
    a = jax.random.categorical(key, logits)
    logp = jax.nn.log_softmax(logits)
    return a, jnp.take_along_axis(logp, a[..., None], axis=-1)[..., 0]
