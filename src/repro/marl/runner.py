"""Batched multi-agent IPPO runners.

``make_gs_trainer`` trains all N agents *jointly on the global simulator*
(the paper's "GS" baseline): E parallel GS copies roll for T steps per
iteration, then every agent takes a PPO update — the whole iteration is a
single jitted program, with the agent axis vmapped (the TPU analogue of
the paper's one-process-per-agent, here one *mesh-shard*-per-agent-group).

``evaluate`` measures the mean per-agent episodic return on the GS —
the paper's periodic evaluation protocol.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.marl import gae as gae_mod
from repro.marl import policy as policy_mod
from repro.marl import ppo as ppo_mod
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class RunConfig:
    n_envs: int = 16
    rollout_steps: int = 16


def _reset_where(done, fresh, current):
    """Vectorized auto-reset: done (E,) selects fresh env states."""
    def sel(f, c):
        d = done.reshape((-1,) + (1,) * (c.ndim - 1))
        return jnp.where(d, f, c)
    return jax.tree.map(sel, fresh, current)


def make_gs_trainer(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                    ppo_cfg: ppo_mod.PPOConfig, run_cfg: RunConfig):
    info = env_cfg.info()
    n_agents, n_envs, t_steps = info.n_agents, run_cfg.n_envs, run_cfg.rollout_steps

    v_gs_init = jax.vmap(lambda k: env_mod.gs_init(k, env_cfg))
    v_gs_step = jax.vmap(lambda s, a, k: env_mod.gs_step(s, a, k, env_cfg))
    v_gs_obs = jax.vmap(lambda s: env_mod.gs_obs(s, env_cfg))

    # policy over stacked agents: params (N,...), obs (E,N,O), h (E,N,H)
    apply_agents = jax.vmap(
        lambda p, o, h: policy_mod.policy_apply(p, o, h, policy_cfg),
        in_axes=(0, 1, 1), out_axes=(1, 1, 1))

    def init_fn(key):
        kp, ke, kr = jax.random.split(key, 3)
        params = jax.vmap(lambda k: policy_mod.policy_init(k, policy_cfg))(
            jax.random.split(kp, n_agents))
        opt = jax.vmap(adamw.init)(params)
        env_state = v_gs_init(jax.random.split(ke, n_envs))
        obs = v_gs_obs(env_state)
        h = policy_mod.initial_hidden(policy_cfg, n_envs, n_agents)
        return {"params": params, "opt": opt, "env": env_state, "obs": obs,
                "h": h, "key": kr, "iter": jnp.zeros((), jnp.int32)}

    def _rollout(state):
        def step(carry, key):
            env, obs, h, prev_done = carry
            k_act, k_env, k_reset = jax.random.split(key, 3)
            logits, value, h_new = apply_agents(state["params"], obs, h)
            action, logp = policy_mod.sample_action(k_act, logits)  # (E,N)
            env2, obs2, rew, u, done = v_gs_step(
                env, action, jax.random.split(k_env, n_envs))
            fresh = v_gs_init(jax.random.split(k_reset, n_envs))
            env3 = _reset_where(done, fresh, env2)
            obs3 = jnp.where(done[:, None, None], v_gs_obs(env3), obs2)
            h3 = jnp.where(done[:, None, None], jnp.zeros_like(h_new), h_new)
            tr = {"obs": obs, "action": action, "logp": logp, "value": value,
                  "reward": rew, "done": jnp.broadcast_to(
                      done[:, None], rew.shape), "h_pre": h,
                  # marks "new episode starts at this step" (GRU reset)
                  "reset_pre": jnp.broadcast_to(prev_done[:, None], rew.shape)}
            return (env3, obs3, h3, done), tr

        (env, obs, h, _), traj = jax.lax.scan(
            step, (state["env"], state["obs"], state["h"],
                   jnp.zeros((n_envs,), bool)),
            jax.random.split(state["key"], t_steps))
        return (env, obs, h), traj          # traj leaves (T, E, N, ...)

    def train_fn(state):
        k_iter = jax.random.fold_in(state["key"], state["iter"])
        state = {**state, "key": k_iter}
        (env, obs, h), traj = _rollout(state)

        # bootstrap value for the state after the last step
        _, last_value, _ = apply_agents(state["params"], obs, h)  # (E, N)

        # GAE per agent: reorder to (N, E, T)
        def nea(x):
            return jnp.moveaxis(x, (0, 1, 2), (2, 0, 1))  # (T,E,N)->(E,N,T)
        rewards, values, dones = map(nea, (traj["reward"],
                                           traj["value"], traj["done"]))
        adv, ret = gae_mod.gae(rewards, values, dones,
                               jnp.moveaxis(last_value, 0, 0),
                               gamma=ppo_cfg.gamma, lam=ppo_cfg.lam,
                               use_kernels=ppo_cfg.use_kernels)

        # PPO per agent. batch leaves (N, E, T, ...)
        def net(x):                           # (T,E,N,...) -> (N,E,T,...)
            return jnp.moveaxis(x, (0, 1, 2), (2, 1, 0))
        batch = {
            "obs": net(traj["obs"]),
            "actions": net(traj["action"]).astype(jnp.int32),
            "logp_old": net(traj["logp"]),
            "values_old": net(traj["value"]),
            "adv": jnp.swapaxes(adv, 0, 1),   # (E,N,T) -> (N,E,T)
            "ret": jnp.swapaxes(ret, 0, 1),
            "resets": net(traj["reset_pre"]).astype(jnp.float32),
            "h0": jnp.moveaxis(traj["h_pre"][0], 1, 0),   # (N, E, H)
        }
        # adv/ret currently (E, N, T) -> want (N, E, T)
        keys = jax.random.split(jax.random.fold_in(k_iter, 1), n_agents)
        new_params, new_opt, metrics = jax.vmap(
            lambda p, o, b, k: ppo_mod.ppo_update(p, o, b, k, policy_cfg,
                                                  ppo_cfg))(
            state["params"], state["opt"], batch, keys)
        mean_rew = traj["reward"].mean()
        return {**state, "params": new_params, "opt": new_opt, "env": env,
                "obs": obs, "h": h, "iter": state["iter"] + 1}, \
            {**jax.tree.map(jnp.mean, metrics), "reward": mean_rew}

    def eval_fn(params, key, *, episodes: int = 4):
        """Deterministic (argmax) evaluation: mean per-step reward over
        full episodes, averaged over agents — the paper's metric."""
        ke, kr = jax.random.split(key)
        env = v_gs_init(jax.random.split(ke, episodes))
        obs = v_gs_obs(env)
        h = policy_mod.initial_hidden(policy_cfg, episodes, n_agents)

        def step(carry, k):
            env, obs, h = carry
            logits, _, h = apply_agents(params, obs, h)
            action = jnp.argmax(logits, axis=-1)
            env, obs, rew, _, done = v_gs_step(
                env, action, jax.random.split(k, episodes))
            return (env, obs, h), rew

        _, rews = jax.lax.scan(step, (env, obs, h),
                               jax.random.split(kr, info.horizon))
        return rews.mean()

    return init_fn, jax.jit(train_fn), jax.jit(eval_fn, static_argnames="episodes")
