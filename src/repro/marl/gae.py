"""Generalized Advantage Estimation — reverse `lax.scan`.

This is the jnp oracle; ``repro.kernels.gae`` holds the Pallas fused
backward-scan kernel (batched over agents×envs) validated against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(rewards, values, dones, last_value, *, gamma: float = 0.99,
        lam: float = 0.95, use_kernels="off"):
    """rewards/values/dones: (..., T); last_value: (...,).

    ``dones[t]`` marks that the episode ended AT step t (no bootstrap
    across it). Returns (advantages, returns) with returns = adv + values.

    ``use_kernels`` (``"auto" | "on" | "off"`` or a pre-resolved
    decision) routes to the fused Pallas reverse scan
    (``repro.kernels.gae``, custom-VJP'd). Default ``"off"`` keeps this
    the pure oracle; the IALS inner step threads ``PPOConfig.use_kernels``.
    """
    from repro.kernels import dispatch
    decision = dispatch.resolve(use_kernels)
    if decision.use:
        from repro.kernels.gae import ops as gae_ops
        return gae_ops.gae(rewards, values, dones, last_value,
                           gamma=gamma, lam=lam,
                           interpret=decision.interpret)
    # accumulate the scan in f32 regardless of input precision (the
    # (1 - d) masking promotes to f32 anyway, which under bf16 inputs
    # used to desync the carry dtype), then cast back so bf16 in means
    # bf16 out — the DtypeRoundTrip contract
    out_dtype = values.dtype
    t_axis = rewards.ndim - 1
    rw = jnp.moveaxis(rewards, t_axis, 0).astype(jnp.float32)
    vl = jnp.moveaxis(values, t_axis, 0).astype(jnp.float32)
    dn = jnp.moveaxis(dones.astype(jnp.float32), t_axis, 0)
    next_values = jnp.concatenate(
        [vl[1:], last_value[None].astype(jnp.float32)], axis=0)

    def step(carry, inp):
        r, v, nv, d = inp
        delta = r + gamma * nv * (1.0 - d) - v
        adv = delta + gamma * lam * (1.0 - d) * carry
        return adv, adv

    _, advs = jax.lax.scan(step,
                           jnp.zeros(last_value.shape, jnp.float32),
                           (rw, vl, next_values, dn), reverse=True)
    advs = jnp.moveaxis(advs, 0, t_axis).astype(out_dtype)
    return advs, advs + values
