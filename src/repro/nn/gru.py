"""GRU (Cho et al., 2014) — the paper's AIP/policy recurrent core.

Functional cell + ``lax.scan`` sequence application. The Pallas kernel in
``repro.kernels.gru`` fuses the gate matmuls + elementwise updates per step;
this module is the jnp oracle and the default CPU path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class GRUConfig:
    in_dim: int
    hidden: int
    dtype: object = jnp.float32


def gru_init(key, cfg: GRUConfig):
    ki, kh = jax.random.split(key)
    # Fused gates: [reset | update | candidate] along the output axis.
    return {
        "wi": initializers.fan_in_normal(0)(ki, (cfg.in_dim, 3 * cfg.hidden), cfg.dtype),
        "wh": initializers.orthogonal()(kh, (cfg.hidden, 3 * cfg.hidden), cfg.dtype),
        "bi": jnp.zeros((3 * cfg.hidden,), cfg.dtype),
        "bh": jnp.zeros((3 * cfg.hidden,), cfg.dtype),
    }


def gru_logical_specs(cfg: GRUConfig):
    return {"wi": ("embed", "mlp"), "wh": ("mlp", "mlp"),
            "bi": ("mlp",), "bh": ("mlp",)}


def gru_cell(params, h, x, use_kernels="off"):
    """One step. h: (B, H); x: (B, in_dim). Returns new h.

    ``use_kernels`` (mode string or pre-resolved ``KernelDecision``)
    routes the step to the fused Pallas cell (``repro.kernels.gru`` at
    T=1) — the GS/LS rollout policy step's fast path. Default ``"off"``
    keeps this the pure oracle (and the body of the oracle scan in
    :func:`gru_sequence` below); config-driven call sites (policy/AIP
    ``*_apply``) thread their own knob through.
    """
    from repro.kernels import dispatch
    decision = dispatch.resolve(use_kernels)
    if decision.use:
        from repro.kernels.gru import ops as gru_ops
        return gru_ops.gru_cell(params, h, x,
                                interpret=decision.interpret)
    gi = layers.dot(x, params["wi"]) + params["bi"].astype(x.dtype)
    gh = layers.dot(h, params["wh"]) + params["bh"].astype(h.dtype)
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid((i_r + h_r).astype(jnp.float32))
    z = jax.nn.sigmoid((i_z + h_z).astype(jnp.float32))
    n = jnp.tanh((i_n + r * h_n).astype(jnp.float32))
    new_h = (1.0 - z) * n + z * h.astype(jnp.float32)
    return new_h.astype(h.dtype)


def gru_sequence(params, xs, h0=None, *, reset_mask=None,
                 use_kernels="off"):
    """xs: (B, T, in_dim) -> hs: (B, T, H).

    ``reset_mask`` (B, T) of {0,1}: 1 resets the hidden state *before*
    consuming that step's input (episode boundaries in rollouts).

    ``use_kernels`` (``"auto" | "on" | "off"`` or a pre-resolved
    ``repro.kernels.dispatch.KernelDecision``) routes the whole sequence
    to the fused Pallas scan (``repro.kernels.gru``) instead of the
    ``lax.scan`` below. Default ``"off"`` keeps this function the pure
    oracle the kernel is validated against; config-driven call sites
    (AIP, policy) thread their own knob through.
    """
    from repro.kernels import dispatch
    decision = dispatch.resolve(use_kernels)
    if decision.use:
        from repro.kernels.gru import ops as gru_ops
        return gru_ops.gru_sequence(params, xs, h0, reset_mask=reset_mask,
                                    interpret=decision.interpret)

    b, t, _ = xs.shape
    hidden = params["wh"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((b, hidden), xs.dtype)

    def step(h, inp):
        x, m = inp
        h = h * (1.0 - m[:, None].astype(h.dtype))
        h = gru_cell(params, h, x)
        return h, h

    xs_t = jnp.swapaxes(xs, 0, 1)                     # (T, B, in)
    ms_t = (jnp.swapaxes(reset_mask, 0, 1).astype(xs.dtype)
            if reset_mask is not None
            else jnp.zeros((t, b), xs.dtype))         # 1-m == 1: identity
    h_last, hs = jax.lax.scan(step, h0, (xs_t, ms_t))
    return jnp.swapaxes(hs, 0, 1), h_last
