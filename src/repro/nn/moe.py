"""Mixture-of-Experts: top-k token-choice router with capacity, einsum
dispatch/combine (Switch/Mesh-style), expert-parallel over the ``expert``
logical axis.

Design notes (TPU adaptation)
-----------------------------
* Experts are stacked along a leading E axis and sharded over the ``model``
  mesh axis (expert parallelism). Dispatch/combine are einsums against
  one-hot tensors, which XLA lowers to all-to-all when the token and expert
  shardings differ — no manual collective needed for the dry-run path.
* Capacity factor bounds per-expert work so the kernel is static-shaped
  (required for jit) and gives the classic dropped-token semantics.
* Router runs in fp32; auxiliary load-balancing loss (Shazeer et al.) and
  router z-loss (ST-MoE) are returned for the trainer to weigh in.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                     # per-expert hidden
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "swiglu"
    dtype: object = jnp.bfloat16
    # "dense": one-hot einsum dispatch (Switch/Mesh style — O(N·E·C·d)
    # extra matmul FLOPs). "gather": scatter/gather routing — removes the
    # dispatch matmuls entirely (§Perf hillclimb; same semantics).
    dispatch: str = "dense"
    # gather path only: route/capacity computed per token-group (groups =
    # contiguous batch slices = the data shards). Keeps the position scan
    # and the capacity buffers SHARDED over the data axis instead of one
    # global buffer the SPMD partitioner must replicate. 1 = global.
    token_shards: int = 1

    def capacity(self, tokens: int) -> int:
        cap = int(math.ceil(tokens * self.top_k / self.num_experts
                            * self.capacity_factor))
        # MXU-friendly: round up to a multiple of 8, min 8.
        return max(8, -(-cap // 8) * 8)


def moe_init(key, cfg: MoEConfig):
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, dm, df = cfg.num_experts, cfg.d_model, cfg.d_ff
    w_in = initializers.fan_in_normal(axis=1)   # fan-in = d_model (axis 1 of (E, dm, df))
    w_out = initializers.fan_in_normal(axis=1)  # fan-in = d_ff
    params = {
        "router": initializers.truncated_normal(dm ** -0.5)(kr, (dm, e), jnp.float32),
        "up": w_in(ku, (e, dm, df), cfg.dtype),
        "down": w_out(kd, (e, df, dm), cfg.dtype),
    }
    if cfg.activation == "swiglu":
        params["gate"] = w_in(kg, (e, dm, df), cfg.dtype)
    return params


def moe_logical_specs(cfg: MoEConfig):
    specs = {
        "router": ("embed", None),
        "up": ("expert", "embed", "mlp"),
        "down": ("expert", "mlp", "embed"),
    }
    if cfg.activation == "swiglu":
        specs["gate"] = ("expert", "embed", "mlp")
    return specs


def router_probs(params, x, cfg: MoEConfig):
    """x: (..., d_model) -> router probabilities (..., E), fp32."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        params["router"])
    return jax.nn.softmax(logits, axis=-1), logits


def _route(params, xf, cfg: MoEConfig):
    """Shared router math: returns (top_w, top_e, pos, keep, aux)."""
    tokens = xf.shape[0]
    probs, logits = router_probs(params, xf, cfg)            # (N, E)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)           # (N, k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    cap = cfg.capacity(tokens)
    e = cfg.num_experts
    # Position of each (token, k) within its chosen expert's buffer.
    # associative_scan, NOT jnp.cumsum: the reduce-window lowering of
    # cumsum over N·k rows costs O((N·k)^2) in the XLA cost model (and on
    # some backends in practice); the log-depth scan is O(N·k·E·log).
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)       # (N, k, E)
    flat = onehot.reshape(tokens * cfg.top_k, e)
    pos = jax.lax.associative_scan(jnp.add, flat, axis=0) - flat
    pos = (pos * flat).sum(-1).reshape(tokens, cfg.top_k)    # (N, k)
    keep = pos < cap

    me = probs.mean(0)
    ce = (jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32)).mean(0)
    aux = {"load_balance": e * jnp.sum(me * ce),
           "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)}
    return top_w, top_e, pos, keep, cap, aux


def _expert_mlp(params, xe, cfg: MoEConfig):
    """xe: (E, C, dm) -> (E, C, dm), batched over experts."""
    up = jnp.einsum("ecd,edf->ecf", xe, params["up"],
                    preferred_element_type=jnp.float32).astype(xe.dtype)
    if cfg.activation == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", xe, params["gate"],
                          preferred_element_type=jnp.float32).astype(xe.dtype)
        h = layers.swiglu(gate, up)
    else:
        h = layers.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, params["down"],
                      preferred_element_type=jnp.float32).astype(xe.dtype)


def moe_layer(params, x, cfg: MoEConfig):
    """x: (B, T, d_model) -> (y, aux) with aux = {load_balance, z_loss}.

    Token-choice top-k with capacity; dropped tokens pass through (their
    combine weights are zero, so the residual carries them).
    """
    if cfg.dispatch == "gather":
        return moe_layer_gather(params, x, cfg)
    b, t, dm = x.shape
    tokens = b * t
    xf = x.reshape(tokens, dm)
    top_w, top_e, pos, keep, cap, aux = _route(params, xf, cfg)
    e = cfg.num_experts

    # dispatch: (N, E, C) one-hot; combine: dispatch * weight
    disp = (jax.nn.one_hot(top_e, e, dtype=xf.dtype)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=xf.dtype)[:, :, None, :]
            * keep[..., None, None].astype(xf.dtype))        # (N, k, E, C)
    combine = (disp * top_w[..., None, None].astype(xf.dtype)).sum(1)
    disp = disp.sum(1)                                       # (N, E, C)

    # Route tokens to expert buffers: (E, C, dm)
    xe = jnp.einsum("nec,nd->ecd", disp, xf,
                    preferred_element_type=jnp.float32).astype(xf.dtype)
    ye = _expert_mlp(params, xe, cfg)
    y = jnp.einsum("nec,ecd->nd", combine, ye,
                   preferred_element_type=jnp.float32).astype(xf.dtype)
    return y.reshape(b, t, dm), aux


def moe_layer_gather(params, x, cfg: MoEConfig):
    """Same semantics as :func:`moe_layer`, but the dispatch/combine are a
    row scatter and a row gather instead of one-hot matmuls.

    The dense dispatch costs 2·N·E·C·d extra matmul FLOPs per layer
    (N·E·C·d each way); with fine-grained experts (granite: d_ff=512,
    E=32) that exceeds the expert MLP compute itself (ratio
    N / (3·d_ff) ≈ 2.7). The scatter/gather form moves O(N·k·d) bytes and
    adds zero matmul FLOPs; each buffer slot receives at most one token
    (positions are unique by construction), so a "drop"-mode scatter-set
    is exact — no accumulation order ambiguity.
    """
    b, t, dm = x.shape
    tokens = b * t
    e = cfg.num_experts
    # group count falls back to 1 when tokens don't split (tiny smoke
    # shapes, single-token decode)
    s = cfg.token_shards if tokens % cfg.token_shards == 0 else 1
    n_loc = tokens // s
    # token groups are contiguous batch slices — exactly the data shards
    # when batch is sharded over ("pod","data")
    xg = x.reshape(s, n_loc, dm)

    # per-group routing (group-local positions and capacity)
    def route_group(xs):
        top_w, top_e, pos, keep, _cap, aux = _route(params, xs, cfg)
        return top_w, top_e, pos, keep, aux
    top_w, top_e, pos, keep, aux = jax.vmap(route_group)(xg)
    aux = jax.tree.map(jnp.mean, aux)
    cap = cfg.capacity(n_loc)

    # buffer slot for every (group, token, k): e*C + c; dropped -> OOB
    slot = jnp.where(keep, top_e * cap + pos, e * cap)       # (S, n, k)
    flat_slot = slot.reshape(s, -1)                          # (S, n*k)
    token_idx = jnp.repeat(jnp.arange(n_loc), cfg.top_k)     # (n*k,)

    def disp(xs, sl):
        # xs (n, dm); sl (n*k,) -> (E, C, dm); unique slots, OOB drops
        return jnp.zeros((e * cap, dm), xs.dtype) \
            .at[sl].set(xs[token_idx], mode="drop") \
            .reshape(e, cap, dm)
    xe = jax.vmap(disp)(xg, flat_slot)                       # (S, E, C, dm)

    ye = jax.vmap(lambda v: _expert_mlp(params, v, cfg))(xe) \
        .reshape(s, e * cap, dm)

    def comb(ys, sl, w):
        ye_pad = jnp.concatenate([ys, jnp.zeros((1, dm), ys.dtype)], axis=0)
        rows = ye_pad[sl].reshape(n_loc, cfg.top_k, dm)      # (n, k, dm)
        return jnp.einsum("nk,nkd->nd", w, rows,
                          preferred_element_type=jnp.float32)
    w = (top_w * keep).reshape(s, n_loc, cfg.top_k).astype(ye.dtype)
    y = jax.vmap(comb)(ye, flat_slot, w).astype(x.dtype)
    return y.reshape(b, t, dm), aux
