"""Pure-JAX neural-network substrate (no flax): functional layers over pytrees."""
from repro.nn import attention, gru, init, layers, moe, ssm  # noqa: F401
