"""Mamba-2 SSD (state-space duality) layer — chunked parallel form for
train/prefill and the O(1)-state recurrent form for decode.

TPU adaptation: the chunked algorithm (Dao & Gu 2024) is the natural fit for
the MXU — each chunk is a (L×L)·(L×P) block matmul; the inter-chunk
recurrence is a short ``lax.scan`` over T/L steps carrying the (H, P, N)
state. The Pallas kernel in ``repro.kernels.ssd`` fuses the intra-chunk
block; this module is the jnp oracle.

Shapes: x (B, T, d_model); inner activations (B, T, H, P) with
H = d_inner // head_dim heads, P = head_dim, N = ssm state size.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    state: int = 128            # N
    head_dim: int = 64          # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128            # SSD chunk length
    dtype: object = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, cfg: SSMConfig):
    kin, kconv, kdt, kout = jax.random.split(key, 4)
    di, n, h = cfg.d_inner, cfg.state, cfg.num_heads
    # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
    proj_out = 2 * di + 2 * n + h
    conv_ch = di + 2 * n          # conv over x, B, C
    return {
        "in_proj": initializers.fan_in_normal(0)(
            kin, (cfg.d_model, proj_out), cfg.dtype),
        "conv_w": initializers.fan_in_normal(0)(
            kconv, (cfg.conv_width, conv_ch), cfg.dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(                       # inv-softplus of ~1e-2..1e-1
            jnp.linspace(1e-3, 1e-1, h, dtype=jnp.float32))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": layers.rmsnorm_init(di),
        "out_proj": initializers.fan_in_normal(0)(
            kout, (di, cfg.d_model), cfg.dtype),
        "dt_w": initializers.fan_in_normal(0)(kdt, (1,), jnp.float32),  # placeholder keeps tree static
    }


def ssm_logical_specs(cfg: SSMConfig):
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "a_log": (None,),
        "dt_bias": (None,),
        "d_skip": (None,),
        "norm": {"scale": ("mlp",)},
        "out_proj": ("mlp", "embed"),
        "dt_w": (None,),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, b):
    """x: (B, T, C); w: (W, C) depthwise; left-pad so output is causal."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    # Sum of shifted slices — unrolled, W is tiny (4).
    t = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + t, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------
def _segsum(a):
    """a: (..., L). Returns (..., L, L) with out[i,j] = sum_{k=j+1..i} a_k
    (i >= j), -inf elsewhere — so exp() gives the decay matrix."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, *, chunk: int, initial_state=None):
    """Chunked SSD.

    x : (B, T, H, P)   inputs (pre-multiplied by nothing; dt applied here)
    dt: (B, T, H)      positive step sizes
    a : (H,)           negative per-head decay rates
    b : (B, T, N)      input projection (shared across heads)
    c : (B, T, N)      output projection (shared across heads)

    Returns (y, final_state) with y (B, T, H, P), state (B, H, P, N).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, f"T={t} must be divisible by chunk={chunk}"
    nc = t // chunk

    # dt-discretize: per-step log decay and effective input weight.
    la = dt * a[None, None, :]                       # (B,T,H) log decay  (<0)
    xw = x * dt[..., None].astype(x.dtype)           # dt * x

    def ck(v):  # (B, T, ...) -> (B, nc, chunk, ...)
        return v.reshape((bsz, nc, chunk) + v.shape[2:])

    xc, lac, bc, cc = ck(xw), ck(la), ck(b), ck(c)
    lac = jnp.moveaxis(lac, -1, 2)                   # (B, nc, H, L)
    cs = jnp.cumsum(lac, axis=-1)                    # inclusive cumsum

    # 1. Intra-chunk (diagonal blocks): y_i += C_i·B_j exp(cs_i-cs_j) x_j
    decay = jnp.exp(_segsum(lac))                    # (B, nc, H, L, L)
    cb = jnp.einsum("bcin,bcjn->bcij", cc.astype(jnp.float32),
                    bc.astype(jnp.float32))          # (B, nc, L, L)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", cb, decay,
                        xc.astype(jnp.float32))

    # 2. Per-chunk end states: S_c = sum_j exp(cs_L - cs_j) B_j x_j^T
    decay_states = jnp.exp(cs[..., -1:] - cs)        # (B, nc, H, L)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", bc.astype(jnp.float32),
                        decay_states, xc.astype(jnp.float32))

    # 3. Inter-chunk recurrence over nc chunks.
    chunk_decay = jnp.exp(cs[..., -1])               # (B, nc, H)
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(s, inp):
        st, dec = inp                                # (B,H,P,N), (B,H)
        prev = s
        s = s * dec[..., None, None] + st
        return s, prev

    st_t = jnp.moveaxis(states, 1, 0)                # (nc, B, H, P, N)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)          # (nc, B, H)
    final, prev_states = jax.lax.scan(step, initial_state.astype(jnp.float32),
                                      (st_t, dec_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)    # (B, nc, H, P, N)

    # 4. Inter-chunk output: y_i += C_i · S_prev * exp(cs_i)
    out_decay = jnp.exp(cs)                          # (B, nc, H, L)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", cc.astype(jnp.float32),
                       prev_states, out_decay)

    y = (y_diag + y_off).reshape(bsz, t, h, p).astype(x.dtype)
    return y, final


def ssd_recurrent_step(state, x, dt, a, b, c):
    """One decode step. state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    b, c: (B,N). Returns (y, new_state)."""
    dec = jnp.exp(dt * a[None, :])                           # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None].astype(x.dtype))
                     .astype(jnp.float32), b.astype(jnp.float32))
    new = state * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, c.astype(jnp.float32))
    return y.astype(x.dtype), new


# ---------------------------------------------------------------------------
# Full layer
# ---------------------------------------------------------------------------
def _project(params, x, cfg: SSMConfig):
    di, n, h = cfg.d_inner, cfg.state, cfg.num_heads
    proj = layers.dot(x, params["in_proj"])
    z, xin, bb, cc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    del h
    return z, xin, bb, cc, dt


def ssm_layer(params, x, cfg: SSMConfig, *, use_kernel: bool = False):
    """Train/prefill. x: (B, T, d_model) -> (B, T, d_model)."""
    bsz, t, _ = x.shape
    h, p = cfg.num_heads, cfg.head_dim
    z, xin, bb, cc, dt = _project(params, x, cfg)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)
    conv_out = causal_conv1d(conv_in, params["conv_w"], params["conv_b"])
    xin, bb, cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + cfg.state],
                            axis=-1)
    xh = xin.reshape(bsz, t, h, p)
    a = -jnp.exp(params["a_log"])
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops
        y, _ = ssd_ops.ssd(xh, dt, a, bb, cc, chunk=cfg.chunk)
    else:
        y, _ = ssd_chunked(xh, dt, a, bb, cc, chunk=cfg.chunk)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, t, cfg.d_inner)
    y = layers.rmsnorm(params["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    return layers.dot(y, params["out_proj"])


def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=None):
    dtype = dtype or cfg.dtype
    conv_ch = cfg.d_inner + 2 * cfg.state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.state),
                           jnp.float32),
    }


def ssm_decode_step(params, x, cache, cfg: SSMConfig):
    """One-token decode. x: (B, 1, d_model). Returns (y, new_cache)."""
    bsz = x.shape[0]
    h, p = cfg.num_heads, cfg.head_dim
    z, xin, bb, cc, dt = _project(params, x, cfg)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)       # (B, 1, C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B, W, C)
    conv_out = (window.astype(jnp.float32)
                * params["conv_w"].astype(jnp.float32)[None]).sum(1) \
        + params["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)        # (B, C)
    xin1, bb1, cc1 = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + cfg.state],
                               axis=-1)
    a = -jnp.exp(params["a_log"])
    y, new_state = ssd_recurrent_step(
        cache["state"], xin1.reshape(bsz, h, p), dt[:, 0], a, bb1, cc1)
    y = y + xin1.reshape(bsz, h, p) * params["d_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = layers.rmsnorm(params["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    y = layers.dot(y, params["out_proj"])
    new_cache = {"conv": window[:, 1:], "state": new_state}
    return y, new_cache
