"""Core functional layers.

Conventions
-----------
* A layer is a pair of functions: ``<name>_init(key, ...) -> params`` and
  ``<name>(params, x, ...) -> y``. Params are plain dicts of jnp arrays.
* Alongside params, model code builds a parallel *logical-spec tree* (same
  structure, leaves are tuples of logical axis names or None) consumed by
  ``repro.distributed.mesh.logical_to_sharding``.
* Matmuls accumulate in fp32 (``preferred_element_type``) and cast back to
  the activation dtype — matches MXU behaviour on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import init as initializers


def dot(x: jax.Array, w: jax.Array) -> jax.Array:
    """Matmul with fp32 accumulation, output cast to x.dtype."""
    y = jax.lax.dot_general(
        x,
        w,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------
def linear_init(key, in_dim: int, out_dim: int, *, use_bias: bool = False,
                dtype=jnp.bfloat16, w_init=None):
    w_init = w_init or initializers.fan_in_normal(axis=0)
    params = {"w": w_init(key, (in_dim, out_dim), dtype)}
    if use_bias:
        params["b"] = jnp.zeros((out_dim,), dtype)
    return params


def linear(params, x):
    y = dot(x, params["w"])
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(dim: int, dtype=jnp.float32):
    # Norm scales stay fp32: they are tiny and precision-sensitive.
    return {"scale": jnp.zeros((dim,), dtype)}  # "zero-centered": scale = 1 + s


def rmsnorm(params, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"])).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def embedding_init(key, vocab: int, dim: int, dtype=jnp.bfloat16, stddev: float = 1.0):
    return {"table": initializers.normal(stddev)(key, (vocab, dim), dtype)}


def embedding_lookup(params, ids, *, scale_by_sqrt_dim: bool = False):
    table = params["table"]
    y = jnp.take(table, ids, axis=0)
    if scale_by_sqrt_dim:
        y = y * jnp.sqrt(jnp.asarray(table.shape[-1], jnp.float32)).astype(y.dtype)
    return y


def embedding_logits(params, x):
    """Tied unembedding: x @ table.T with fp32 accumulation, fp32 output."""
    table = params["table"]
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------
def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "swiglu"  # swiglu | gelu
    use_bias: bool = False
    dtype: object = jnp.bfloat16


def mlp_init(key, cfg: MLPConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "gate": linear_init(k1, cfg.d_model, cfg.d_ff, use_bias=cfg.use_bias, dtype=cfg.dtype),
            "up": linear_init(k2, cfg.d_model, cfg.d_ff, use_bias=cfg.use_bias, dtype=cfg.dtype),
            "down": linear_init(k3, cfg.d_ff, cfg.d_model, use_bias=cfg.use_bias, dtype=cfg.dtype,
                                 w_init=initializers.fan_in_normal(axis=0)),
        }
    return {
        "up": linear_init(k1, cfg.d_model, cfg.d_ff, use_bias=cfg.use_bias, dtype=cfg.dtype),
        "down": linear_init(k2, cfg.d_ff, cfg.d_model, use_bias=cfg.use_bias, dtype=cfg.dtype),
    }


def mlp(params, x, *, activation: str = "swiglu"):
    if activation == "swiglu":
        h = swiglu(linear(params["gate"], x), linear(params["up"], x))
    else:
        h = gelu(linear(params["up"], x))
    return linear(params["down"], h)


def mlp_logical_specs(cfg: MLPConfig):
    """Logical axes for mlp params (parallel tree)."""
    two = {"w": ("embed", "mlp")}
    down = {"w": ("mlp", "embed")}
    if cfg.use_bias:
        two = {"w": ("embed", "mlp"), "b": ("mlp",)}
        down = {"w": ("mlp", "embed"), "b": ("embed",)}
    if cfg.activation == "swiglu":
        return {"gate": dict(two), "up": dict(two), "down": dict(down)}
    return {"up": dict(two), "down": dict(down)}
