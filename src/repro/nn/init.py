"""Parameter initializers.

Every initializer takes (key, shape, dtype) and returns an array. We keep
initialization deterministic given a seed so elastic restarts / resharding
tests can re-derive identical params.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def normal(stddev: float = 1.0):
    def f(key, shape, dtype=jnp.float32):
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return f


def truncated_normal(stddev: float = 1.0):
    def f(key, shape, dtype=jnp.float32):
        x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
        return (stddev * x).astype(dtype)

    return f


def fan_in_normal(axis: int = 0):
    """He-style init with stddev = 1/sqrt(fan_in) along ``axis``."""

    def f(key, shape, dtype=jnp.float32):
        fan_in = shape[axis]
        return truncated_normal(1.0 / math.sqrt(max(fan_in, 1)))(key, shape, dtype)

    return f


def orthogonal(scale: float = 1.0):
    def f(key, shape, dtype=jnp.float32):
        if len(shape) < 2:
            return normal(scale)(key, shape, dtype)
        rows, cols = shape[-2], shape[-1]
        n = max(rows, cols)
        flat = jax.random.normal(key, shape[:-2] + (n, n), jnp.float32)
        q, r = jnp.linalg.qr(flat)
        q = q * jnp.sign(jnp.diagonal(r, axis1=-2, axis2=-1))[..., None, :]
        return (scale * q[..., :rows, :cols]).astype(dtype)

    return f


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)
