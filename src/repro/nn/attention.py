"""Attention: GQA with RoPE, causal / sliding-window masks, logit softcap,
cross-attention, and a KV-cache decode path.

Shapes
------
* activations  x : (B, T, d_model)
* q            : (B, T, H, Dh)
* k, v         : (B, T, Hkv, Dh)   with H % Hkv == 0 (GQA)
* KV cache     : dict(k=(B, S, Hkv, Dh), v=(B, S, Hkv, Dh), index=())

All matmuls accumulate in fp32. The jnp reference path here is the XLA
implementation used by the dry-run/roofline; the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU fast path with the same
semantics (validated against :func:`attend` in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    rope_theta: float = 10_000.0
    use_qkv_bias: bool = False              # qwen-style
    sliding_window: Optional[int] = None    # gemma2 local layers
    attn_softcap: Optional[float] = None    # gemma2 logit soft-capping
    causal: bool = True                     # False for encoder self-attn
    dtype: object = jnp.bfloat16

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.num_heads


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(dh: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (dh//2,), fp32."""
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float) -> jax.Array:
    """x: (B, T, H, Dh); positions: (B, T) or (T,) int32."""
    dh = x.shape[-1]
    inv_freq = rope_frequencies(dh, theta)                      # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv_freq   # (B, T, Dh/2)
    sin = jnp.sin(ang)[:, :, None, :]                           # (B, T, 1, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def attention_init(key, cfg: AttentionConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    dh = cfg.dh
    return {
        "q": layers.linear_init(kq, cfg.d_model, cfg.num_heads * dh,
                                use_bias=cfg.use_qkv_bias, dtype=cfg.dtype),
        "k": layers.linear_init(kk, cfg.d_model, cfg.num_kv_heads * dh,
                                use_bias=cfg.use_qkv_bias, dtype=cfg.dtype),
        "v": layers.linear_init(kv, cfg.d_model, cfg.num_kv_heads * dh,
                                use_bias=cfg.use_qkv_bias, dtype=cfg.dtype),
        "o": layers.linear_init(ko, cfg.num_heads * dh, cfg.d_model,
                                use_bias=False, dtype=cfg.dtype),
    }


def attention_logical_specs(cfg: AttentionConfig):
    qspec = {"w": ("embed", "heads")}
    kvspec = {"w": ("embed", "kv_heads")}
    if cfg.use_qkv_bias:
        qspec = {"w": ("embed", "heads"), "b": ("heads",)}
        kvspec = {"w": ("embed", "kv_heads"), "b": ("kv_heads",)}
    return {"q": qspec, "k": dict(kvspec), "v": dict(kvspec),
            "o": {"w": ("heads", "embed")}}


# ---------------------------------------------------------------------------
# Core attend (the jnp oracle; flash kernel mirrors this)
# ---------------------------------------------------------------------------
def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    b, t, hkv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, hkv, groups, dh)) \
              .reshape(b, t, hkv * groups, dh)


def make_mask(q_len: int, kv_len: int, *, causal: bool,
              sliding_window: Optional[int], q_offset,
              kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Boolean mask (q_len, kv_len); True = attend.

    ``kv_positions`` overrides the default contiguous key positions — used
    by the ring-buffer decode cache, where slot order is rotated and slots
    holding stale/unwritten entries carry position -1.
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    if kv_positions is None:
        k_pos = jnp.arange(kv_len)[None, :]
        mask = jnp.ones((q_len, kv_len), bool)
    else:
        k_pos = kv_positions[None, :]
        mask = k_pos >= 0
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window is not None:
        mask &= k_pos > q_pos - sliding_window
    return mask


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True,
           sliding_window: Optional[int] = None,
           softcap: Optional[float] = None,
           q_offset=0,
           kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Scaled dot-product attention with GQA broadcast.

    q: (B, Tq, H, Dh); k, v: (B, Tk, Hkv, Dh). Returns (B, Tq, H, Dh).
    """
    b, tq, h, dh = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = make_mask(tq, k.shape[1], causal=causal,
                     sliding_window=sliding_window, q_offset=q_offset,
                     kv_positions=kv_positions)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attend_chunked(q, k, v, *, causal: bool = True,
                   sliding_window: Optional[int] = None,
                   softcap: Optional[float] = None,
                   block_k: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention in pure XLA: scans key blocks
    carrying (running max, normalizer, accumulator), so the (T×T) score
    matrix is never materialized — the jit/dry-run analogue of the Pallas
    flash kernel (same FLOPs, O(T·block_k) memory)."""
    b, tq, h, dh = q.shape
    hkv = k.shape[2]
    tk = k.shape[1]
    if tk % block_k != 0:
        return attend(q, k, v, causal=causal, sliding_window=sliding_window,
                      softcap=softcap)
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    nk = tk // block_k
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    kb = jnp.moveaxis(k.reshape(b, nk, block_k, h, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_k, h, dh), 1, 0)
    q_pos = jnp.arange(tq)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ki = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * block_k + jnp.arange(block_k)
        mask = jnp.ones((tq, block_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if sliding_window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    a0 = jnp.zeros((b, h, tq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full layers
# ---------------------------------------------------------------------------
def _split_heads(x, n, dh):
    return x.reshape(x.shape[0], x.shape[1], n, dh)


def self_attention(params, x, cfg: AttentionConfig, *, positions=None,
                   use_flash: bool = False):
    """Prefill / training self-attention. x: (B, T, d_model)."""
    b, t, _ = x.shape
    dh = cfg.dh
    q = _split_heads(layers.linear(params["q"], x), cfg.num_heads, dh)
    k = _split_heads(layers.linear(params["k"], x), cfg.num_kv_heads, dh)
    v = _split_heads(layers.linear(params["v"], x), cfg.num_kv_heads, dh)
    if positions is None:
        positions = jnp.arange(t)
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    if use_flash:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(
            q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window,
            softcap=cfg.attn_softcap)
    elif t >= 2048:
        # flash-equivalent XLA path: never materializes the (T, T) scores
        out = attend_chunked(q, k, v, causal=cfg.causal,
                             sliding_window=cfg.sliding_window,
                             softcap=cfg.attn_softcap)
    else:
        out = attend(q, k, v, causal=cfg.causal,
                     sliding_window=cfg.sliding_window,
                     softcap=cfg.attn_softcap)
    return layers.linear(params["o"], out.reshape(b, t, cfg.num_heads * dh))


def init_kv_cache(cfg: AttentionConfig, batch: int, max_len: int,
                  dtype=None):
    """Position-tracking KV cache.

    ``max_len`` may be smaller than the sequence length, in which case the
    cache is a ring buffer (sliding-window layers allocate only
    ``window`` slots). ``pos`` records the absolute position stored in each
    slot (-1 = empty); attention masks are derived from it, so the rotated
    slot order of the ring is immaterial (softmax is order-invariant).
    """
    dtype = dtype or cfg.dtype
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.dh), dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
    }


def decode_self_attention(params, x, cache, cache_index, cfg: AttentionConfig,
                          *, logits_constraint=None):
    """One-token decode. x: (B, 1, d_model); cache_index: scalar int32
    (absolute position of the new token). Returns (out, new_cache).
    RoPE is applied to K at write time, so cached keys are position-final.

    ``logits_constraint``: optional sharding constraint applied to the
    (B, H, 1, slots) attention logits. When the cache sequence axis is
    mesh-sharded, constraining the logits to the SAME sharding makes the
    partitioner run a distributed softmax (small all-reduces of the
    per-shard max/sum and the PV partials) instead of all-gathering the
    whole K/V cache per layer — the decode §Perf fix.
    """
    b = x.shape[0]
    dh = cfg.dh
    slots = cache["k"].shape[1]
    q = _split_heads(layers.linear(params["q"], x), cfg.num_heads, dh)
    k = _split_heads(layers.linear(params["k"], x), cfg.num_kv_heads, dh)
    v = _split_heads(layers.linear(params["v"], x), cfg.num_kv_heads, dh)
    pos = jnp.full((1,), cache_index, jnp.int32)
    q = apply_rope(q, pos, theta=cfg.rope_theta)
    k = apply_rope(k, pos, theta=cfg.rope_theta)
    slot = jax.lax.rem(cache_index, slots)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos, slot, axis=0)
    if logits_constraint is None:
        out = attend(q, new_k, new_v, causal=True,
                     sliding_window=cfg.sliding_window,
                     softcap=cfg.attn_softcap,
                     q_offset=cache_index,
                     kv_positions=new_pos)
    else:
        out = _attend_decode_sharded(
            q, new_k, new_v, cfg, cache_index, new_pos, logits_constraint)
    out = layers.linear(params["o"], out.reshape(b, 1, cfg.num_heads * dh))
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


def _attend_decode_sharded(q, k, v, cfg: AttentionConfig, cache_index,
                           kv_positions, logits_constraint):
    """attend() with an explicit distributed softmax over the (sharded)
    cache sequence axis: identical math, but the logits/probs tensors are
    sharding-constrained so reductions lower to small all-reduces."""
    b, tq, h, dh = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if cfg.attn_softcap is not None:
        logits = cfg.attn_softcap * jnp.tanh(logits / cfg.attn_softcap)
    mask = make_mask(tq, k.shape[1], causal=True,
                     sliding_window=cfg.sliding_window, q_offset=cache_index,
                     kv_positions=kv_positions)
    logits = jnp.where(mask[None, None], logits, -1e30)
    logits = logits_constraint(logits)
    m = jnp.max(logits, axis=-1, keepdims=True)              # all-reduce max
    p = logits_constraint(jnp.exp(logits - m))
    s = jnp.sum(p, axis=-1, keepdims=True)                   # all-reduce sum
    pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v,
                    preferred_element_type=jnp.float32)       # psum partials
    return (pv / jnp.moveaxis(s, 1, 2).astype(pv.dtype)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder, llama-vision image layers)
# ---------------------------------------------------------------------------
def cross_attention_init(key, cfg: AttentionConfig, kv_dim: Optional[int] = None):
    kq, kk, kv, ko = jax.random.split(key, 4)
    dh = cfg.dh
    kv_dim = kv_dim or cfg.d_model
    return {
        "q": layers.linear_init(kq, cfg.d_model, cfg.num_heads * dh, dtype=cfg.dtype),
        "k": layers.linear_init(kk, kv_dim, cfg.num_kv_heads * dh, dtype=cfg.dtype),
        "v": layers.linear_init(kv, kv_dim, cfg.num_kv_heads * dh, dtype=cfg.dtype),
        "o": layers.linear_init(ko, cfg.num_heads * dh, cfg.d_model, dtype=cfg.dtype),
    }


def cross_attention(params, x, kv_src, cfg: AttentionConfig):
    """x: (B, Tq, d_model); kv_src: (B, Tk, kv_dim). No RoPE, no mask."""
    b, tq, _ = x.shape
    dh = cfg.dh
    q = _split_heads(layers.linear(params["q"], x), cfg.num_heads, dh)
    k = _split_heads(layers.linear(params["k"], kv_src), cfg.num_kv_heads, dh)
    v = _split_heads(layers.linear(params["v"], kv_src), cfg.num_kv_heads, dh)
    out = attend(q, k, v, causal=False, softcap=cfg.attn_softcap)
    return layers.linear(params["o"], out.reshape(b, tq, cfg.num_heads * dh))
