"""Program registry for the contract checker.

This module knows how to build every traced program in the repo as a
:class:`repro.analysis.contracts.Program` — abstractly, at tiny sizes
(jaxprs via ``make_jaxpr``/``eval_shape``, no FLOPs) — so
``tools/check_programs.py`` can run the full rule set over **both
drivers × every registered scenario**:

* the **sharded driver**'s fused round + split shard-train program
  (donation, sync budget, callback rules), their extracted per-shard
  train bodies (collective-free) and GS bodies (halo-only), and the
  collect program;
* the **loop driver**'s jitted pieces (collect, AIP train, IALS inner
  step, GS eval) — no mesh, so no collective rules fire, but callback
  and structural rules run identically (the driver-parity contract);
* the **kernel dispatch paths** (GRU/GAE ops, oracle and Pallas) as
  dtype round-trip programs;
* the **wide-stream collect path** — the donating ring-slot collect and
  the fused round re-audited at S=64 streams, where donation aliasing
  and the sync budget can silently regress as shapes grow.

New traced programs MUST register here (see ROADMAP): either extend
:func:`scenario_programs` or append a builder via
:func:`register_programs` — the CI ``analysis`` job checks whatever
this module yields.
"""
from __future__ import annotations

import functools
from typing import Callable, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.analysis.contracts import Program

__all__ = ["tiny_trainer", "loop_programs", "sharded_programs",
           "kernel_dtype_programs", "recovery_programs",
           "stream_programs", "scenario_programs", "all_programs",
           "register_programs", "DRIVERS"]

DRIVERS = ("loop", "sharded")

# extension point: fns () -> List[Program], run by all_programs()
_EXTRA_BUILDERS: List[Callable[[], List[Program]]] = []


def register_programs(builder: Callable[[], List[Program]]) -> None:
    """Register additional programs with the checker (future traced
    programs must call this — the CI analysis job audits the union)."""
    _EXTRA_BUILDERS.append(builder)


def tiny_trainer(env: str, *, kind: str = "fnn", **kw):
    """A ``DIALSTrainer`` at trace-only sizes (mirrors the test suite's
    tiny config) — never ``run()`` here; the checker only traces."""
    from repro.core import dials, influence
    from repro.envs import registry
    from repro.marl import policy as policy_mod, ppo as ppo_mod

    env_mod, cfg = registry.make(env, horizon=16)
    info = cfg.info()
    pc = policy_mod.PolicyConfig(obs_dim=info.obs_dim,
                                 n_actions=info.n_actions, kind=kind,
                                 hidden=(16,), gru_hidden=8)
    ac = influence.AIPConfig(in_dim=info.alsh_dim,
                             n_sources=info.n_influence, kind=kind,
                             hidden=(16,), gru_hidden=8, epochs=2,
                             batch=16)
    ppo_cfg = ppo_mod.PPOConfig(epochs=1, minibatches=2)
    dcfg = dials.DIALSConfig(**{
        **dict(outer_rounds=2, aip_refresh=2, collect_envs=2,
               collect_steps=16, n_envs=2, rollout_steps=8,
               eval_episodes=2), **kw})
    return dials.DIALSTrainer(env_mod, cfg, pc, ac, ppo_cfg, dcfg)


def _key_aval():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


# ---------------------------------------------------------------------------
# loop driver
# ---------------------------------------------------------------------------
def loop_programs(env: str, *, kind: str = "fnn") -> List[Program]:
    """The loop driver's jitted pieces, traced abstractly."""
    from repro.core import gs as gs_mod
    from repro.core import influence

    trainer = tiny_trainer(env, kind=kind, shards=1)
    info, cfg = trainer.info, trainer.cfg
    key = _key_aval()
    state = jax.eval_shape(trainer.ials_init, key)
    params = state["params"]
    aips = jax.eval_shape(
        lambda k: jax.vmap(
            lambda kk: influence.aip_init(kk, trainer.aip_cfg))(
            jax.random.split(k, info.n_agents)), key)
    data = jax.eval_shape(trainer.collect, params, key)
    train_data = jax.eval_shape(
        lambda d: gs_mod.split_dataset(d, trainer.n_eval_seqs)[0], data)
    agent_keys = jax.ShapeDtypeStruct((info.n_agents, 2), jnp.uint32)
    gs_eval = functools.partial(trainer.gs_eval,
                                episodes=cfg.eval_episodes)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    mask = jax.ShapeDtypeStruct((info.n_agents,), jnp.float32)
    reports = jax.ShapeDtypeStruct((info.n_agents,), jnp.int32)
    aip_round_args = (aips, data, agent_keys, mask, reports,
                      scalar, scalar)
    n_data_leaves = len(jax.tree.leaves(data))
    pre = f"loop/{env}"
    return [
        Program(name=f"{pre}/collect", roles=("collect", "program"),
                jaxpr=jax.make_jaxpr(trainer.collect)(params, key),
                fn=trainer.collect, args=(params, key)),
        # the donating ring-slot variant of the same pool rollout: the
        # RingBufferResident + DonationUsed pair pins the no-host-round-
        # trip / no-realloc claim the DeviceRing makes
        Program(name=f"{pre}/ring_collect",
                roles=("ring_collect", "donated", "program"),
                jaxpr=jax.make_jaxpr(trainer.collect_into)(
                    data, params, key),
                fn=trainer.collect_into, args=(data, params, key),
                donate_argnums=(0,),
                meta={"expect_aliased": n_data_leaves}),
        Program(name=f"{pre}/train_aips", roles=("program",),
                jaxpr=jax.make_jaxpr(trainer.train_aips)(
                    aips, train_data, agent_keys),
                fn=trainer.train_aips, args=(aips, train_data,
                                             agent_keys)),
        # the fused AIP round (holdout split + eval + train + freshness
        # gate as ONE program over the ring-resident dataset)
        Program(name=f"{pre}/aip_round", roles=("program",),
                jaxpr=jax.make_jaxpr(trainer.aip_round)(*aip_round_args),
                fn=trainer.aip_round, args=aip_round_args),
        Program(name=f"{pre}/ials_train", roles=("program",),
                jaxpr=jax.make_jaxpr(trainer.ials_train)(state, aips),
                fn=trainer.ials_train, args=(state, aips)),
        Program(name=f"{pre}/gs_eval", roles=("program",),
                jaxpr=jax.make_jaxpr(gs_eval)(params, key),
                fn=gs_eval, args=(params, key)),
    ]


# ---------------------------------------------------------------------------
# sharded driver
# ---------------------------------------------------------------------------
def sharded_programs(env: str, *, kind: str = "fnn",
                     n_shards: Optional[int] = None) -> List[Program]:
    """The sharded driver's fused/split round programs plus their
    extracted train and GS bodies. Needs >1 visible device to build a
    multi-shard mesh; a 1-device process still audits a 1-shard mesh."""
    from repro.core import dials_sharded
    from repro.distributed import runtime

    trainer = tiny_trainer(env, kind=kind)
    info = trainer.info
    if n_shards is None:
        n_shards = runtime.choose_shards(info.n_agents,
                                         len(jax.devices()))
    runner = dials_sharded.ShardedDIALSRunner(
        trainer.env_mod, trainer.env_cfg, trainer.policy_cfg,
        trainer.aip_cfg, trainer.ppo_cfg, trainer.cfg,
        n_shards=n_shards)

    key = _key_aval()
    carry = runner._abstract_carry()
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    mask = jax.ShapeDtypeStruct((info.n_agents,), jnp.float32)
    round_args = (carry, key, scalar, mask)
    data = jax.eval_shape(runner.collect, carry["ials"]["params"], key)
    train_args = (carry, data, key, scalar, scalar, mask)
    n_carry_leaves = len(jax.tree.leaves(carry))

    round_jx = runner.round_jaxpr()
    train_jx = runner.train_round_jaxpr()
    pre = f"sharded/{env}@{runner.n_shards}"
    programs = [
        Program(name=f"{pre}/round", roles=("round", "donated"),
                jaxpr=round_jx, fn=runner.round, args=round_args,
                donate_argnums=(0,),
                meta={"expect_aliased": n_carry_leaves}),
        Program(name=f"{pre}/train_round",
                roles=("train_round", "donated"),
                jaxpr=train_jx, fn=runner.train_round, args=train_args,
                donate_argnums=(0,),
                meta={"expect_aliased": n_carry_leaves}),
        Program(name=f"{pre}/collect", roles=("collect", "program"),
                jaxpr=jax.make_jaxpr(runner.collect)(
                    carry["ials"]["params"], key),
                fn=runner.collect,
                args=(carry["ials"]["params"], key)),
    ]
    for what, jx in (("round", round_jx), ("train_round", train_jx)):
        train_body, gs_bodies = runner._classify_bodies(
            jx, "round" if what == "round" else "shard-train program")
        programs.append(Program(
            name=f"{pre}/{what}/train_body", roles=("train_body",),
            jaxpr=train_body))
        programs.extend(Program(
            name=f"{pre}/{what}/gs_body[{i}]", roles=("gs_body",),
            jaxpr=body) for i, body in enumerate(gs_bodies))
    return programs


# ---------------------------------------------------------------------------
# recovery / resume path (post-loss re-bootstrap)
# ---------------------------------------------------------------------------
def recovery_programs(env: str = "traffic", *,
                      kind: str = "fnn") -> List[Program]:
    """The post-loss resume path's traced programs.

    After a host death the survivors re-exec, re-bootstrap as a shrunken
    group, and resume from the committed distributed checkpoint — so the
    programs that actually run are (a) the fused round retraced on the
    *shrunken* mesh and (b) the two jit-identity re-shard transfers the
    restore/mirror path performs: checkpoint rows (host/replicated) →
    agent-sharded placement, and agent-sharded state → replicated fetch
    (the checkpoint snapshot + metrics path). The round re-audits under
    the full rule set; the ``("reshard",)`` programs feed the
    ``ReshardCollectives`` rule, which pins the restore path to
    data-movement collectives only (all-gather / collective-permute) —
    a surprise all-reduce here would mean the resume path silently
    recomputes instead of moving rows."""
    from repro.core import dials_sharded
    from repro.distributed import runtime

    trainer = tiny_trainer(env, kind=kind)
    info = trainer.info
    n_dev = len(jax.devices())
    # the shrunken group: half the devices vanished with the dead host
    n_shards = runtime.choose_shards(info.n_agents, max(1, n_dev // 2))
    runner = dials_sharded.ShardedDIALSRunner(
        trainer.env_mod, trainer.env_cfg, trainer.policy_cfg,
        trainer.aip_cfg, trainer.ppo_cfg, trainer.cfg,
        n_shards=n_shards)

    key = _key_aval()
    carry = runner._abstract_carry()
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    mask = jax.ShapeDtypeStruct((info.n_agents,), jnp.float32)
    n_carry_leaves = len(jax.tree.leaves(carry))
    round_jx = runner.round_jaxpr()
    pre = f"recovery/{env}@{runner.n_shards}"
    programs = [
        Program(name=f"{pre}/resume_round", roles=("round", "donated"),
                jaxpr=round_jx, fn=runner.round,
                args=(carry, key, scalar, mask), donate_argnums=(0,),
                meta={"expect_aliased": n_carry_leaves}),
    ]
    # the resume round IS a fused round program — classify it as one
    # (the "round" key sets the expected GS-body count: collect + eval)
    train_body, gs_bodies = runner._classify_bodies(round_jx, "round")
    programs.append(Program(
        name=f"{pre}/resume_round/train_body", roles=("train_body",),
        jaxpr=train_body))
    programs.extend(Program(
        name=f"{pre}/resume_round/gs_body[{i}]", roles=("gs_body",),
        jaxpr=body) for i, body in enumerate(gs_bodies))

    # the re-shard transfers: jit identities whose in/out shardings force
    # XLA to emit exactly the data movement the restore path performs
    sharded = jax.tree.map(
        lambda _: runtime.agent_sharding(runner.mesh), carry)
    replicated = jax.tree.map(
        lambda _: runtime.replicated_sharding(runner.mesh), carry)
    place = jax.jit(lambda t: t, in_shardings=(replicated,),
                    out_shardings=sharded)
    fetch = jax.jit(lambda t: t, in_shardings=(sharded,),
                    out_shardings=replicated)
    programs.extend([
        Program(name=f"{pre}/reshard_place", roles=("reshard",),
                fn=place, args=(carry,),
                meta={"mesh_devices": runner.mesh.devices.size}),
        Program(name=f"{pre}/reshard_fetch", roles=("reshard",),
                fn=fetch, args=(carry,),
                meta={"mesh_devices": runner.mesh.devices.size}),
    ])
    return programs


# ---------------------------------------------------------------------------
# wide-stream (S-swept) collect path
# ---------------------------------------------------------------------------
def stream_programs(env: str = "traffic", *, streams: int = 64,
                    kind: str = "fnn") -> List[Program]:
    """The large-batch collect path at a wide stream count S.

    The S knobs (``DIALSConfig.collect_streams``) only change a vmapped
    batch axis, so the contracts that hold at S=2 must hold at S=64 —
    but donation aliasing, the ring's struct round-trip, and the fused
    round's sync budget are exactly the properties that CAN silently
    regress when a shape grows (XLA drops an alias, a reduction widens
    an output). This re-audits the loop ring collect and the sharded
    fused round with the stream axis actually wide."""
    from repro.core import dials_sharded

    trainer = tiny_trainer(env, kind=kind, collect_streams=streams)
    info = trainer.info
    key = _key_aval()
    params = jax.eval_shape(trainer.ials_init, key)["params"]
    data = jax.eval_shape(trainer.collect, params, key)
    pre = f"streams/{env}@S{streams}"
    programs = [
        Program(name=f"{pre}/ring_collect",
                roles=("ring_collect", "donated", "program"),
                jaxpr=jax.make_jaxpr(trainer.collect_into)(
                    data, params, key),
                fn=trainer.collect_into, args=(data, params, key),
                donate_argnums=(0,),
                meta={"expect_aliased": len(jax.tree.leaves(data))}),
    ]
    runner = dials_sharded.ShardedDIALSRunner(
        trainer.env_mod, trainer.env_cfg, trainer.policy_cfg,
        trainer.aip_cfg, trainer.ppo_cfg, trainer.cfg, n_shards=1)
    carry = runner._abstract_carry()
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    mask = jax.ShapeDtypeStruct((info.n_agents,), jnp.float32)
    round_jx = runner.round_jaxpr()
    programs.append(Program(
        name=f"{pre}/round", roles=("round", "donated"),
        jaxpr=round_jx, fn=runner.round,
        args=(carry, key, scalar, mask), donate_argnums=(0,),
        meta={"expect_aliased": len(jax.tree.leaves(carry))}))
    train_body, gs_bodies = runner._classify_bodies(round_jx, "round")
    programs.append(Program(
        name=f"{pre}/round/train_body", roles=("train_body",),
        jaxpr=train_body))
    programs.extend(Program(
        name=f"{pre}/round/gs_body[{i}]", roles=("gs_body",),
        jaxpr=body) for i, body in enumerate(gs_bodies))
    return programs


# ---------------------------------------------------------------------------
# kernel dispatch dtype contracts
# ---------------------------------------------------------------------------
def kernel_dtype_programs(dtype=jnp.bfloat16) -> List[Program]:
    """The GRU/GAE hot-spot ops, oracle and kernel path, as dtype
    round-trip programs: reduced-precision in ⇒ reduced-precision out
    (internals may accumulate f32; outputs must cast back)."""
    from repro.kernels.gae import ops as gae_ops
    from repro.kernels.gru import ops as gru_ops
    from repro.marl import gae as gae_oracle
    from repro.nn import gru as gru_oracle

    b, t, d_in, h = 2, 8, 4, 8
    seq = jax.ShapeDtypeStruct((b, t), dtype)
    last = jax.ShapeDtypeStruct((b,), dtype)
    gae_args = (seq, seq, seq, last)
    xs = jax.ShapeDtypeStruct((b, t, d_in), dtype)
    gru_params = {
        "wi": jax.ShapeDtypeStruct((d_in, 3 * h), dtype),
        "wh": jax.ShapeDtypeStruct((h, 3 * h), dtype),
        "bi": jax.ShapeDtypeStruct((3 * h,), dtype),
        "bh": jax.ShapeDtypeStruct((3 * h,), dtype),
    }
    kernel_gae = functools.partial(gae_ops.gae, interpret=True)
    kernel_gru = functools.partial(gru_ops.gru_sequence, interpret=True)
    return [
        Program(name="kernels/gae/oracle", roles=("dtype",),
                fn=gae_oracle.gae, args=gae_args),
        Program(name="kernels/gae/pallas", roles=("dtype",),
                fn=kernel_gae, args=gae_args),
        Program(name="kernels/gru/oracle", roles=("dtype",),
                fn=gru_oracle.gru_sequence, args=(gru_params, xs)),
        Program(name="kernels/gru/pallas", roles=("dtype",),
                fn=kernel_gru, args=(gru_params, xs)),
    ]


# ---------------------------------------------------------------------------
# the full catalogue
# ---------------------------------------------------------------------------
def scenario_programs(env: str, drivers: Iterable[str] = DRIVERS,
                      *, kind: str = "fnn") -> List[Program]:
    out: List[Program] = []
    if "loop" in drivers:
        out.extend(loop_programs(env, kind=kind))
    if "sharded" in drivers:
        out.extend(sharded_programs(env, kind=kind))
    return out


def all_programs(scenarios: Optional[Iterable[str]] = None,
                 drivers: Iterable[str] = DRIVERS,
                 *, kernels: bool = True,
                 recovery: bool = True,
                 streams: bool = True) -> List[Program]:
    """Every registered program: both drivers × every scenario, the
    kernel dtype contracts, the post-loss resume-path programs, the
    wide-stream collect re-audit, and anything added via
    :func:`register_programs`."""
    from repro.envs import registry

    if scenarios is None:
        scenarios = registry.names()
    scenarios = list(scenarios)
    out: List[Program] = []
    for env in scenarios:
        out.extend(scenario_programs(env, drivers))
    if kernels:
        out.extend(kernel_dtype_programs())
    if recovery and scenarios and "sharded" in drivers:
        out.extend(recovery_programs(scenarios[0]))
    if streams and scenarios:
        out.extend(stream_programs(scenarios[0]))
    for builder in _EXTRA_BUILDERS:
        out.extend(builder())
    return out
