"""Shared finding/violation formatting for every repo gate.

``tools/check_programs.py`` (contract + lint violations),
``tools/telemetry_report.py --check`` (schema problems) and
``benchmarks/check_bench.py`` (regression problems) all print failures
through :func:`format_finding` so the output shape is identical across
gates: a stable uppercase tag, ``file:line`` provenance when known, and
— under ``GITHUB_ACTIONS`` — a ``::error`` workflow command so CI
renders each violation as an annotation on the offending line.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

__all__ = ["Finding", "format_finding", "emit"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One reportable problem from any gate."""
    tag: str                       # e.g. CONTRACT-VIOLATION, LINT, REGRESSION
    message: str
    file: Optional[str] = None
    line: Optional[int] = None
    rule: Optional[str] = None     # rule / check name, shown as a title

    @property
    def location(self) -> str:
        if not self.file:
            return ""
        return f"{self.file}:{self.line}" if self.line else self.file


def format_finding(f: Finding, *, github: Optional[bool] = None) -> str:
    """Render one finding.

    Plain mode::

        CONTRACT-VIOLATION src/x.py:42 [CollectiveFree] psum in train body

    GitHub mode (``github=True``, or auto-detected from the
    ``GITHUB_ACTIONS`` env var) emits a workflow command that the Actions
    runner turns into a file:line annotation::

        ::error file=src/x.py,line=42,title=CollectiveFree::psum in ...
    """
    if github is None:
        github = os.environ.get("GITHUB_ACTIONS") == "true"
    if github:
        props = []
        if f.file:
            props.append(f"file={f.file}")
        if f.line:
            props.append(f"line={f.line}")
        props.append(f"title={f.rule or f.tag}")
        # workflow commands terminate the message at a newline
        msg = f.message.replace("\n", " ")
        return f"::error {','.join(props)}::[{f.tag}] {msg}"
    parts = [f.tag]
    loc = f.location
    if loc:
        parts.append(loc)
    if f.rule:
        parts.append(f"[{f.rule}]")
    parts.append(f.message)
    return " ".join(parts)


def emit(findings, *, github: Optional[bool] = None) -> int:
    """Print every finding; return the count (0 = clean)."""
    n = 0
    for f in findings:
        print(format_finding(f, github=github))
        n += 1
    return n
