"""Path-aware jaxpr traversal with source provenance.

``repro.distributed.runtime.jaxpr_primitives`` flattens a whole traced
program to a *set of primitive names* — enough to say "a psum exists",
useless for saying *where*. This walker replaces that flattening with a
structured traversal: every primitive occurrence becomes a
:class:`PrimSite` carrying

* the **structural path** from the program root — which ``pjit`` /
  ``shard_map`` / ``scan`` / ``cond`` / ``while`` / ``custom_vjp`` /
  ``pallas_call`` bodies enclose it (e.g.
  ``pjit:train_fn / shard_map / scan``);
* the **named-scope labels** active at trace time
  (``jax.named_scope`` — the ``shard_train`` / ``gs_collect`` /
  ``halo_exchange`` annotations ``repro.obs.trace.annotate`` stamps);
* the **source location** (file, line, function) of the user code that
  emitted the primitive, via the eqn's ``source_info``.

Contract violations reported off these records name the offending
primitive AND the line of repro code that traced it — see
``repro.analysis.contracts``.

Sub-jaxpr discovery is belt-and-braces: an explicit table for the
primitives whose body parameters we know (including ``pallas_call``,
whose kernel body is a *raw* ``Jaxpr`` parameter — exactly the shape a
ClosedJaxpr-only param scan misses), plus a generic scan over every
equation parameter for stray (Closed)Jaxpr values so a new jax
primitive cannot silently hide a body from the audit.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Set, Tuple

import jax
import jax.extend

__all__ = [
    "PrimSite", "walk", "primitives", "sites", "fingerprint",
    "raw_jaxpr", "sub_jaxprs",
]


def raw_jaxpr(jaxpr):
    """The underlying ``Jaxpr`` of a (Closed)Jaxpr."""
    if isinstance(jaxpr, jax.extend.core.ClosedJaxpr):
        return jaxpr.jaxpr
    return jaxpr


# primitives whose params are known to carry sub-jaxprs; the walker
# labels these bodies by primitive name. Everything else goes through
# the generic param scan below.
_KNOWN_BODY_PARAMS = {
    "scan": ("jaxpr",),
    "while": ("cond_jaxpr", "body_jaxpr"),
    "cond": ("branches",),
    "pjit": ("jaxpr",),
    "shard_map": ("jaxpr",),
    "pallas_call": ("jaxpr",),
    "custom_jvp_call": ("call_jaxpr", "jvp_jaxpr_fun"),
    "custom_vjp_call": ("call_jaxpr", "fun_jaxpr"),
    "custom_vjp_call_jaxpr": ("fun_jaxpr",),
    "checkpoint": ("jaxpr",),
    "remat2": ("jaxpr",),
}


def sub_jaxprs(eqn) -> Iterator:
    """Every sub-jaxpr an equation carries, as ``(label, jaxpr)``.

    ``pallas_call`` is listed in the known-body table explicitly: its
    kernel body is a raw ``Jaxpr`` param (not a ClosedJaxpr), which is
    how name-set flatteners historically missed Pallas kernel interiors.
    The generic fallback scans all remaining params for (Closed)Jaxpr
    values — list- or tuple-nested included — so nothing is silently
    skipped when jax grows new body-carrying primitives.
    """
    jaxpr_types = (jax.extend.core.ClosedJaxpr, jax.extend.core.Jaxpr)
    known = _KNOWN_BODY_PARAMS.get(eqn.primitive.name, ())
    emitted = set()

    def emit(name, val, index=None):
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for i, v in enumerate(vals):
            if isinstance(v, jaxpr_types) and id(v) not in emitted:
                emitted.add(id(v))
                label = name if len(vals) == 1 else f"{name}[{i}]"
                yield label, raw_jaxpr(v)

    for name in known:
        if name in eqn.params:
            yield from emit(name, eqn.params[name])
    for name, val in eqn.params.items():
        if name in known:
            continue
        yield from emit(name, val)


@dataclasses.dataclass(frozen=True)
class PrimSite:
    """One primitive occurrence inside a traced program."""
    prim: str
    path: Tuple[str, ...]          # enclosing bodies, outermost first
    scopes: Tuple[str, ...]        # jax.named_scope labels, outermost first
    file: Optional[str] = None     # user source that emitted the primitive
    line: Optional[int] = None
    fn: Optional[str] = None

    @property
    def location(self) -> str:
        """``file:line (fn)`` — empty string when provenance is absent
        (e.g. a synthetic jaxpr)."""
        if self.file is None:
            return ""
        loc = f"{self.file}:{self.line}"
        return f"{loc} ({self.fn})" if self.fn else loc

    def describe(self) -> str:
        """Human-oriented one-liner: primitive, path, scopes, source."""
        parts = [self.prim]
        if self.path:
            parts.append("in " + "/".join(self.path))
        if self.scopes:
            parts.append("under scope " + "/".join(self.scopes))
        loc = self.location
        if loc:
            parts.append(f"at {loc}")
        return " ".join(parts)


def _provenance(source_info):
    """(file, line, fn, scopes) off an eqn's source_info; every field
    degrades to None/() on jax builds whose internals moved."""
    scopes: Tuple[str, ...] = ()
    try:
        stack = str(source_info.name_stack)
        if stack:
            scopes = tuple(s for s in stack.split("/") if s)
    except Exception:
        pass
    try:
        from jax._src import source_info_util as siu
        frame = siu.user_frame(source_info)
        if frame is not None:
            return frame.file_name, frame.start_line, \
                frame.function_name, scopes
    except Exception:
        pass
    return None, None, None, scopes


def _path_component(eqn) -> str:
    """Display name of one enclosing body: the primitive, plus the
    program name where the primitive carries one (``pjit:round_fn``)."""
    name = eqn.params.get("name")
    if not isinstance(name, str):
        info = eqn.params.get("name_and_src_info")     # pallas_call
        name = getattr(info, "name", None)
    if isinstance(name, str) and name:
        return f"{eqn.primitive.name}:{name}"
    return eqn.primitive.name


def walk(jaxpr, *, path: Tuple[str, ...] = ()) -> Iterator[PrimSite]:
    """Yield a :class:`PrimSite` for every primitive in ``jaxpr``,
    recursing into every sub-jaxpr (scan/while/cond/pjit/shard_map/
    custom_vjp/pallas_call bodies included)."""
    jaxpr = raw_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        file, line, fn, scopes = _provenance(eqn.source_info)
        yield PrimSite(eqn.primitive.name, path, scopes, file, line, fn)
        component = _path_component(eqn)
        subs = list(sub_jaxprs(eqn))
        for label, sub in subs:
            comp = component if len(subs) == 1 else f"{component}:{label}"
            yield from walk(sub, path=path + (comp,))


def primitives(jaxpr) -> Set[str]:
    """Name-set flattening, as a walker view (the compatibility surface
    ``repro.distributed.runtime.jaxpr_primitives`` keeps serving)."""
    return {site.prim for site in walk(jaxpr)}


def sites(jaxpr, prims: Optional[Sequence[str]] = None) -> list:
    """All :class:`PrimSite` records, optionally filtered to a
    primitive-name set — the usual rule-engine entry point."""
    if prims is None:
        return list(walk(jaxpr))
    wanted = set(prims)
    return [s for s in walk(jaxpr) if s.prim in wanted]


def fingerprint(jaxpr) -> Tuple:
    """Order-insensitive structural fingerprint: the sorted multiset of
    ``(primitive, path)`` pairs. Two programs with equal fingerprints
    execute the same primitives in the same body structure — the
    invariant the telemetry-cannot-change-the-program rule pins, without
    the brittleness of string-equality on jaxpr pretty-printing."""
    counts: dict = {}
    for site in walk(jaxpr):
        key = (site.prim, site.path)
        counts[key] = counts.get(key, 0) + 1
    return tuple(sorted((p, path, n) for (p, path), n in counts.items()))
