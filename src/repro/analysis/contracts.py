"""Declarative contract rules over traced programs.

The runtime's correctness story is a set of *program contracts* — claims
about what a traced jaxpr may and may not contain, which the paper's
runtime-stays-constant and exactness arguments rest on. Each contract is
a :class:`ContractRule` checked against :class:`Program` records;
violations come back as ``repro.analysis.report.Finding``s whose
file:line points at the repro source that emitted the offending
primitive (via the walker's ``PrimSite`` provenance), not at the
checker.

Rule catalogue (see README "Static program contracts"):

``CollectiveFree``    a train body exchanges nothing between AIP
                      refreshes — no collective primitive anywhere in
                      it, nested sub-jaxprs included.
``HaloOnly``          a region-decomposed GS body talks to its ring
                      neighbours only (``runtime.HALO_PRIMS``) and must
                      contain at least one halo exchange — anything
                      else means the "decomposed" rollout
                      re-centralized.
``NoHostCallback``    a fused round program contains no host-callback
                      primitive — a hidden per-step device↔host sync
                      would silently break the one-sync-per-round
                      claim.
``DonationUsed``      every buffer a program declares donated is
                      actually aliased into an output at lower time; an
                      unusable donation is a full silent copy of the
                      carry every round.
``DtypeRoundTrip``    with reduced-precision (bf16) inputs the program
                      returns reduced-precision outputs — kernels may
                      accumulate in f32 internally but must cast back
                      (the class of silent-upcast bug the kernel
                      dispatch paths have grown before).
``ScalarSyncBudget``  the fused round's non-carry outputs are host
                      scalars drawn from the typed round-record schema
                      (``repro.obs.metrics.ROUND_KEYS``) — the
                      once-per-round sync contract as a rule, replacing
                      jaxpr string-equality tests.
``ReshardCollectives`` the restore/re-shard transfers (post-loss
                      resume) compile to data movement only —
                      all-gather / collective-permute — never a
                      combining collective; checked on compiled HLO
                      text, where sharding-induced collectives live.
``RingBufferResident`` the donating ring-buffer collect keeps the wide
                      dataset device-resident: no host-callback
                      primitive anywhere in it, and the fresh dataset
                      has exactly the retired slot's tree structure /
                      shapes / dtypes, so every slot leaf can alias an
                      output and nothing round-trips through the host
                      between collect and training.

Programs carry ``roles`` tags; each rule declares which roles it
applies to, and :func:`run_rules` does the cross product. Adding a
contract = subclassing :class:`ContractRule` and appending to
``DEFAULT_RULES``.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis import walker
from repro.analysis.report import Finding

__all__ = [
    "Program", "ContractRule", "run_rules", "DEFAULT_RULES",
    "CollectiveFree", "HaloOnly", "NoHostCallback", "DonationUsed",
    "DtypeRoundTrip", "ScalarSyncBudget", "ReshardCollectives",
    "RingBufferResident",
]

TAG = "CONTRACT-VIOLATION"

# host-callback primitives — any of these inside a fused round program
# is a hidden device<->host transfer the sync budget does not see
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call",
})


@dataclasses.dataclass
class Program:
    """One traced program under audit.

    ``roles`` routes rules: e.g. the sharded fused round registers as
    ``("round",)`` with its train body re-registered as a
    ``("train_body",)`` program and each GS body as ``("gs_body",)``.
    Jaxpr-less programs (donation / dtype checks) carry ``fn`` +
    abstract ``args`` instead.
    """
    name: str
    roles: Tuple[str, ...]
    jaxpr: Any = None                      # (Closed)Jaxpr, when traced
    fn: Optional[Callable] = None          # callable, for lower/eval_shape
    args: Tuple = ()                       # abstract args for fn
    donate_argnums: Tuple[int, ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _site_finding(rule: str, program: Program, site, message: str,
                  tag: str = TAG) -> Finding:
    detail = site.describe()
    return Finding(tag=tag, rule=rule, file=site.file, line=site.line,
                   message=f"{program.name}: {message} — {detail}")


class ContractRule:
    """Base rule: ``roles`` it applies to + a ``check`` returning
    findings (empty list = contract satisfied)."""
    name: str = "ContractRule"
    roles: Tuple[str, ...] = ()

    def applies(self, program: Program) -> bool:
        return any(r in program.roles for r in self.roles)

    def check(self, program: Program) -> List[Finding]:
        raise NotImplementedError


class CollectiveFree(ContractRule):
    """No cross-shard communication anywhere in the program."""
    name = "CollectiveFree"
    roles = ("train_body",)

    def check(self, program: Program) -> List[Finding]:
        from repro.distributed import runtime
        return [
            _site_finding(self.name, program, s,
                          "collective in a body that must be "
                          "collective-free between AIP refreshes")
            for s in walker.sites(program.jaxpr, runtime.COLLECTIVE_PRIMS)
        ]


class HaloOnly(ContractRule):
    """Only neighbour halo exchanges, and at least one of them."""
    name = "HaloOnly"
    roles = ("gs_body",)

    def check(self, program: Program) -> List[Finding]:
        from repro.distributed import runtime
        found = walker.sites(program.jaxpr, runtime.COLLECTIVE_PRIMS)
        out = [
            _site_finding(self.name, program, s,
                          f"non-halo collective in a region-decomposed "
                          f"GS body (allowed: "
                          f"{sorted(runtime.HALO_PRIMS)})")
            for s in found if s.prim not in runtime.HALO_PRIMS
        ]
        if not found:
            out.append(Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: no halo exchange at all — "
                        f"this is not the region-decomposed GS program"))
        return out


class NoHostCallback(ContractRule):
    """No host-callback primitive inside an on-mesh program."""
    name = "NoHostCallback"
    roles = ("round", "train_round", "train_body", "gs_body", "collect",
             "program")

    def check(self, program: Program) -> List[Finding]:
        return [
            _site_finding(self.name, program, s,
                          "host callback inside a traced round program "
                          "(hidden device<->host sync)")
            for s in walker.sites(program.jaxpr, CALLBACK_PRIMS)
        ]


class DonationUsed(ContractRule):
    """Every donated buffer is actually aliased into an output at lower
    time.

    The observable signal is the donation attributes on the lowered
    module's parameters — ``tf.aliasing_output`` when the alias is
    resolved at lower time, ``jax.buffer_donor`` when it is deferred to
    XLA (the sharded round takes this path). A donated-but-unused
    buffer is dropped from the lowered program and carries neither
    attribute (jax does not reliably warn on CPU), so the rule counts
    donor-marked parameters against the donated leaf count
    (``meta["expect_aliased"]`` overrides; default = leaves of the
    donated arguments). Lower-time donation warnings are violations
    too.
    """
    name = "DonationUsed"
    roles = ("donated",)

    def check(self, program: Program) -> List[Finding]:
        if program.fn is None:
            return []
        jitted = program.fn
        if not hasattr(jitted, "lower"):
            jitted = jax.jit(jitted,
                             donate_argnums=program.donate_argnums)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            lowered = jitted.lower(*program.args)
        out: List[Finding] = []
        for w in caught:
            msg = str(w.message)
            if "donated" in msg.lower():
                out.append(Finding(
                    tag=TAG, rule=self.name,
                    message=f"{program.name}: donation leaked — {msg}"))
        expected = program.meta.get("expect_aliased")
        if expected is None:
            expected = sum(len(jax.tree.leaves(program.args[i]))
                           for i in program.donate_argnums
                           if i < len(program.args))
        text = lowered.as_text()
        aliased = (text.count("tf.aliasing_output")
                   + text.count("jax.buffer_donor"))
        if aliased < expected:
            out.append(Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: only {aliased} of {expected} "
                        f"donated buffers aliased into outputs — the "
                        f"rest are silently copied every call"))
        return out


def _float_dtypes(tree) -> set:
    import jax.numpy as jnp
    out = set()
    for leaf in jax.tree.leaves(tree):
        dt = jnp.asarray(leaf).dtype if not hasattr(leaf, "dtype") \
            else leaf.dtype
        if jnp.issubdtype(dt, jnp.floating):
            out.add(jnp.dtype(dt))
    return out


class DtypeRoundTrip(ContractRule):
    """bf16 in ⇒ bf16 out: no floating output wider than the widest
    floating input (abstractly, via ``eval_shape`` — no FLOPs)."""
    name = "DtypeRoundTrip"
    roles = ("dtype",)

    def check(self, program: Program) -> List[Finding]:
        import jax.numpy as jnp
        if program.fn is None:
            return []
        try:
            out_tree = jax.eval_shape(program.fn, *program.args)
        except Exception as e:
            # a program that cannot even trace at reduced precision has
            # a dtype bug by definition (e.g. an f32-promoting op inside
            # a scan whose carry stays bf16)
            first = str(e).split("\n", 1)[0]
            return [Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: does not trace at reduced "
                        f"precision — {type(e).__name__}: {first}")]
        in_floats = _float_dtypes(program.args)
        if not in_floats:
            return []
        widest_in = max(dt.itemsize for dt in in_floats)
        out: List[Finding] = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                out_tree)[0]:
            dt = jnp.dtype(leaf.dtype)
            if jnp.issubdtype(dt, jnp.floating) and \
                    dt.itemsize > widest_in:
                keystr = jax.tree_util.keystr(path)
                out.append(Finding(
                    tag=TAG, rule=self.name,
                    message=f"{program.name}: output{keystr} is {dt} "
                            f"but the widest floating input is "
                            f"{widest_in * 8}-bit — a silent upcast "
                            f"through the kernel path"))
        return out


class ScalarSyncBudget(ContractRule):
    """The fused round returns (carry, record); the record — the ONLY
    thing the driver fetches per round — must be host scalars drawn
    from the typed round schema. Extra keys, non-scalar leaves, or keys
    outside ``ROUND_KEYS`` would grow the once-per-round sync."""
    name = "ScalarSyncBudget"
    roles = ("round", "train_round")

    def check(self, program: Program) -> List[Finding]:
        from repro.obs import metrics
        if program.fn is None:
            return []
        result = jax.eval_shape(program.fn, *program.args)
        if not (isinstance(result, tuple) and len(result) == 2
                and isinstance(result[1], dict)):
            return [Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: round program must return "
                        f"(carry, record-dict), got "
                        f"{type(result).__name__}")]
        rec = result[1]
        out: List[Finding] = []
        extra = set(rec) - set(metrics.ROUND_KEYS)
        if extra:
            out.append(Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: record keys {sorted(extra)} "
                        f"are outside the typed round schema "
                        f"(repro.obs.metrics.ROUND_FIELDS)"))
        for k, v in sorted(rec.items()):
            for leaf in jax.tree.leaves(v):
                if getattr(leaf, "shape", ()) != ():
                    out.append(Finding(
                        tag=TAG, rule=self.name,
                        message=f"{program.name}: record[{k!r}] has "
                                f"shape {leaf.shape} — the per-round "
                                f"fetch must move scalars only"))
        budget = program.meta.get("sync_budget", len(metrics.ROUND_KEYS))
        if len(rec) > budget:
            out.append(Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: {len(rec)} record scalars "
                        f"exceed the per-round sync budget of {budget}"))
        return out


class ReshardCollectives(ContractRule):
    """The restore/re-shard path moves data; it must not compute on it.

    After a recovery the survivors re-place checkpoint rows onto the
    shrunken mesh and fetch sharded state back for snapshots — pure data
    movement, which XLA lowers to at most ``all-gather`` /
    ``collective-permute``. Any other collective in the compiled
    transfer (an ``all-reduce``, ``reduce-scatter``, ``all-to-all``)
    means the resume path is silently *combining* shards rather than
    moving rows — exactly the bug class that turns a bitwise resume into
    a numerically different run. Sharding-induced collectives do not
    exist at jaxpr level, so the rule inspects the *compiled* HLO text
    (same observable layer as ``DonationUsed``'s donation attributes).
    """
    name = "ReshardCollectives"
    roles = ("reshard",)
    # HLO op mnemonics; compiled text shows them as e.g. "all-gather",
    # "all-gather-start", "%all-gather.3 = ..."
    COLLECTIVE_TOKENS = ("all-reduce", "all-gather", "all-to-all",
                         "collective-permute", "reduce-scatter",
                         "collective-broadcast")
    ALLOWED = frozenset({"all-gather", "collective-permute"})

    @classmethod
    def _collectives_in_text(cls, text: str) -> List[str]:
        """Collective op tokens present in (compiled) HLO text, sorted.
        Longest-token-first matching so ``all-gather-start`` does not
        also count as a phantom second op."""
        found = set()
        for tok in cls.COLLECTIVE_TOKENS:
            if re.search(rf"(?<![\w-]){re.escape(tok)}(?![a-z])", text):
                found.add(tok)
        return sorted(found)

    def check(self, program: Program) -> List[Finding]:
        if program.fn is None:
            return []
        jitted = program.fn
        if not hasattr(jitted, "lower"):
            jitted = jax.jit(jitted)
        compiled = jitted.lower(*program.args).compile()
        text = compiled.as_text()
        banned = [tok for tok in self._collectives_in_text(text)
                  if tok not in self.ALLOWED]
        if banned:
            return [Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: compiled re-shard transfer "
                        f"contains {banned} — the restore path must be "
                        f"pure data movement (all-gather / "
                        f"collective-permute only)")]
        return []


class RingBufferResident(ContractRule):
    """The donating ring collect never leaves the device.

    Two claims make the ring a zero-copy path: (a) no host-callback
    primitive anywhere in the program — a hidden ``pure_callback`` would
    stage the wide ``(N, S, T, ...)`` dataset through the host exactly
    where the ring exists to avoid it; and (b) the returned dataset has
    the retired slot's tree structure, shapes, and dtypes bit-for-bit,
    which is what lets XLA alias every donated slot buffer into an
    output (``DonationUsed`` then counts the aliases on the lowered
    module — the two rules are one contract observed at two layers).
    A struct mismatch means some leaf is reallocated every round and the
    steady-state memory claim quietly doubles.
    """
    name = "RingBufferResident"
    roles = ("ring_collect",)

    @staticmethod
    def _struct(tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return treedef, [(tuple(leaf.shape), str(leaf.dtype))
                         for leaf in leaves]

    def check(self, program: Program) -> List[Finding]:
        out: List[Finding] = []
        if program.jaxpr is not None:
            out.extend(
                _site_finding(self.name, program, s,
                              "host callback inside the ring-buffer "
                              "collect — the device-resident dataset "
                              "just round-tripped through the host")
                for s in walker.sites(program.jaxpr, CALLBACK_PRIMS))
        if program.fn is None:
            return out
        slot_idx = (program.donate_argnums[0]
                    if program.donate_argnums else 0)
        slot = program.args[slot_idx]
        result = jax.eval_shape(program.fn, *program.args)
        slot_def, slot_leaves = self._struct(slot)
        res_def, res_leaves = self._struct(result)
        if slot_def != res_def or slot_leaves != res_leaves:
            out.append(Finding(
                tag=TAG, rule=self.name,
                message=f"{program.name}: collect output structure "
                        f"{res_leaves} differs from the donated slot "
                        f"{slot_leaves} — the slot cannot be aliased in "
                        f"place and the ring reallocates every round"))
        return out


DEFAULT_RULES: Tuple[ContractRule, ...] = (
    CollectiveFree(), HaloOnly(), NoHostCallback(), DonationUsed(),
    DtypeRoundTrip(), ScalarSyncBudget(), ReshardCollectives(),
    RingBufferResident(),
)


def run_rules(programs: Sequence[Program],
              rules: Sequence[ContractRule] = DEFAULT_RULES
              ) -> List[Finding]:
    """Check every rule against every program it applies to."""
    findings: List[Finding] = []
    for program in programs:
        for rule in rules:
            if rule.applies(program):
                findings.extend(rule.check(program))
    return findings


def raise_findings(findings: Sequence[Finding]) -> None:
    """Turn a non-empty finding list into one AssertionError (the shape
    the repo's in-process audits — ``audit_collectives`` and friends —
    raise)."""
    from repro.analysis.report import format_finding
    if findings:
        raise AssertionError("\n".join(
            format_finding(f, github=False) for f in findings))
