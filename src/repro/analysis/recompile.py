"""Steady-state recompile detector.

A traced round program that retraces after the first round silently
multiplies compile cost by the round count — the bug class behind past
"static arg changed every round" regressions. jax logs one message per
XLA compilation when ``jax_log_compiles`` is on; :class:`CompileCounter`
captures those messages, and :func:`check_steady_state` turns per-round
counter snapshots (taken from the driver's per-round ``log`` callback)
into contract findings: after the first full round has compiled
everything, later rounds must add **zero** new compilations on either
driver.
"""
from __future__ import annotations

import logging
from typing import List, Optional, Sequence

import jax

from repro.analysis.report import Finding

__all__ = ["CompileCounter", "check_steady_state"]

# the loggers jax's dispatch paths emit compile messages on (both the
# eager dispatch path and the pjit/pxla path)
_COMPILE_LOGGERS = ("jax._src.dispatch", "jax._src.interpreters.pxla")


class _CountingHandler(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.count = 0
        self.names: List[str] = []

    def emit(self, record):
        msg = record.getMessage()
        if "Compiling" in msg:
            self.count += 1
            self.names.append(msg.split("\n", 1)[0])


class CompileCounter:
    """Context manager counting XLA compilations while active.

    ::

        with CompileCounter() as cc:
            counts = []
            trainer.run(key, log=lambda rec: counts.append(cc.count))
        problems = check_steady_state(counts, what="loop driver")
    """

    def __init__(self):
        self._handler = _CountingHandler()
        self._was_on: Optional[bool] = None

    @property
    def count(self) -> int:
        return self._handler.count

    @property
    def names(self) -> List[str]:
        return list(self._handler.names)

    def __enter__(self) -> "CompileCounter":
        self._was_on = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        for name in _COMPILE_LOGGERS:
            logging.getLogger(name).addHandler(self._handler)
        return self

    def __exit__(self, *exc):
        for name in _COMPILE_LOGGERS:
            logging.getLogger(name).removeHandler(self._handler)
        jax.config.update("jax_log_compiles", bool(self._was_on))
        return False


def check_steady_state(per_round_counts: Sequence[int], *,
                       what: str = "driver") -> List[Finding]:
    """Findings for any round after the first that triggered new
    compilations.

    ``per_round_counts[i]`` is the cumulative compile count observed
    when round ``i``'s record arrived. Round 0 may compile anything it
    likes (it IS the compile round); every later round must hold the
    counter flat. Needs at least two rounds to say anything.
    """
    out: List[Finding] = []
    if len(per_round_counts) < 2:
        return out
    steady = per_round_counts[0]
    for i, count in enumerate(per_round_counts[1:], start=1):
        if count > steady:
            out.append(Finding(
                tag="CONTRACT-VIOLATION", rule="SteadyStateCompile",
                message=f"{what}: round {i} triggered "
                        f"{count - steady} recompilation(s) after the "
                        f"warm-up round — a static argument or shape "
                        f"is changing per round"))
            steady = count
    return out
