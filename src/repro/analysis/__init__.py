"""Static verification layer for the DIALS runtime.

Two passes over the repo's traced programs and source tree:

* **jaxpr contracts** (``walker`` + ``contracts`` + ``recompile``) — a
  path-aware jaxpr traversal with source provenance, declarative
  ``ContractRule``s over it (collective placement, donation, dtype
  round-trip, host-sync budget, steady-state compile), and the program
  registry in ``programs`` that traces both drivers across every
  registered scenario;
* **repo lint** (``lint``) — AST rules ruff cannot express: PRNG key
  discipline, host-time/``numpy.random`` inside traced code, Python
  branching on traced values.

Entry point: ``tools/check_programs.py`` (CI ``analysis`` job). Shared
finding formatting lives in ``report`` and is reused by
``tools/telemetry_report.py`` and ``benchmarks/check_bench.py``.
"""
from repro.analysis import walker  # noqa: F401
from repro.analysis.report import Finding, format_finding  # noqa: F401
