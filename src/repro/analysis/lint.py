"""Repo-specific AST lint — rules ruff cannot express.

The jaxpr contract engine (``repro.analysis.contracts``) audits what a
program *traced to*; this pass audits the *source* for bug classes that
trace fine and fail silently at runtime:

``prng-reuse``           a PRNG key passed to a second consuming
                         ``jax.random`` call without being re-derived —
                         correlated randomness across draws. The
                         ``repro.core.env_pool`` key helpers
                         (``stream_keys`` / ``init_keys`` /
                         ``step_keys``) register as consumers too: each
                         derives a whole fold-in chain from its first
                         argument, so feeding the same key (or stream-
                         key array) to a second consumer correlates
                         every stream at once.
``prng-discarded-split`` a result of ``jax.random.split`` bound to a
                         name that is never read (underscore-prefixed
                         names opt out — the repo's "deliberately
                         unused" convention).
``prng-relative-fold``   ``jax.random.fold_in`` keyed on
                         ``axis_index`` — per-agent keys must fold the
                         ABSOLUTE agent id, or randomness changes with
                         the shard count and the sharded round stops
                         matching the loop driver (the
                         shard-equivariance contract of
                         ``repro.core.ials``).
``numpy-random``         a ``numpy.random`` *call* in runtime modules —
                         host RNG inside code that also traces is
                         either dead under jit or a silent
                         nondeterminism leak. (Annotations like
                         ``np.random.Generator`` are fine.)
``host-time``            ``time.time()``-family calls inside *nested*
                         functions of runtime modules. Depth-1
                         functions/methods are driver host code where
                         wall-clock spans are the point; nested
                         functions are the traced bodies, where a
                         host clock is a constant baked in at trace
                         time.
``traced-branch``        Python ``if``/``while`` on a bare parameter of
                         a nested function in ``core/``/
                         ``distributed/`` — parameters of traced bodies
                         are tracers; branching on one is a
                         ConcretizationError at best and a silent
                         trace-time constant at worst. (``is None``
                         checks and config attributes don't trip
                         this.)

Run via ``tools/check_programs.py --lint``; findings carry file:line
and render as CI annotations through ``repro.analysis.report``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Tuple

from repro.analysis.report import Finding

__all__ = ["lint_source", "lint_file", "lint_paths", "default_targets"]

TAG = "LINT"

# jax.random calls that consume a key's uniqueness (passing the same key
# to two of these yields correlated draws)
CONSUMING = frozenset({
    "split", "normal", "uniform", "bernoulli", "categorical", "randint",
    "permutation", "choice", "gumbel", "exponential", "laplace",
    "truncated_normal", "bits", "poisson", "gamma", "beta", "dirichlet",
    "orthogonal", "rademacher", "cauchy", "logistic",
    "multivariate_normal", "ball", "t", "loggamma", "binomial",
})

# repro.core.env_pool helpers that consume their first key argument the
# way a jax.random call does: each derives per-stream fold-in chains
# from it, so passing the same key/stream-key array to a second
# consumer correlates every stream's draws at once
POOL_CONSUMING = frozenset({"stream_keys", "init_keys", "step_keys"})

HOST_CLOCKS = frozenset({"time", "perf_counter", "monotonic",
                         "process_time", "perf_counter_ns", "time_ns"})

# modules whose code traces (lint targets); traced-branch additionally
# restricts to the runtime packages where every nested fn is on-mesh
RUNTIME_DIRS = ("core", "distributed", "kernels", "marl", "nn", "envs",
                "models", "optim", "data")
BRANCH_DIRS = ("core", "distributed")


def _dotted(node) -> str:
    """Best-effort dotted name of an expression (``jax.random.split``)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_jax_random(call: ast.Call) -> Optional[str]:
    """The jax.random function name of a call, or None."""
    if not isinstance(call.func, ast.Attribute):
        return None
    dotted = _dotted(call.func)
    head, _, fn = dotted.rpartition(".")
    if head in ("jax.random", "random", "jrandom", "jr"):
        return fn
    return None


def _is_pool_key_helper(call: ast.Call) -> Optional[str]:
    """The env_pool key-helper name of a call (qualified or bare), or
    None — these consume their first key argument like jax.random."""
    if isinstance(call.func, ast.Attribute):
        name = call.func.attr
    elif isinstance(call.func, ast.Name):
        name = call.func.id
    else:
        return None
    return name if name in POOL_CONSUMING else None


def _contains_axis_index(node) -> bool:
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Attribute):
            name = sub.attr
        elif isinstance(sub, ast.Name):
            name = sub.id
        if name == "axis_index":
            return True
    return False


class _KeyState:
    """Per-function PRNG dataflow: which names hold keys that were
    already consumed, which split results still await a read, and which
    names carry shard-relative (``axis_index``-derived) data."""

    def __init__(self):
        self.consumed: Dict[str, Tuple[int, str]] = {}   # name -> (line, by)
        self.split_unused: Dict[str, int] = {}           # name -> line
        self.relative: set = set()                       # axis_index data

    def copy(self) -> "_KeyState":
        st = _KeyState()
        st.consumed = dict(self.consumed)
        st.split_unused = dict(self.split_unused)
        st.relative = set(self.relative)
        return st

    def merge(self, other: "_KeyState") -> None:
        # a branch consuming a key counts: union of consumption; a read
        # on either branch satisfies the split result
        self.consumed.update(other.consumed)
        self.relative |= other.relative
        for name in list(self.split_unused):
            if name not in other.split_unused:
                del self.split_unused[name]


class _FunctionLinter:
    """Statement-ordered walk of one function body (branch-aware, loop
    bodies analyzed once — reuse across loop iterations is out of
    scope)."""

    def __init__(self, checker: "_Checker", depth: int):
        self.checker = checker
        self.depth = depth
        self.state = _KeyState()

    # -- expression pass ------------------------------------------------------
    @staticmethod
    def _is_relative(node, state: _KeyState) -> bool:
        """Does an expression carry ``axis_index`` data — directly, or
        through a name previously assigned from one?"""
        if _contains_axis_index(node):
            return True
        return any(isinstance(sub, ast.Name) and sub.id in state.relative
                   for sub in ast.walk(node))

    def use_expr(self, node, state: _KeyState) -> None:
        """Record name reads + key consumption inside one expression."""
        if node is None:
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                state.split_unused.pop(sub.id, None)
            if isinstance(sub, ast.Call):
                self.call(sub, state)

    def call(self, call: ast.Call, state: _KeyState) -> None:
        fn = _is_jax_random(call)
        pool_fn = None if fn is not None else _is_pool_key_helper(call)
        if fn is None and pool_fn is None:
            return
        if fn == "fold_in" and len(call.args) >= 2 and \
                self._is_relative(call.args[1], state):
            self.checker.add(call, "prng-relative-fold",
                             "fold_in keyed on axis_index — fold the "
                             "absolute agent id so per-agent randomness "
                             "is shard-count invariant")
        consumes = (fn in CONSUMING) if fn is not None else True
        if consumes and call.args and \
                isinstance(call.args[0], ast.Name):
            name = call.args[0].id
            prior = state.consumed.get(name)
            if prior is not None:
                self.checker.add(
                    call, "prng-reuse",
                    f"key {name!r} already consumed by "
                    f"jax.random.{prior[1]} at line {prior[0]} — "
                    f"re-deriving (split/fold_in) is required before "
                    f"every consuming call")
            else:
                state.consumed[name] = (
                    call.lineno, fn if fn is not None
                    else f"(env_pool.{pool_fn})")

    # -- statement pass -------------------------------------------------------
    def assign_targets(self, targets, value, state: _KeyState) -> None:
        names: List[str] = []
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                names.append(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    if isinstance(el, ast.Name):
                        names.append(el.id)
        relative = value is not None and self._is_relative(value, state)
        for name in names:
            state.consumed.pop(name, None)       # rebind = fresh key
            state.split_unused.pop(name, None)
            if relative:
                state.relative.add(name)
            else:
                state.relative.discard(name)
        if isinstance(value, ast.Call) and \
                _is_jax_random(value) == "split":
            for tgt in targets:
                elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                    else [tgt]
                for el in elts:
                    if isinstance(el, ast.Name) and \
                            not el.id.startswith("_"):
                        state.split_unused[el.id] = value.lineno

    def run(self, body) -> None:
        self.block(body, self.state)
        for name, line in sorted(self.state.split_unused.items(),
                                 key=lambda kv: kv[1]):
            self.checker.add_at(
                line, "prng-discarded-split",
                f"split result {name!r} is never used — either consume "
                f"it or name it with a leading underscore")

    def block(self, body, state: _KeyState) -> None:
        for stmt in body:
            self.stmt(stmt, state)

    def stmt(self, stmt, state: _KeyState) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.checker.function(stmt, self.depth + 1)
            return
        if isinstance(stmt, ast.Assign):
            self.use_expr(stmt.value, state)
            self.assign_targets(stmt.targets, stmt.value, state)
            return
        if isinstance(stmt, ast.AnnAssign):
            self.use_expr(stmt.value, state)
            if stmt.value is not None:
                self.assign_targets([stmt.target], stmt.value, state)
            return
        if isinstance(stmt, ast.AugAssign):
            self.use_expr(stmt.value, state)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            self.branch_check(stmt)
            self.use_expr(stmt.test, state)
            then_state = state.copy()
            self.block(stmt.body, then_state)
            else_state = state.copy()
            self.block(stmt.orelse, else_state)
            then_state.merge(else_state)
            state.consumed = then_state.consumed
            state.split_unused = then_state.split_unused
            return
        if isinstance(stmt, ast.For):
            self.use_expr(stmt.iter, state)
            self.assign_targets([stmt.target], None, state)
            self.block(stmt.body, state)
            self.block(stmt.orelse, state)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.use_expr(item.context_expr, state)
            self.block(stmt.body, state)
            return
        if isinstance(stmt, ast.Try):
            self.block(stmt.body, state)
            for handler in stmt.handlers:
                self.block(handler.body, state)
            self.block(stmt.orelse, state)
            self.block(stmt.finalbody, state)
            return
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.expr):
                self.use_expr(node, state)

    def branch_check(self, stmt) -> None:
        """``traced-branch``: if/while on a bare parameter of a nested
        function in the runtime packages."""
        if self.depth < 2 or not self.checker.branch_rules:
            return
        test = stmt.test
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            test = test.operand
        if isinstance(test, ast.Name) and \
                test.id in self.checker.param_stack[-1]:
            kind = "if" if isinstance(stmt, ast.If) else "while"
            self.checker.add(
                stmt, "traced-branch",
                f"Python `{kind}` on parameter {test.id!r} of a nested "
                f"(traced) function — tracers cannot drive host control "
                f"flow; use lax.cond/lax.select or hoist the decision "
                f"to a static config")


class _Checker(ast.NodeVisitor):
    def __init__(self, filename: str, *, branch_rules: bool):
        self.filename = filename
        self.branch_rules = branch_rules
        self.findings: List[Finding] = []
        self.param_stack: List[set] = []

    def add(self, node, rule: str, message: str) -> None:
        self.findings.append(Finding(
            tag=TAG, rule=rule, file=self.filename,
            line=getattr(node, "lineno", None), message=message))

    def add_at(self, line: int, rule: str, message: str) -> None:
        self.findings.append(Finding(
            tag=TAG, rule=rule, file=self.filename, line=line,
            message=message))

    # -- module / class walk --------------------------------------------------
    def check_module(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                self.flat_call(node)
        for stmt in tree.body:
            self.toplevel(stmt)

    def toplevel(self, stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.function(stmt, 1)
        elif isinstance(stmt, ast.ClassDef):
            for sub in stmt.body:
                self.toplevel(sub)

    def flat_call(self, call: ast.Call) -> None:
        """Position-independent call rules (numpy-random)."""
        dotted = _dotted(call.func)
        head = dotted.rpartition(".")[0]
        if head in ("np.random", "numpy.random"):
            self.add(call, "numpy-random",
                     f"{dotted}() in a runtime module — host RNG is "
                     f"dead under jit; thread a jax.random key instead")

    # -- function walk --------------------------------------------------------
    def function(self, fn, depth: int) -> None:
        params = {a.arg for a in (fn.args.posonlyargs + fn.args.args +
                                  fn.args.kwonlyargs)} - \
            {"self", "cls", "cfg", "config"}
        self.param_stack.append(params)
        if depth >= 2:
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    dotted = _dotted(node.func)
                    mod, _, attr = dotted.rpartition(".")
                    if mod == "time" and attr in HOST_CLOCKS:
                        self.add(node, "host-time",
                                 f"time.{attr}() inside a nested "
                                 f"(traced) function — a host clock is "
                                 f"a trace-time constant under jit; "
                                 f"time in the driver instead")
        linter = _FunctionLinter(self, depth)
        linter.run(fn.body)
        self.param_stack.pop()


def lint_source(source: str, filename: str = "<string>", *,
                branch_rules: bool = True) -> List[Finding]:
    """Lint one module's source text (the test-fixture entry point)."""
    tree = ast.parse(source, filename=filename)
    checker = _Checker(filename, branch_rules=branch_rules)
    checker.check_module(tree)
    return checker.findings


def lint_file(path: str, *, branch_rules: Optional[bool] = None
              ) -> List[Finding]:
    if branch_rules is None:
        branch_rules = any(os.sep + d + os.sep in path
                           for d in BRANCH_DIRS)
    with open(path) as f:
        source = f.read()
    return lint_source(source, filename=path, branch_rules=branch_rules)


def default_targets(src_root: str) -> List[str]:
    """The runtime modules the lint pass covers, under ``src_root``
    (= ``.../src/repro``)."""
    out: List[str] = []
    for d in RUNTIME_DIRS:
        base = os.path.join(src_root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirs, files in os.walk(base):
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(files) if f.endswith(".py"))
    return sorted(out)


def lint_paths(paths) -> List[Finding]:
    findings: List[Finding] = []
    for path in paths:
        findings.extend(lint_file(path))
    return findings
