"""Decoder-only LM (and the generic backbone used by enc-dec / VLM).

* Depth lowers as ``lax.scan`` over ``repeats`` copies of the layer period —
  compile time and HLO size are O(period), not O(n_layers).
* Per-repeat remat (``jax.checkpoint``) with a configurable policy.
* Memory-safe loss: cross-entropy is computed in sequence chunks
  (``loss_chunk``) so the (B, T, vocab) logits tensor is never materialized
  — critical for the 100k–256k vocab architectures.
* Decode: one-token step threading stacked per-layer caches through the
  same scan structure.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.nn import attention as attn_mod
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int                               # len(period) * repeats
    period: Tuple[blocks.LayerSpec, ...]
    shared: Optional[blocks.LayerSpec] = None   # zamba-style shared block
    tie_embeddings: bool = True
    final_softcap: Optional[float] = None
    embed_scale: bool = False                   # gemma: x *= sqrt(d_model)
    dtype: object = jnp.bfloat16
    remat: str = "full"                         # none | full | dots
    loss_chunk: int = 2048
    use_flash: bool = False
    # fully unroll the depth scan (dry-run cost extrapolation only: XLA's
    # cost analysis counts a while body once, unrolled bodies count fully)
    scan_unroll: bool = False

    @property
    def repeats(self) -> int:
        assert self.n_layers % len(self.period) == 0, \
            f"{self.n_layers} layers not divisible by period {len(self.period)}"
        return self.n_layers // len(self.period)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_lm(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4 + len(cfg.period))
    params = {
        "embed": layers.embedding_init(keys[0], cfg.vocab, cfg.d_model,
                                       dtype=cfg.dtype,
                                       stddev=cfg.d_model ** -0.5),
        "final_norm": layers.rmsnorm_init(cfg.d_model),
    }
    stacked = []
    for j, spec in enumerate(cfg.period):
        lkeys = jax.random.split(keys[2 + j], cfg.repeats)
        stacked.append(jax.vmap(lambda k: blocks.block_init(k, spec))(lkeys))
    params["layers"] = stacked
    if cfg.shared is not None:
        params["shared"] = blocks.block_init(keys[1], cfg.shared)
    if not cfg.tie_embeddings:
        params["unembed"] = layers.linear_init(
            keys[-1], cfg.d_model, cfg.vocab, dtype=cfg.dtype)
    return params


def lm_logical_specs(cfg: ModelConfig):
    specs = {
        "embed": {"table": ("vocab", "embed")},
        "final_norm": {"scale": ("embed",)},
    }
    stacked = []
    for spec in cfg.period:
        tree = blocks.block_logical_specs(spec)
        # prepend the scan ("layers") axis to every leaf
        stacked.append(jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), tree,
            is_leaf=lambda x: isinstance(x, tuple)))
    specs["layers"] = stacked
    if cfg.shared is not None:
        specs["shared"] = blocks.block_logical_specs(cfg.shared)
    if not cfg.tie_embeddings:
        specs["unembed"] = {"w": ("embed", "vocab")}
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params, tokens, cfg: ModelConfig, *, cross_kv=None,
            positions=None, act_constraint=None):
    """tokens: (B, T) int32 -> final hidden states (B, T, d_model).

    ``act_constraint``: optional sharding constraint applied to the
    residual stream at layer-period boundaries (sequence parallelism: the
    scan carry — the only activation saved across the depth scan — is
    stored sequence-sharded over the model axis, cutting saved-activation
    memory by the TP degree)."""
    x = layers.embedding_lookup(params["embed"], tokens,
                                scale_by_sqrt_dim=cfg.embed_scale)
    if act_constraint is not None:
        x = act_constraint(x)
    shared_p = params.get("shared")

    def body(carry, layer_p):
        x = carry
        aux = {"load_balance": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
        for j, spec in enumerate(cfg.period):
            x, a = blocks.block_apply(layer_p[j], x, spec,
                                      cross_kv=cross_kv,
                                      positions=positions,
                                      use_flash=cfg.use_flash)
            if a is not None:
                aux = jax.tree.map(jnp.add, aux, a)
        if shared_p is not None:
            x, _ = blocks.block_apply(shared_p, x, cfg.shared,
                                      cross_kv=cross_kv, positions=positions,
                                      use_flash=cfg.use_flash)
        if act_constraint is not None:
            x = act_constraint(x)
        return x, aux

    x, auxs = jax.lax.scan(_remat_wrap(body, cfg.remat), x,
                           tuple(params["layers"]),
                           unroll=cfg.repeats if cfg.scan_unroll else 1)
    x = layers.rmsnorm(params["final_norm"], x)
    aux = jax.tree.map(jnp.sum, auxs)
    return x, aux


def logits_fn(params, x, cfg: ModelConfig):
    """Full logits (fp32). Only safe for small vocab/short sequences."""
    if cfg.tie_embeddings:
        logits = layers.embedding_logits(params["embed"], x)
    else:
        logits = layers.linear(params["unembed"], x).astype(jnp.float32)
    return layers.softcap(logits, cfg.final_softcap)


# ---------------------------------------------------------------------------
# Loss (chunked over sequence — never materializes (B, T, V))
# ---------------------------------------------------------------------------
def token_xent(params, x, labels, cfg: ModelConfig):
    """x: (B, T, d), labels: (B, T) -> per-token loss (B, T), fp32."""
    b, t, d = x.shape
    chunk = min(cfg.loss_chunk, t)
    if t % chunk != 0:
        chunk = t
    nch = t // chunk
    xr = jnp.moveaxis(x.reshape(b, nch, chunk, d), 1, 0)
    lr = jnp.moveaxis(labels.reshape(b, nch, chunk), 1, 0)

    def f(args):
        xc, lc = args
        logits = logits_fn(params, xc, cfg)            # (B, chunk, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return logz - gold

    losses = jax.lax.map(jax.checkpoint(f), (xr, lr))  # (nch, B, chunk)
    return jnp.moveaxis(losses, 0, 1).reshape(b, t)


def lm_loss(params, batch, cfg: ModelConfig, *,
            lb_weight: float = 0.01, z_weight: float = 1e-3, cross_kv=None,
            act_constraint=None):
    """batch: dict(tokens=(B,T), labels=(B,T)[, cross_kv]). Returns (loss, metrics)."""
    cross = batch.get("cross_kv", cross_kv)
    x, aux = forward(params, batch["tokens"], cfg, cross_kv=cross,
                     act_constraint=act_constraint)
    per_tok = token_xent(params, x, batch["labels"], cfg)
    xent = per_tok.mean()
    loss = xent + lb_weight * aux["load_balance"] + z_weight * aux["z_loss"]
    return loss, {"xent": xent, **aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_caches(params, cfg: ModelConfig, batch: int, max_len: int,
                cross_src=None):
    """Stacked caches: one pytree per period position, leading repeats axis.
    Cross-attn blocks precompute projected K/V from ``cross_src`` once."""
    caches = []
    for j, spec in enumerate(cfg.period):
        if spec.mixer == "cross_attn":
            def proj(p):
                dh = spec.attn.dh
                k = layers.linear(p["mixer"]["k"], cross_src)
                v = layers.linear(p["mixer"]["v"], cross_src)
                s = cross_src.shape
                return {"k": k.reshape(s[0], s[1], spec.attn.num_kv_heads, dh),
                        "v": v.reshape(s[0], s[1], spec.attn.num_kv_heads, dh)}
            caches.append(jax.vmap(proj)(params["layers"][j]))
        else:
            one = blocks.init_block_cache(spec, batch, max_len)
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.repeats,) + a.shape), one))
    shared_cache = None
    if cfg.shared is not None:
        one = blocks.init_block_cache(cfg.shared, batch, max_len)
        shared_cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.repeats,) + a.shape), one)
    return {"layers": caches, "shared": shared_cache}


def cache_logical_specs(cfg: ModelConfig):
    """Logical-axis tree parallel to :func:`init_caches`'s output (stacked
    caches get a leading "layers" axis)."""
    def stack(tree):
        return jax.tree.map(lambda ax: ("layers",) + tuple(ax), tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    layers_specs = [stack(blocks.block_cache_logical_specs(s))
                    for s in cfg.period]
    shared = (stack(blocks.block_cache_logical_specs(cfg.shared))
              if cfg.shared is not None else None)
    return {"layers": layers_specs, "shared": shared}


def _decode_cross(p, x, cache, spec):
    """Cross-attn decode against precomputed K/V."""
    h = layers.rmsnorm(p["norm1"], x)
    b = x.shape[0]
    dh = spec.attn.dh
    q = layers.linear(p["mixer"]["q"], h).reshape(b, 1, spec.attn.num_heads, dh)
    out = attn_mod.attend(q, cache["k"], cache["v"], causal=False,
                          softcap=spec.attn.attn_softcap)
    h = layers.linear(p["mixer"]["o"], out.reshape(b, 1, -1))
    if spec.gated_cross:
        h = h * jnp.tanh(p["gate_attn"]).astype(h.dtype)
    x = x + h
    if spec.ffn != "none":
        h = layers.rmsnorm(p["norm2"], x)
        h, _ = blocks._ffn_apply(p, spec, h)
        if spec.gated_cross:
            h = h * jnp.tanh(p["gate_ffn"]).astype(h.dtype)
        x = x + h
    return x


def decode_step(params, token, caches, index, cfg: ModelConfig, *,
                logits_constraint=None):
    """token: (B, 1) int32, index: scalar int32 position. Returns
    (logits (B, 1, V) fp32, new_caches)."""
    x = layers.embedding_lookup(params["embed"], token,
                                scale_by_sqrt_dim=cfg.embed_scale)
    shared_p = params.get("shared")

    def body(x, inp):
        layer_p, cache, shared_c = inp
        new_caches = []
        for j, spec in enumerate(cfg.period):
            if spec.mixer == "cross_attn":
                x = _decode_cross(layer_p[j], x, cache[j], spec)
                new_caches.append(cache[j])
            else:
                x, c = blocks.block_decode(
                    layer_p[j], x, cache[j], index, spec,
                    logits_constraint=logits_constraint)
                new_caches.append(c)
        if shared_p is not None:
            x, shared_c = blocks.block_decode(
                shared_p, x, shared_c, index, cfg.shared,
                logits_constraint=logits_constraint)
        return x, (tuple(new_caches), shared_c)

    x, new = jax.lax.scan(
        body, x,
        (tuple(params["layers"]), tuple(caches["layers"]), caches["shared"]),
        unroll=cfg.repeats if cfg.scan_unroll else 1)
    x = layers.rmsnorm(params["final_norm"], x)
    logits = logits_fn(params, x, cfg)
    return logits, {"layers": list(new[0]), "shared": new[1]}
