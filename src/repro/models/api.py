"""Uniform model API over the three backbone kinds (lm / encdec / vlm).

``ArchSpec`` is what a config file in ``repro.configs`` produces; the
launcher, dry-run, trainer and tests all speak this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, lm, vlm


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    kind: str                      # lm | encdec | vlm
    cfg: object                    # ModelConfig | EncDecConfig
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    sub_quadratic: bool = False    # eligible for the long_500k cell
    has_decode: bool = True
    source: str = ""
    # stub-frontend shapes
    n_frames: int = 0              # encdec stub frames
    n_patches: int = 0             # vlm stub patches
    vision_dim: int = 0


def init(key, spec: ArchSpec):
    if spec.kind == "encdec":
        return encdec.init_encdec(key, spec.cfg)
    return lm.init_lm(key, spec.cfg)


def logical_specs(spec: ArchSpec):
    if spec.kind == "encdec":
        return encdec.encdec_logical_specs(spec.cfg)
    return lm.lm_logical_specs(spec.cfg)


def loss_fn(spec: ArchSpec, *, act_constraint=None) -> Callable:
    if spec.kind == "encdec":
        return lambda p, b: encdec.encdec_loss(
            p, b, spec.cfg, act_constraint=act_constraint)
    if spec.kind == "vlm":
        return lambda p, b: vlm.vlm_loss(
            p, b, spec.cfg, act_constraint=act_constraint)
    return lambda p, b: lm.lm_loss(
        p, b, spec.cfg, act_constraint=act_constraint)


def init_caches(params, spec: ArchSpec, batch: int, max_len: int,
                batch_inputs: Optional[dict] = None):
    binp = batch_inputs or {}
    if spec.kind == "encdec":
        return encdec.init_decode_caches(params, spec.cfg, binp["frames"],
                                         batch, max_len)
    if spec.kind == "vlm":
        return vlm.init_decode_caches(params, spec.cfg, binp["patches"],
                                      batch, max_len)
    return lm.init_caches(params, spec.cfg, batch, max_len)


def decode_step(params, token, caches, index, spec: ArchSpec):
    if spec.kind == "encdec":
        return encdec.decode_step(params, token, caches, index, spec.cfg)
    return lm.decode_step(params, token, caches, index,
                          spec.cfg if spec.kind != "encdec" else spec.cfg)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def active_param_count(params, spec: ArchSpec) -> int:
    """For MoE: count experts at top_k/num_experts weight (6·N_active·D)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        frac = 1.0
        if any(k in ("up", "down", "gate") for k in keys) and leaf.ndim == 3:
            # stacked-expert weight (E, d, f) — possibly (layers, E, d, f)
            moe_specs = [s.moe for s in _periods(spec) if s.moe is not None]
            if moe_specs:
                frac = moe_specs[0].top_k / moe_specs[0].num_experts
        total += int(leaf.size * frac)
    return total


def _periods(spec: ArchSpec):
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    out = list(cfg.period)
    if cfg.shared is not None:
        out.append(cfg.shared)
    return out
