"""Encoder-decoder backbone (whisper-tiny).

Per the brief the audio frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (B, n_frames, d_model) where the conv
subsampler would produce them. The encoder is a non-causal self-attention
stack; the decoder is the generic LM with interleaved cross-attention
blocks (each whisper layer's self+cross+mlp is modelled as a period of
two blocks: [self/no-ffn, cross/mlp] — same compute graph).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks, lm
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    encoder_period: Tuple[blocks.LayerSpec, ...]
    encoder_layers: int
    decoder: lm.ModelConfig
    d_model: int = 384
    dtype: object = jnp.bfloat16

    @property
    def encoder_repeats(self) -> int:
        return self.encoder_layers // len(self.encoder_period)


def init_encdec(key, cfg: EncDecConfig):
    ke, kd = jax.random.split(key)
    stacked = []
    for j, spec in enumerate(cfg.encoder_period):
        lkeys = jax.random.split(jax.random.fold_in(ke, j), cfg.encoder_repeats)
        stacked.append(jax.vmap(lambda k: blocks.block_init(k, spec))(lkeys))
    return {
        "encoder": {"layers": stacked,
                    "final_norm": layers.rmsnorm_init(cfg.d_model)},
        "decoder": lm.init_lm(kd, cfg.decoder),
    }


def encdec_logical_specs(cfg: EncDecConfig):
    enc_stacked = []
    for spec in cfg.encoder_period:
        tree = blocks.block_logical_specs(spec)
        enc_stacked.append(jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), tree,
            is_leaf=lambda x: isinstance(x, tuple)))
    return {
        "encoder": {"layers": enc_stacked,
                    "final_norm": {"scale": ("embed",)}},
        "decoder": lm.lm_logical_specs(cfg.decoder),
    }


def encode(params, frames, cfg: EncDecConfig):
    """frames: (B, T_frames, d_model) stub embeddings -> encoder output."""
    def body(x, layer_p):
        for j, spec in enumerate(cfg.encoder_period):
            x, _ = blocks.block_apply(layer_p[j], x, spec)
        return x, None

    x, _ = jax.lax.scan(body, frames.astype(cfg.dtype),
                        tuple(params["encoder"]["layers"]),
                        unroll=(cfg.encoder_repeats
                                if cfg.decoder.scan_unroll else 1))
    return layers.rmsnorm(params["encoder"]["final_norm"], x)


def encdec_loss(params, batch, cfg: EncDecConfig, *, act_constraint=None):
    """batch: dict(frames=(B,Tf,d), tokens=(B,T), labels=(B,T))."""
    enc_out = encode(params, batch["frames"], cfg)
    return lm.lm_loss(params["decoder"],
                      {"tokens": batch["tokens"], "labels": batch["labels"]},
                      cfg.decoder, cross_kv=enc_out,
                      act_constraint=act_constraint)


def init_decode_caches(params, cfg: EncDecConfig, frames, batch: int,
                       max_len: int):
    enc_out = encode(params, frames, cfg)
    return lm.init_caches(params["decoder"], cfg.decoder, batch, max_len,
                          cross_src=enc_out)


def decode_step(params, token, caches, index, cfg: EncDecConfig, *,
                logits_constraint=None):
    return lm.decode_step(params["decoder"], token, caches, index,
                          cfg.decoder, logits_constraint=logits_constraint)
