"""Composable model definitions (decoder LM, enc-dec, VLM) over the nn substrate."""
from repro.models import api, blocks, encdec, lm, vlm  # noqa: F401
