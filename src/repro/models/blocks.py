"""Transformer / SSM / MoE blocks and the layer-period abstraction.

A model is ``n_layers`` blocks arranged as ``repeats`` copies of a short
``period`` of heterogeneous :class:`LayerSpec`s (period 1 = plain llama;
period 2 = gemma2 local/global alternation; period 5 = llama-vision
4×self + 1×cross; zamba2 = 2×ssm + a *shared* attention block). Params for
each period position are stacked along a leading ``layers`` axis so the
whole depth lowers as one ``lax.scan`` — compile time is O(period), not
O(n_layers). Shared blocks keep a single unstacked copy applied once per
repeat (zamba-style weight sharing).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import attention as attn_mod
from repro.nn import layers, moe as moe_mod, ssm as ssm_mod


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One block in the period."""
    mixer: str = "attn"                       # attn | ssm | cross_attn
    attn: Optional[attn_mod.AttentionConfig] = None
    ssm: Optional[ssm_mod.SSMConfig] = None
    ffn: str = "mlp"                          # mlp | moe | none
    mlp: Optional[layers.MLPConfig] = None
    moe: Optional[moe_mod.MoEConfig] = None
    post_norm: bool = False                   # gemma2-style post-block norms
    gated_cross: bool = False                 # llama-vision tanh-gated cross
    cross_kv_dim: Optional[int] = None
    d_model: int = 0
    dtype: object = jnp.bfloat16


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def block_init(key, spec: LayerSpec):
    keys = jax.random.split(key, 4)
    p = {"norm1": layers.rmsnorm_init(spec.d_model)}
    if spec.mixer == "attn":
        p["mixer"] = attn_mod.attention_init(keys[0], spec.attn)
    elif spec.mixer == "cross_attn":
        p["mixer"] = attn_mod.cross_attention_init(
            keys[0], spec.attn, kv_dim=spec.cross_kv_dim)
        if spec.gated_cross:
            p["gate_attn"] = jnp.zeros((), jnp.float32)
            p["gate_ffn"] = jnp.zeros((), jnp.float32)
    elif spec.mixer == "ssm":
        p["mixer"] = ssm_mod.ssm_init(keys[0], spec.ssm)
    else:
        raise ValueError(spec.mixer)
    if spec.post_norm:
        p["norm1_post"] = layers.rmsnorm_init(spec.d_model)
    if spec.ffn != "none":
        p["norm2"] = layers.rmsnorm_init(spec.d_model)
        if spec.ffn == "mlp":
            p["ffn"] = layers.mlp_init(keys[1], spec.mlp)
        else:
            p["ffn"] = moe_mod.moe_init(keys[1], spec.moe)
        if spec.post_norm:
            p["norm2_post"] = layers.rmsnorm_init(spec.d_model)
    return p


def block_logical_specs(spec: LayerSpec):
    s = {"norm1": {"scale": ("embed",)}}
    if spec.mixer in ("attn", "cross_attn"):
        s["mixer"] = attn_mod.attention_logical_specs(spec.attn)
        if spec.mixer == "cross_attn":
            s["mixer"] = {"q": {"w": ("embed", "heads")},
                          "k": {"w": (None, "kv_heads")},
                          "v": {"w": (None, "kv_heads")},
                          "o": {"w": ("heads", "embed")}}
            if spec.gated_cross:
                s["gate_attn"] = ()
                s["gate_ffn"] = ()
    else:
        s["mixer"] = ssm_mod.ssm_logical_specs(spec.ssm)
    if spec.post_norm:
        s["norm1_post"] = {"scale": ("embed",)}
    if spec.ffn != "none":
        s["norm2"] = {"scale": ("embed",)}
        s["ffn"] = (layers.mlp_logical_specs(spec.mlp) if spec.ffn == "mlp"
                    else moe_mod.moe_logical_specs(spec.moe))
        if spec.post_norm:
            s["norm2_post"] = {"scale": ("embed",)}
    return s


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _ffn_apply(p, spec: LayerSpec, h):
    if spec.ffn == "mlp":
        return layers.mlp(p["ffn"], h, activation=spec.mlp.activation), None
    out, aux = moe_mod.moe_layer(p["ffn"], h, spec.moe)
    return out, aux


def block_apply(p, x, spec: LayerSpec, *, cross_kv=None, positions=None,
                use_flash: bool = False):
    """Returns (x, moe_aux_or_None). x: (B, T, d_model)."""
    h = layers.rmsnorm(p["norm1"], x)
    if spec.mixer == "attn":
        h = attn_mod.self_attention(p["mixer"], h, spec.attn,
                                    positions=positions, use_flash=use_flash)
    elif spec.mixer == "cross_attn":
        h = attn_mod.cross_attention(p["mixer"], h, cross_kv, spec.attn)
        if spec.gated_cross:
            h = h * jnp.tanh(p["gate_attn"]).astype(h.dtype)
    else:
        h = ssm_mod.ssm_layer(p["mixer"], h, spec.ssm)
    if spec.post_norm:
        h = layers.rmsnorm(p["norm1_post"], h)
    x = x + h
    aux = None
    if spec.ffn != "none":
        h = layers.rmsnorm(p["norm2"], x)
        h, aux = _ffn_apply(p, spec, h)
        if spec.gated_cross:
            h = h * jnp.tanh(p["gate_ffn"]).astype(h.dtype)
        if spec.post_norm:
            h = layers.rmsnorm(p["norm2_post"], h)
        x = x + h
    return x, aux


# ---------------------------------------------------------------------------
# Decode (single token, cached)
# ---------------------------------------------------------------------------
def init_block_cache(spec: LayerSpec, batch: int, max_len: int,
                     cross_kv=None):
    """Cache pytree for one block. For cross-attn blocks the cache holds the
    projected image/audio K/V (computed once here)."""
    if spec.mixer == "attn":
        window = spec.attn.sliding_window
        slots = min(max_len, window) if window else max_len
        return attn_mod.init_kv_cache(spec.attn, batch, slots)
    if spec.mixer == "ssm":
        return ssm_mod.init_ssm_cache(spec.ssm, batch)
    # cross_attn: precompute projected K/V once.
    dh = spec.attn.dh
    k = layers.linear  # noqa — projected lazily in decode when params known
    del k
    return {"src": cross_kv}


def block_cache_logical_specs(spec: LayerSpec):
    """Logical axes for one block's decode cache (parallel tree)."""
    if spec.mixer == "attn":
        return {"k": ("cache_batch", "cache_seq", "kv_heads", None),
                "v": ("cache_batch", "cache_seq", "kv_heads", None),
                "pos": ("cache_seq",)}
    if spec.mixer == "ssm":
        return {"conv": ("cache_batch", None, "mlp"),
                "state": ("cache_batch", "heads", None, None)}
    # cross_attn: precomputed K/V over the (short) modality sequence
    return {"k": ("cache_batch", None, "kv_heads", None),
            "v": ("cache_batch", None, "kv_heads", None)}


def block_decode(p, x, cache, index, spec: LayerSpec, *, cross_kv=None,
                 logits_constraint=None):
    """One-token decode. x: (B, 1, d). Returns (x, new_cache)."""
    h = layers.rmsnorm(p["norm1"], x)
    if spec.mixer == "attn":
        h, cache = attn_mod.decode_self_attention(
            p["mixer"], h, cache, index, spec.attn,
            logits_constraint=logits_constraint)
    elif spec.mixer == "cross_attn":
        src = cache["src"] if cache and "src" in cache else cross_kv
        h = attn_mod.cross_attention(p["mixer"], h, src, spec.attn)
        if spec.gated_cross:
            h = h * jnp.tanh(p["gate_attn"]).astype(h.dtype)
    else:
        h, cache = ssm_mod.ssm_decode_step(p["mixer"], h, cache, spec.ssm)
    if spec.post_norm:
        h = layers.rmsnorm(p["norm1_post"], h)
    x = x + h
    if spec.ffn != "none":
        h = layers.rmsnorm(p["norm2"], x)
        h, _ = _ffn_apply(p, spec, h)
        if spec.gated_cross:
            h = h * jnp.tanh(p["gate_ffn"]).astype(h.dtype)
        if spec.post_norm:
            h = layers.rmsnorm(p["norm2_post"], h)
        x = x + h
    return x, cache
