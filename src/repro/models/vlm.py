"""Vision-language backbone (llama-3.2-vision style).

The vision tower is a STUB per the brief: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches, vision_dim) which feed the
tanh-gated cross-attention layers interleaved in the decoder (period of
five: four self-attention blocks + one gated cross-attention block, giving
the 4:1 self:cross ratio of the released checkpoints).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import lm


def vlm_loss(params, batch, cfg: lm.ModelConfig, *, act_constraint=None):
    """batch: dict(tokens, labels, patches=(B, P, vision_dim))."""
    return lm.lm_loss(params, batch, cfg, cross_kv=batch["patches"],
                      act_constraint=act_constraint)


def init_decode_caches(params, cfg: lm.ModelConfig, patches, batch: int,
                       max_len: int):
    return lm.init_caches(params, cfg, batch, max_len,
                          cross_src=patches.astype(cfg.dtype))


decode_step = lm.decode_step
