"""Distribution layer: logical-axis sharding rules, collectives, fault tolerance."""
from repro.distributed import collectives, fault, mesh  # noqa: F401
