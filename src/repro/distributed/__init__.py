"""Distribution layer: logical-axis sharding rules, collectives, fault
tolerance, and the agent-sharded runtime substrate."""
from repro.distributed import collectives, fault, mesh, runtime  # noqa: F401
