"""Distribution layer: logical-axis sharding rules, collectives, fault
tolerance, deterministic fault injection, post-loss re-bootstrap, and
the agent-sharded runtime substrate."""
from repro.distributed import (chaos, collectives, fault, mesh,  # noqa: F401
                               recovery, runtime)
