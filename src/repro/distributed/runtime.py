"""Sharded runtime substrate for the DIALS outer loop.

Three things the agent-sharded Algorithm-1 program needs, factored out so
tests and benchmarks can use them independently of the runner:

* **mesh construction** — :func:`shard_mesh` builds the 1-D ``("shards",)``
  device mesh; :func:`choose_shards` picks the largest shard count that
  divides the agent count (the agent axis must tile exactly — DIALS has no
  notion of a fractional region).
* **agent-axis placement** — :func:`agent_sharding` /
  :func:`shard_agent_tree`: every leaf of the IALS/AIP state has leading
  axis N, so one ``PartitionSpec("shards")`` shards the whole state.
* **jaxpr auditing** — :func:`jaxpr_primitives` /
  :func:`collectives_in_jaxpr` / :func:`assert_no_collectives`: the
  paper's runtime-stays-constant claim rests on the inner program having
  ZERO cross-shard communication between AIP refreshes.  Rather than
  trusting the partitioner, we walk the jaxpr of the per-shard body
  (including every nested scan/cond/pjit sub-jaxpr) and assert that no
  collective primitive appears — the claim as an executable check.
"""
from __future__ import annotations

from typing import Iterable, Optional, Set

import jax
import jax.extend
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SHARD_AXIS = "shards"

# Cross-device communication primitives (jax.lax collectives as they appear
# in jaxprs). ``axis_index`` is deliberately absent: it reads the shard id
# without communicating.
COLLECTIVE_PRIMS: frozenset = frozenset({
    "psum", "psum2", "pmin", "pmax", "pmean", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "reduce_scatter", "psum_scatter",
    "collective_permute", "pgather", "pdot",
})

# Neighbour-only communication — what a region-decomposed GS body is
# allowed (repro.core.gs_sharded exchanges halos with ring ppermutes).
# Deliberately NOT psum_scatter/reduce_scatter: those are full
# cross-shard reductions, i.e. exactly the quiet re-centralization this
# whitelist exists to reject. Anything outside this set in a GS body
# means the "decomposed" rollout re-centralized.
HALO_PRIMS: frozenset = frozenset({
    "ppermute", "collective_permute",
})


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------
def choose_shards(n_agents: int, n_devices: Optional[int] = None) -> int:
    """Largest divisor of ``n_agents`` that is ≤ the device count."""
    if n_devices is None:
        n_devices = len(jax.devices())
    for s in range(min(n_agents, n_devices), 0, -1):
        if n_agents % s == 0:
            return s
    return 1


def shard_mesh(n_shards: Optional[int] = None, *,
               devices: Optional[Iterable] = None) -> Mesh:
    """1-D ``("shards",)`` mesh over ``n_shards`` devices.

    Single process: the first ``n_shards`` of ``jax.devices()``, as
    before. Multi-process (``jax.distributed`` initialized): the mesh
    takes ``n_shards / process_count`` devices from EVERY process, in
    process order — each host owns a contiguous block of shards, which
    is both the layout the elastic reassignment reasons about
    (:func:`shards_on_hosts`) and the one that keeps every process
    addressable in every program (a process with no devices in a
    sharding cannot even call the jit that uses it)."""
    if devices is not None:
        devices = list(devices)
        if n_shards is None:
            n_shards = len(devices)
        if n_shards > len(devices):
            raise ValueError(
                f"asked for {n_shards} shards but only "
                f"{len(devices)} devices")
        return Mesh(np.array(devices[:n_shards]), (SHARD_AXIS,))

    all_devices = jax.devices()
    nproc = jax.process_count()
    if n_shards is None:
        n_shards = len(all_devices)
    if n_shards > len(all_devices):
        raise ValueError(
            f"asked for {n_shards} shards but only "
            f"{len(all_devices)} devices")
    if nproc <= 1:
        return Mesh(np.array(all_devices[:n_shards]), (SHARD_AXIS,))
    if n_shards % nproc:
        raise ValueError(
            f"{n_shards} shards cannot be balanced over {nproc} "
            f"processes (must divide evenly)")
    per = n_shards // nproc
    by_proc: dict = {}
    for d in all_devices:
        by_proc.setdefault(d.process_index, []).append(d)
    if any(len(ds) < per for ds in by_proc.values()):
        raise ValueError(
            f"{n_shards} shards need {per} devices per process; some "
            f"process has fewer")
    chosen = [d for pid in sorted(by_proc) for d in by_proc[pid][:per]]
    return Mesh(np.array(chosen), (SHARD_AXIS,))


def mesh_hosts(mesh: Mesh) -> tuple:
    """Sorted process ids whose devices participate in ``mesh``."""
    return tuple(sorted({d.process_index for d in mesh.devices.flat}))


def mesh_spans_processes(mesh: Mesh) -> bool:
    return len(mesh_hosts(mesh)) > 1


def shards_on_hosts(mesh: Mesh, hosts) -> tuple:
    """Shard indices (positions along the ``shards`` axis) whose device
    lives on one of ``hosts`` — the work units orphaned when those hosts
    die."""
    hosts = set(hosts)
    return tuple(i for i, d in enumerate(mesh.devices.flat)
                 if d.process_index in hosts)


def surviving_devices(mesh: Mesh, dead_hosts) -> list:
    """``mesh``'s devices minus the dead hosts', in shard order."""
    dead = set(dead_hosts)
    return [d for d in mesh.devices.flat if d.process_index not in dead]


def spare_device(n_in_use: int):
    """First local device beyond the first ``n_in_use``, or None.

    The sharded runtime puts the ``("shards",)`` mesh on the first
    ``n_shards`` devices; when the machine has more, the overlapped GS
    collect (repro.distributed.async_collect) runs on the next one so it
    never contends with the shard-train program's devices.

    Multi-process: always None. The collect is a *global* program there
    — its arrays span processes and cannot be device_put onto one spare
    — so the async collector falls back to in-stream dispatch."""
    if jax.process_count() > 1:
        return None
    devices = jax.devices()
    return devices[n_in_use] if len(devices) > n_in_use else None


def shard_map_nocheck(f, mesh: Mesh, *, in_specs, out_specs):
    """Version-compat ``shard_map`` with replication checking disabled
    (the DIALS per-shard body produces sharded-only outputs). jax moved
    ``jax.experimental.shard_map`` (``check_rep=``) to ``jax.shard_map``
    (``check_vma=``); support both so the pinned floor can move freely."""
    sm = getattr(jax, "shard_map", None)
    if callable(sm):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# Agent-axis placement
# ---------------------------------------------------------------------------
def agent_sharding(mesh: Mesh) -> NamedSharding:
    """Leading-axis (agent) sharding over the shard mesh."""
    return NamedSharding(mesh, P(SHARD_AXIS))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_agent_tree(tree, mesh: Mesh):
    """Place a pytree whose every leaf has leading agent axis N onto the
    mesh, N/num_shards agents per device.

    On a single-process mesh this is a plain ``device_put``. On a mesh
    spanning processes, ``device_put`` of a host array is not legal —
    instead each process materializes ONLY the slices its local devices
    own (``jax.make_array_from_callback``), which is also the point:
    per-host data plumbing ships a host its own agents' block, never the
    global state."""
    sh = agent_sharding(mesh)
    if not mesh_spans_processes(mesh):
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    def place(x):
        if isinstance(x, jax.Array) and not x.is_fully_addressable:
            # already a global array (e.g. a replicated-GS collect
            # output): reshard in-stream instead of round-tripping
            # through the host
            return jax.jit(lambda a: a, out_shardings=sh)(x)
        x = np.asarray(x)
        return jax.make_array_from_callback(x.shape, sh,
                                            lambda idx: x[idx])
    return jax.tree.map(place, tree)


def fetch_tree(tree):
    """Bring a (possibly cross-process-sharded) pytree to host numpy.

    Single-process arrays are just ``device_get``. Arrays with
    non-addressable shards are first made fully replicated via a jit'd
    identity (an all-gather under the hood — every process ends up
    holding every agent's block), after which each process can read them
    locally. This is the mirror the elastic driver keeps so that
    surviving hosts can re-materialize a dead host's agents."""
    def fetch(x):
        if not hasattr(x, "sharding"):
            return np.asarray(x)
        if getattr(x, "is_fully_addressable", True):
            return np.asarray(jax.device_get(x))
        mesh = x.sharding.mesh
        rep = jax.jit(lambda a: a,
                      out_shardings=NamedSharding(mesh, P()))(x)
        return np.asarray(jax.device_get(rep))
    return jax.tree.map(fetch, tree)


def local_slice_struct(tree, n_shards: int):
    """ShapeDtypeStructs of one shard's slice of an agent-stacked tree —
    what the per-shard body of a ``shard_map`` actually sees."""
    def one(x):
        n = x.shape[0]
        if n % n_shards:
            raise ValueError(
                f"agent axis {n} not divisible by {n_shards} shards")
        return jax.ShapeDtypeStruct((n // n_shards,) + tuple(x.shape[1:]),
                                    x.dtype)
    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Jaxpr auditing — thin compatibility surface over repro.analysis.walker
# (the path-aware traversal with source provenance; pallas_call kernel
# bodies are walked explicitly there, which the old generic param scan
# left to luck)
# ---------------------------------------------------------------------------
def _sub_jaxprs(eqn):
    from repro.analysis import walker
    for _label, sub in walker.sub_jaxprs(eqn):
        yield sub


def jaxpr_primitives(jaxpr) -> Set[str]:
    """All primitive names in a (Closed)Jaxpr, recursing into every
    nested sub-jaxpr — scan/while/cond/pjit/custom_* AND ``pallas_call``
    kernel bodies (``repro.analysis.walker`` owns the traversal)."""
    from repro.analysis import walker
    return walker.primitives(jaxpr)


def collectives_in_jaxpr(jaxpr) -> Set[str]:
    return jaxpr_primitives(jaxpr) & COLLECTIVE_PRIMS


def find_shard_map_jaxprs(jaxpr):
    """The body jaxprs of every ``shard_map`` eqn in a traced program
    (recursing through nested sub-jaxprs). Auditing these — extracted
    from the REAL program rather than traced separately — is what ties
    the no-collectives assertion to the code that actually runs."""
    from repro.analysis import walker
    jaxpr = walker.raw_jaxpr(jaxpr)
    found = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "shard_map":
            body = eqn.params.get("jaxpr")
            if body is not None:
                found.append(body)
        for _label, sub in walker.sub_jaxprs(eqn):
            found.extend(find_shard_map_jaxprs(sub))
    return found


def _collective_sites(jaxpr):
    from repro.analysis import walker
    return walker.sites(jaxpr, COLLECTIVE_PRIMS)


def _describe_sites(sites) -> str:
    return "; ".join(s.describe() for s in sites)


def assert_no_collectives(jaxpr, *, what: str = "program") -> None:
    """Raise if any cross-shard collective appears anywhere in
    ``jaxpr`` — naming each occurrence's source line and jaxpr path."""
    sites = _collective_sites(jaxpr)
    if sites:
        raise AssertionError(
            f"{what} must be collective-free between AIP refreshes but "
            f"contains {sorted({s.prim for s in sites})}: "
            f"{_describe_sites(sites)}")


def assert_only_halo_collectives(jaxpr, *, what: str = "GS body") -> None:
    """Raise unless every collective in ``jaxpr`` is a halo exchange
    (``HALO_PRIMS``) and at least one is present — a region-decomposed
    GS body must talk to its ring neighbours and to nobody else."""
    sites = _collective_sites(jaxpr)
    extra = [s for s in sites if s.prim not in HALO_PRIMS]
    if extra:
        raise AssertionError(
            f"{what} may contain only halo-exchange collectives "
            f"{sorted(HALO_PRIMS)} but also has "
            f"{sorted({s.prim for s in extra})}: "
            f"{_describe_sites(extra)}")
    if not sites:
        raise AssertionError(
            f"{what} contains no halo exchange at all — it is not the "
            f"region-decomposed GS program")


def live_collective_prims() -> Set[str]:
    """Collective primitive names registered by the *running* jax (from
    ``jax.lax``'s parallel-operator module), minus ``axis_index`` (reads
    the shard id without communicating). The frozen tables above must
    cover these — :func:`validate_collective_tables`."""
    from jax._src.lax import parallel
    live = {
        p.name for p in vars(parallel).values()
        if isinstance(p, jax.extend.core.Primitive)
    }
    return live - {"axis_index"}


def validate_collective_tables() -> None:
    """Raise if the frozen ``COLLECTIVE_PRIMS``/``HALO_PRIMS`` tables
    rotted against the running jax: every live collective primitive must
    be classified (else an upgrade could add a collective the audits
    silently wave through), and the halo whitelist must stay a strict
    subset of the collective set."""
    live = live_collective_prims()
    missing = live - COLLECTIVE_PRIMS
    if missing:
        raise AssertionError(
            f"COLLECTIVE_PRIMS is missing live jax collective "
            f"primitives {sorted(missing)} — the no-collectives audit "
            f"would not see them; add them to the table")
    if not HALO_PRIMS <= COLLECTIVE_PRIMS:
        raise AssertionError(
            f"HALO_PRIMS {sorted(HALO_PRIMS - COLLECTIVE_PRIMS)} not in "
            f"COLLECTIVE_PRIMS — the halo whitelist must be a subset of "
            f"the collective set")
