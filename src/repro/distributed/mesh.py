"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Model code annotates parameters with *logical* axis names
(``("embed", "mlp")``); this module resolves them against a rule table for
the current mesh, with two production-grade details:

* **divisibility fallback** — a logical axis only binds to a mesh axis if
  the dimension size divides the axis size; otherwise it falls back to the
  next rule (or replication). E.g. ``kv_heads=8`` cannot shard over
  ``model=16`` as a cache dimension, but the *flattened* projection dim
  (kv_heads·head_dim) can.
* **FSDP residual sharding** — after rule application, parameters are
  additionally sharded over the (pod, data) axes on their largest free
  dimension (ZeRO-3 style), so per-device parameter + optimizer memory
  scales down with the full mesh, not just the model axis.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Tuple[Tuple[str, Union[str, Tuple[str, ...], None]], ...]

# Training-time rules. Order matters: first applicable rule wins.
TRAIN_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("embed", None),
    ("layers", None),
    ("seq", None),
    ("cache_batch", ("pod", "data")),
    ("cache_seq", None),
)

# Decode rules (decode_32k). KV caches are the dominant bytes: batch over
# (pod, data); the cache sequence axis takes the model axis (kv_heads are
# usually 4–8 and cannot split 16 ways — the divisibility fallback then
# leaves "model" free, so cache_seq claims it and attention reduces over
# the sharded key axis with a small psum).
DECODE_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("cache_batch", ("pod", "data")),
    ("cache_seq", "model"),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("embed", None),
    ("layers", None),
    ("seq", None),
)

# long_500k (global_batch=1): the KV/attention cache sequence axis is the
# only large axis — shard it over `data`.
LONG_CONTEXT_RULES: Rules = (
    ("batch", None),
    ("cache_batch", None),
    ("cache_seq", "data"),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("embed", None),
    ("layers", None),
    ("seq", None),
)


def _axis_size(mesh: Mesh, axes: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(logical: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: Rules) -> P:
    """Map one logical-axis tuple to a PartitionSpec, respecting
    divisibility and never using a mesh axis twice."""
    used: set = set()
    out = []
    rule_map = {}
    for name, target in rules:
        rule_map.setdefault(name, target)
    for dim, name in zip(shape, logical):
        target = rule_map.get(name) if name else None
        if target is None:
            out.append(None)
            continue
        taxes = (target,) if isinstance(target, str) else tuple(target)
        taxes = tuple(a for a in taxes if a in mesh.shape and a not in used)
        if not taxes or dim % _axis_size(mesh, taxes) != 0:
            # try single-axis prefixes before giving up
            ok = None
            for k in range(len(taxes), 0, -1):
                sub = taxes[:k]
                if sub and dim % _axis_size(mesh, sub) == 0:
                    ok = sub
                    break
            taxes = ok or ()
        if taxes:
            used.update(taxes)
            out.append(taxes if len(taxes) > 1 else taxes[0])
        else:
            out.append(None)
    return P(*out)


def _fsdp_augment(spec: P, shape: Sequence[int], mesh: Mesh,
                  fsdp_axes: Tuple[str, ...]) -> P:
    """Shard the largest unsharded dim over the unused fsdp axes."""
    used = set()
    for s in spec:
        if s is None:
            continue
        used.update((s,) if isinstance(s, str) else s)
    free = tuple(a for a in fsdp_axes if a in mesh.shape and a not in used)
    if not free:
        return spec
    size = _axis_size(mesh, free)
    # largest dim, prefer trailing, must divide
    best, best_dim = None, 0
    for i, (dim, s) in enumerate(zip(shape, spec)):
        if s is None and dim % size == 0 and dim >= best_dim and dim >= size:
            best, best_dim = i, dim
    if best is None:
        return spec
    new = list(spec)
    new[best] = free if len(free) > 1 else free[0]
    return P(*new)


def logical_to_sharding(spec_tree, shape_tree, mesh: Mesh, *,
                        rules: Rules = TRAIN_RULES,
                        fsdp_axes: Tuple[str, ...] = ()):
    """Resolve a logical-spec tree (parallel to a params/cache tree whose
    leaves are arrays or ShapeDtypeStructs) into NamedShardings."""
    def is_spec(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    def one(logical, leaf):
        shape = leaf.shape
        logical = tuple(logical)
        if len(logical) < len(shape):          # scalar/under-specified
            logical = logical + (None,) * (len(shape) - len(logical))
        spec = resolve_spec(logical[:len(shape)], shape, mesh, rules)
        if fsdp_axes:
            spec = _fsdp_augment(spec, shape, mesh, fsdp_axes)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: is_spec(x) or x == ())


def batch_spec(mesh: Mesh, *, long_context: bool = False) -> P:
    if long_context:
        return P(None)
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0])


def batch_sharding(mesh: Mesh, **kw) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, **kw))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
