"""Standalone (external) coordination service for fault-tolerant groups.

``jax.distributed`` hosts its coordination service inside rank 0's
process. For fault tolerance that placement is fatal by construction:
when rank 0 dies, every survivor's error-polling RPC to the service
breaks instantly and the client's native reaction terminates the
survivor — *before* any Python-level recovery can run, and uninterceptably
(the fatal fires in a native thread; jaxlib cannot cast the failure
status into a Python callback). The survivable topology is a
coordination service that is not hosted by any worker:

    python -m repro.distributed.coordinator --bind 127.0.0.1:5432 \\
        --num-processes 2 --ready-file /tmp/coord.ready &

    DIALS_COORDINATOR=127.0.0.1:5432 DIALS_COORDINATOR_EXTERNAL=1 \\
        <launch workers as usual>

With ``DIALS_COORDINATOR_EXTERNAL`` set (and a
``peer_death_grace_s``-enabled bootstrap), rank 0 skips in-process
service creation and connects like every other rank; any worker —
including rank 0 — can then die without collapsing the others'
coordination channel. The service's own missed-heartbeat reaction is
stretched by the same grace window, so the recovery supervisor
(``repro.distributed.recovery``) owns the timeline.

The process serves until SIGTERM/SIGINT (or ``--timeout-s``);
``--ready-file`` is written (atomically) once the service is listening
so launchers can sequence worker startup without polling the port.
"""
from __future__ import annotations

import argparse
import os
import signal
import threading
from typing import Optional

from repro.distributed import bootstrap


def serve(bind: str, num_processes: int, *, grace_s: float = 600.0,
          ready_file: Optional[str] = None,
          stop: Optional[threading.Event] = None,
          timeout_s: Optional[float] = None) -> None:
    """Run the coordination service until ``stop`` is set (or
    ``timeout_s`` elapses). Blocks the calling thread."""
    from jax._src.lib import xla_extension
    gk = bootstrap.grace_kwargs(grace_s)
    service = xla_extension.get_distributed_runtime_service(
        bind, num_processes,
        heartbeat_interval=gk["service_heartbeat_interval_seconds"],
        max_missing_heartbeats=gk["service_max_missing_heartbeats"])
    try:
        if ready_file:
            tmp = ready_file + ".tmp"
            with open(tmp, "w") as f:
                f.write(bind)
            os.replace(tmp, ready_file)
        if stop is None:
            stop = threading.Event()
        stop.wait(timeout_s)
    finally:
        service.shutdown()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="external jax.distributed coordination service")
    ap.add_argument("--bind", required=True,
                    help="host:port to serve on (workers' "
                         "DIALS_COORDINATOR must point here)")
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--grace-s", type=float, default=600.0,
                    help="missed-heartbeat window before the service "
                         "declares a silent worker dead")
    ap.add_argument("--ready-file", default=None,
                    help="written once the service is listening")
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="exit after this long even without a signal")
    args = ap.parse_args(argv)

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())
    serve(args.bind, args.num_processes, grace_s=args.grace_s,
          ready_file=args.ready_file, stop=stop, timeout_s=args.timeout_s)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
