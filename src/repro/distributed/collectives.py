"""Collective helpers used inside shard_map'd programs.

These are thin, named wrappers over ``jax.lax`` collectives so higher
layers (DIALS runner, outer optimizer, gradient compression) read like the
paper's pseudocode. All take an ``axis_name`` bound by the enclosing
``shard_map``/``pmap``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_pmean(tree, axis_name: str):
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), tree)


def tree_psum(tree, axis_name: str):
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def tree_all_gather(tree, axis_name: str, *, axis: int = 0, tiled=True):
    return jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled),
        tree)


def tree_psum_scatter(tree, axis_name: str, *, axis: int = 0):
    """Reduce-scatter: each shard ends with its slice of the sum — half the
    bytes of an all-reduce when the consumer is itself sharded (ZeRO grads)."""
    return jax.tree.map(
        lambda x: jax.lax.psum_scatter(x, axis_name, scatter_dimension=axis,
                                       tiled=True), tree)


def ppermute_ring(x, axis_name: str, *, shift: int = 1):
    """Ring shift (used by the ring-attention long-context variant)."""
    n = jax.lax.axis_size(axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name, perm)


def pbroadcast(x, axis_name: str, root: int = 0):
    """Broadcast ``x`` from shard ``root`` to every shard along
    ``axis_name``: zero the value everywhere off-root, then ``psum`` — one
    all-reduce, the standard root-broadcast under SPMD (no point-to-point
    send primitive exists at the lax level)."""
    idx = jax.lax.axis_index(axis_name)

    def one(a):
        a = jnp.asarray(a)
        calc = a.astype(jnp.float32) if a.dtype == jnp.bool_ else a
        masked = jnp.where(idx == root, calc, jnp.zeros_like(calc))
        return jax.lax.psum(masked, axis_name).astype(a.dtype)

    return jax.tree.map(one, x)
