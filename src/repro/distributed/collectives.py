"""Collective helpers used inside shard_map'd programs.

These are thin, named wrappers over ``jax.lax`` collectives so higher
layers (DIALS runner, outer optimizer, gradient compression) read like the
paper's pseudocode. All take an ``axis_name`` bound by the enclosing
``shard_map``/``pmap``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_pmean(tree, axis_name: str):
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), tree)


def tree_psum(tree, axis_name: str):
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def tree_all_gather(tree, axis_name: str, *, axis: int = 0, tiled=True):
    return jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled),
        tree)


def tree_psum_scatter(tree, axis_name: str, *, axis: int = 0):
    """Reduce-scatter: each shard ends with its slice of the sum — half the
    bytes of an all-reduce when the consumer is itself sharded (ZeRO grads)."""
    return jax.tree.map(
        lambda x: jax.lax.psum_scatter(x, axis_name, scatter_dimension=axis,
                                       tiled=True), tree)


def ppermute_ring(x, axis_name: str, *, shift: int = 1, axis_size: int):
    """Ring shift (the sharded-GS halo primitive). The permutation is a
    static list, so the caller must supply the axis size — the pinned
    jax floor predates ``jax.lax.axis_size``, and every caller (the
    shard_map builders) knows its mesh size statically anyway."""
    perm = [(i, (i + shift) % axis_size) for i in range(axis_size)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange(tree, axis_name: str, *, axis_size: int):
    """The one communication of the region-decomposed GS step: every
    shard sends its whole payload (boundary states + actions) one hop
    around the block ring in both directions and receives its two
    neighbours'. Returns ``(prev, next)`` — the payloads of blocks b-1
    and b+1 (mod n) — as two ring ``ppermute``s per leaf; nothing else
    (no psum/all_gather) may appear in a sharded-GS body, which is what
    ``repro.distributed.runtime.assert_only_halo_collectives`` audits."""
    prev = jax.tree.map(
        lambda x: ppermute_ring(x, axis_name, shift=1,
                                axis_size=axis_size), tree)
    nxt = jax.tree.map(
        lambda x: ppermute_ring(x, axis_name, shift=-1,
                                axis_size=axis_size), tree)
    return prev, nxt


def pbroadcast(x, axis_name: str, root: int = 0):
    """Broadcast ``x`` from shard ``root`` to every shard along
    ``axis_name``: zero the value everywhere off-root, then ``psum`` — one
    all-reduce, the standard root-broadcast under SPMD (no point-to-point
    send primitive exists at the lax level)."""
    idx = jax.lax.axis_index(axis_name)

    def one(a):
        a = jnp.asarray(a)
        calc = a.astype(jnp.float32) if a.dtype == jnp.bool_ else a
        masked = jnp.where(idx == root, calc, jnp.zeros_like(calc))
        return jax.lax.psum(masked, axis_name).astype(a.dtype)

    return jax.tree.map(one, x)
