"""Multi-host bootstrap — ``jax.distributed`` initialization for DIALS.

Everything above this layer (the sharded runner, the region-decomposed
GS, the benchmarks) is written against a *global* device mesh; the only
thing standing between the single-process ``("shards",)`` mesh and a
real multi-host one is process coordination. This module owns it:

* :func:`config_from_env` / :func:`add_arguments` — one process-group
  contract (coordinator address, process count, process id, optional
  forced host-device count) readable from env vars or CLI flags, so a
  launcher (``benchmarks/scaling.py --processes N``,
  ``launch.variants.launch_group``, SLURM wrappers) and the launched
  process agree by construction.
* :func:`bootstrap` — the one call a process makes before touching any
  device: applies the forced host-device count to ``XLA_FLAGS`` (must
  happen before the backend initializes), selects the gloo CPU
  collectives implementation (cross-process ``ppermute``/``psum`` on
  CPU hosts — the halo exchange of the sharded GS rides on it), and
  calls ``jax.distributed.initialize``. A process with no group config
  gets a valid single-process :class:`DistContext` back — every caller
  can bootstrap unconditionally.

Env vars (the ``DIALS_`` namespace, mirrored by the CLI flags):

``DIALS_COORDINATOR``     host:port of process 0's coordination service
``DIALS_NUM_PROCESSES``   total process count
``DIALS_PROCESS_ID``      this process's id in [0, num_processes)
``DIALS_LOCAL_DEVICES``   optional: force this many host CPU devices
                          (``--xla_force_host_platform_device_count``)
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional

ENV_COORDINATOR = "DIALS_COORDINATOR"
ENV_NUM_PROCESSES = "DIALS_NUM_PROCESSES"
ENV_PROCESS_ID = "DIALS_PROCESS_ID"
ENV_LOCAL_DEVICES = "DIALS_LOCAL_DEVICES"
# truthy: the coordination service at DIALS_COORDINATOR is an external
# process (repro.distributed.coordinator) — rank 0 must NOT host one
ENV_COORDINATOR_EXTERNAL = "DIALS_COORDINATOR_EXTERNAL"

_FORCE_FLAG = "--xla_force_host_platform_device_count"


@dataclasses.dataclass(frozen=True)
class BootstrapConfig:
    """The process-group contract a coordinated process starts from."""
    coordinator: str
    num_processes: int
    process_id: int
    local_devices: Optional[int] = None

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError(f"num_processes must be >= 1, "
                             f"got {self.num_processes}")
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(
                f"process_id {self.process_id} outside "
                f"[0, {self.num_processes})")

    def env(self) -> dict:
        """The env-var block that reproduces this config in a child
        process (the launcher side of the contract)."""
        out = {ENV_COORDINATOR: self.coordinator,
               ENV_NUM_PROCESSES: str(self.num_processes),
               ENV_PROCESS_ID: str(self.process_id)}
        if self.local_devices is not None:
            out[ENV_LOCAL_DEVICES] = str(self.local_devices)
        return out


@dataclasses.dataclass(frozen=True)
class DistContext:
    """What :func:`bootstrap` hands back: where this process sits."""
    process_id: int
    num_processes: int
    coordinator: Optional[str]
    initialized: bool            # did jax.distributed.initialize run?

    @property
    def is_primary(self) -> bool:
        return self.process_id == 0

    @property
    def distributed(self) -> bool:
        return self.num_processes > 1


def config_from_env(
        environ: Mapping[str, str] = os.environ) -> Optional[BootstrapConfig]:
    """The env-var side of the contract; None when no group is declared
    (single-process run). Partial declarations are an error — a process
    that was *meant* to join a group must never silently run solo."""
    keys = (ENV_COORDINATOR, ENV_NUM_PROCESSES, ENV_PROCESS_ID)
    present = [k for k in keys if environ.get(k)]
    if not present:
        return None
    missing = [k for k in keys if not environ.get(k)]
    if missing:
        raise ValueError(
            f"incomplete multi-host declaration: {present} set "
            f"but {missing} missing")
    local = environ.get(ENV_LOCAL_DEVICES)
    return BootstrapConfig(
        coordinator=environ[ENV_COORDINATOR],
        num_processes=int(environ[ENV_NUM_PROCESSES]),
        process_id=int(environ[ENV_PROCESS_ID]),
        local_devices=int(local) if local else None)


def add_arguments(parser) -> None:
    """CLI flags mirroring the env vars (flags win where both are set)."""
    parser.add_argument("--coordinator", default=None,
                        help="host:port of process 0's jax.distributed "
                             f"coordination service (or ${ENV_COORDINATOR})")
    parser.add_argument("--num-processes", type=int, default=None,
                        help=f"total process count (or ${ENV_NUM_PROCESSES})")
    parser.add_argument("--process-id", type=int, default=None,
                        help=f"this process's id (or ${ENV_PROCESS_ID})")
    parser.add_argument("--local-devices", type=int, default=None,
                        help="force this many host CPU devices "
                             f"(or ${ENV_LOCAL_DEVICES})")


def config_from_args(args, environ: Mapping[str, str] = os.environ
                     ) -> Optional[BootstrapConfig]:
    """Resolve :func:`add_arguments` flags over the env (CLI wins
    field-wise)."""
    base = config_from_env(environ)
    fields = {"coordinator": args.coordinator,
              "num_processes": args.num_processes,
              "process_id": args.process_id,
              "local_devices": args.local_devices}
    if all(v is None for v in fields.values()):
        return base
    merged = dataclasses.asdict(base) if base is not None else {
        "coordinator": None, "num_processes": 1, "process_id": 0,
        "local_devices": None}
    merged.update({k: v for k, v in fields.items() if v is not None})
    if merged["coordinator"] is None and merged["num_processes"] > 1:
        raise ValueError("--num-processes > 1 requires --coordinator")
    if merged["coordinator"] is None:
        # device forcing without a group: still useful (single-process
        # mesh emulation), handled below without initialize()
        return BootstrapConfig(coordinator="", num_processes=1,
                               process_id=0,
                               local_devices=merged["local_devices"])
    return BootstrapConfig(**merged)


def force_host_devices(n: int, environ=os.environ) -> None:
    """Append the forced-host-device XLA flag. Must run before the jax
    backend initializes (importing jax is fine; creating arrays is not)."""
    flags = environ.get("XLA_FLAGS", "")
    if _FORCE_FLAG in flags:
        return
    environ["XLA_FLAGS"] = f"{flags} {_FORCE_FLAG}={n}".strip()


_HEARTBEAT_INTERVAL_S = 10           # jax's own default, kept explicit


def grace_kwargs(grace_s: float) -> dict:
    """The coordination-service heartbeat kwargs that give a surviving
    process ``grace_s`` seconds after a peer dies before the service's
    missed-heartbeat reaction (terminate the survivors) can fire."""
    misses = max(2, -(-int(grace_s) // _HEARTBEAT_INTERVAL_S))
    return {"service_heartbeat_interval_seconds": _HEARTBEAT_INTERVAL_S,
            "service_max_missing_heartbeats": misses,
            "client_heartbeat_interval_seconds": _HEARTBEAT_INTERVAL_S,
            "client_max_missing_heartbeats": misses}


def _initialize_with_grace(cfg: BootstrapConfig, grace_s: float,
                           kwargs: dict, *,
                           environ: Mapping[str, str] = os.environ) -> bool:
    """``jax.distributed.initialize`` with stretched heartbeat windows
    and optional external-coordinator support.

    The public wrapper hides the heartbeat knobs; the defaults
    *terminate the survivors* when a peer dies — after the
    missed-heartbeat window in general, and INSTANTLY when the dead
    peer was rank 0, because the coordination service lives inside
    rank 0's process and every survivor's error-polling RPC breaks with
    it (the fatal fires in a native thread; a Python
    ``missed_heartbeat_callback`` cannot intercept it — jaxlib's
    nanobind cast of a non-OK status into Python throws and
    ``std::terminate``s). So a recovery supervisor needs two things:
    stretched windows (this function) and, to survive a *coordinator*
    death, a coordination service that is not hosted by any worker
    (``repro.distributed.coordinator`` + ``DIALS_COORDINATOR_EXTERNAL``
    — then rank 0 skips in-process service creation and merely connects
    like everyone else).

    Replicates the internal ``global_state.initialize`` group path
    (stable across jax 0.4.x) because the heartbeat kwargs and the
    skip-service choice are invisible to the public API; returns False
    when this jax build doesn't expose the internals so the caller can
    fall back to the public API (no grace, but functional)."""
    try:
        from jax._src import distributed as _jax_distributed
        from jax._src import xla_bridge as _xla_bridge
        from jax._src.lib import xla_extension as _xla_extension
        if _xla_bridge.backends_are_initialized():
            raise RuntimeError(
                "jax.distributed must initialize before any computation")
        state = _jax_distributed.global_state
        if state.client is not None:
            raise RuntimeError("jax.distributed already initialized")
        gk = grace_kwargs(grace_s)
        external = environ.get(ENV_COORDINATOR_EXTERNAL, "") not in ("", "0")
        if cfg.process_id == 0 and not external:
            bind = "[::]:" + cfg.coordinator.rsplit(":", 1)[1]
            state.service = _xla_extension.get_distributed_runtime_service(
                bind, cfg.num_processes,
                heartbeat_interval=gk["service_heartbeat_interval_seconds"],
                max_missing_heartbeats=gk["service_max_missing_heartbeats"])
        client = _xla_extension.get_distributed_runtime_client(
            cfg.coordinator, cfg.process_id,
            init_timeout=kwargs.get("initialization_timeout", 300),
            heartbeat_interval=gk["client_heartbeat_interval_seconds"],
            max_missing_heartbeats=gk["client_max_missing_heartbeats"],
            use_compression=True)
        client.connect()
        state.client = client
        state.process_id = cfg.process_id
        state.num_processes = cfg.num_processes
        state.coordinator_address = cfg.coordinator
        state.initialize_preemption_sync_manager()
        return True
    except (ImportError, AttributeError, TypeError):
        return False


def bootstrap(cfg: Optional[BootstrapConfig] = None, *,
              environ: Mapping[str, str] = os.environ,
              init_timeout_s: Optional[float] = None,
              peer_death_grace_s: Optional[float] = None) -> DistContext:
    """Initialize this process's place in the (possibly 1-process) group.

    Call once, before any jax device use. Idempotent for the
    single-process case; a second distributed call raises (jax owns that
    invariant). Order matters inside: XLA flags first (backend reads
    them at first device query), the gloo CPU-collectives selection
    second (cross-process collectives on CPU need a real transport —
    without it the first halo exchange dies inside XLA), initialize
    last. ``init_timeout_s`` bounds how long initialize blocks waiting
    for peers (jax's default is ~300s) — the recovery supervisor's
    bounded-retry re-bootstrap needs a short, known bound.
    ``peer_death_grace_s`` stretches the coordination service's
    missed-heartbeat windows so it cannot terminate a surviving process
    while a recovery supervisor is still reacting to the loss.
    """
    if cfg is None:
        cfg = config_from_env(environ)
    if cfg is not None and cfg.local_devices is not None:
        force_host_devices(cfg.local_devices, environ=os.environ)
    if cfg is None or cfg.num_processes <= 1:
        return DistContext(process_id=0, num_processes=1, coordinator=None,
                           initialized=False)

    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):     # non-CPU build / renamed knob
        pass
    kwargs = {}
    if init_timeout_s is not None:
        kwargs["initialization_timeout"] = int(init_timeout_s)
    if (peer_death_grace_s is None
            or not _initialize_with_grace(cfg, peer_death_grace_s, kwargs,
                                          environ=environ)):
        jax.distributed.initialize(coordinator_address=cfg.coordinator,
                                   num_processes=cfg.num_processes,
                                   process_id=cfg.process_id, **kwargs)
    return DistContext(process_id=jax.process_index(),
                       num_processes=jax.process_count(),
                       coordinator=cfg.coordinator, initialized=True)
