"""Post-loss re-bootstrap: survive a host death by *restarting the
process group*, not just shrinking the mesh.

PR 6's elastic path keeps training on the remnant mesh of the surviving
process group — which works only until the next cross-process collective
needs the dead host, and leaves ``jax.process_count()`` lying about the
world. The honest recovery, measured against jax 0.4.x on CPU/gloo, has
three hard constraints this module is built around:

1. ``jax.distributed.shutdown()`` **hangs** when a peer is dead (the
   coordination service waits out its ~100 s error-propagation window) —
   so teardown runs on a daemon thread with a bounded join and is
   abandoned on timeout.
2. ``jax.distributed.initialize()`` **cannot be called again** in a
   process that has executed any jax computation — so the surviving
   process re-executes itself (``os.execv``) with the shrunken group's
   ``DIALS_*`` env, and the fresh interpreter bootstraps normally.
3. The dying group's collectives are unusable — so survivor state is
   *not* migrated over the mesh; it comes from the last committed
   distributed checkpoint, including a commit-takeover
   (:meth:`~repro.checkpoint.distributed.DistributedCheckpointManager.
   finalize_pending`) when rank 0 died between prepare and commit.

Flow: the driver's ``heartbeats`` hook raises :class:`HostLossDetected`
out of ``DIALSTrainer.run`` (see :func:`raising_gate`); the worker's
``except`` arm calls :func:`recover` — finalize pending commit →
timeout-guarded teardown → :func:`shrink_config` (survivor re-ranking,
coordinator failover to the lowest surviving rank, port bumped by
generation) → :func:`reexec`. The re-executed process sees
``DIALS_RECOVERY_GENERATION`` ≥ 1, bootstraps via
:func:`bootstrap_with_retry` (bounded retries, exponential backoff,
short initialize timeout), emits a ``rebootstrap`` telemetry event, and
resumes ``run()`` from the committed checkpoint.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import threading
import time
from typing import Mapping, Optional, Sequence, Tuple

from repro import obs
from repro.distributed import bootstrap

ENV_GENERATION = "DIALS_RECOVERY_GENERATION"


class HostLossDetected(RuntimeError):
    """Raised out of the driver's heartbeat gate when the HostMonitor
    declares peers dead — carries what the supervisor needs."""

    def __init__(self, round: int, dead: Sequence[int]):
        super().__init__(f"host(s) {sorted(dead)} lost at round {round}")
        self.round = int(round)
        self.dead = tuple(sorted(dead))


def raising_gate(monitor):
    """A ``heartbeats`` callback for ``DIALSTrainer.run`` that converts
    a death verdict into :class:`HostLossDetected` instead of handing
    back a shrunken remnant mesh — the re-bootstrap path's entry.
    Remembers the last gated round (``gate.round``) and its monitor
    (``gate.monitor``) so :func:`diagnose` can hold a post-mortem after
    a mid-round collective failure."""
    def gate(rnd: int):
        gate.round = max(gate.round, int(rnd))
        dead = monitor.gate(rnd)
        if dead:
            raise HostLossDetected(rnd, dead)
        return ()
    gate.round = 0
    gate.monitor = monitor
    return gate


_PEER_FAILURE_MARKERS = (
    # gloo transport errors surface as XlaRuntimeError text when a
    # dead peer's TCP connection drops mid-collective
    "connection reset by peer",
    "connection refused",
    "connection closed by peer",
    "socket closed",
    "broken pipe",
    # coordination-service verdicts about a lost task
    "heartbeat timeout",
    "coordinationservice",
    "gloo collective",
)


def is_peer_failure(err: BaseException) -> bool:
    """Does this error read like a dead peer rather than a program bug?
    Marker matching is the only option: gloo and the coordination
    service both surface through ``XlaRuntimeError`` with no stable
    error class."""
    text = f"{type(err).__name__}: {err}".lower()
    return any(m in text for m in _PEER_FAILURE_MARKERS)


def diagnose(err: BaseException, gate, *, telemetry=obs.DISABLED
             ) -> HostLossDetected:
    """Post-mortem for an exception that escaped the training loop: a
    host death *between* rounds raises :class:`HostLossDetected` at the
    gate, but a death *mid-round* surfaces first as a failed collective
    (gloo connection reset inside an ``XlaRuntimeError``) — the gate
    never ran. When the error reads like a peer failure, ask the
    heartbeat monitor for the verdict: every survivor runs this same
    protocol and beats ``gate.round + 1``, while the dead peer never
    will. Returns the loss to hand to :func:`recover`; re-raises ``err``
    when it isn't a peer failure or every peer turns out to be alive
    (a real program error must stay fatal)."""
    if isinstance(err, HostLossDetected):
        return err
    if gate is None or getattr(gate, "monitor", None) is None \
            or not is_peer_failure(err):
        raise err
    rnd = gate.round + 1
    telemetry.emit("collective_failure", round=rnd - 1,
                   error=repr(err)[:500])
    try:
        gate(rnd)
    except HostLossDetected as loss:
        return loss
    raise err                        # everyone beat: not a host loss


class Deadman:
    """Liveness watchdog for the deaths the round protocol cannot see.

    Both in-band detectors need the MAIN thread back in Python: the
    heartbeat gate runs between rounds, and :func:`diagnose` runs after
    a collective *errors*. But a peer that dies mid-collective can
    leave the survivor wedged in a native wait that never errors — the
    recv side of a half-open TCP connection sees no RST, so XLA blocks
    forever, and the coordination service's eventual missed-heartbeat
    verdict *terminates* the survivor instead of waking it. The deadman
    is the out-of-band answer:

    * a **pulse** thread touches ``live-{host}`` in the shared beat
      directory every ``interval_s``, independent of round progress
      (native collectives release the GIL, so the pulse keeps running
      while the main thread is stuck);
    * a **watch** thread declares any peer whose pulse has been silent
      for ``silence_s`` dead and hands a :class:`HostLossDetected` to
      ``on_loss`` — typically a closure over :func:`recover`, which is
      safe to run from this thread because ``os.execv`` replaces the
      whole process, wedged threads included.

    ``silence_s`` must sit between the longest legitimate pulse gap
    (scheduler jitter, seconds) and the bootstrap's
    ``peer_death_grace_s`` (the coordination service's own fuse). The
    :meth:`claim` latch keeps the watchdog and a healthy main-thread
    recovery path from both acting: whoever claims first recovers, the
    other parks. Staleness is judged by file mtime, so all hosts must
    share a filesystem clock (same box, or NFS with sane time sync) —
    the same assumption ``HostMonitor`` already makes.
    """

    def __init__(self, directory: str, *, host: int, n_hosts: int,
                 on_loss, current_round=lambda: 0,
                 interval_s: float = 2.0, silence_s: float = 60.0,
                 telemetry=obs.DISABLED):
        self.directory = directory
        self.host = int(host)
        self.n_hosts = int(n_hosts)
        self.on_loss = on_loss
        self.current_round = current_round
        self.interval_s = float(interval_s)
        self.silence_s = float(silence_s)
        self.telemetry = telemetry
        self._stop = threading.Event()
        self._latch = threading.Lock()
        self._threads = []
        self._born = time.time()
        os.makedirs(directory, exist_ok=True)

    def _live_path(self, host: int) -> str:
        return os.path.join(self.directory, f"live-{host}")

    def _pulse(self) -> None:
        path = self._live_path(self.host)
        while not self._stop.is_set():
            with open(path, "w") as f:
                f.write(str(time.time()))
            self._stop.wait(self.interval_s)

    def silent_peers(self) -> Tuple[int, ...]:
        """Peers whose pulse is ``silence_s`` stale. A peer that never
        pulsed SINCE THIS WATCHDOG WAS BORN is not silent: either it is
        still bootstrapping (the init timeout's failure mode, not ours)
        or the file is a leftover from a previous generation — the beat
        directory survives execv, and re-ranked host ids alias old
        ones."""
        now = time.time()
        dead = []
        for h in range(self.n_hosts):
            if h == self.host:
                continue
            try:
                mtime = os.stat(self._live_path(h)).st_mtime
            except OSError:
                continue
            if mtime >= self._born and now - mtime > self.silence_s:
                dead.append(h)
        return tuple(dead)

    def _watch(self) -> None:
        while not self._stop.wait(self.interval_s):
            dead = self.silent_peers()
            if not dead or not self.claim():
                continue
            rnd = int(self.current_round())
            self.telemetry.emit("host_death", round=rnd,
                                dead_hosts=list(dead),
                                all_dead=list(dead),
                                detector="deadman",
                                silence_s=self.silence_s)
            self.on_loss(HostLossDetected(rnd, dead))
            return

    def claim(self) -> bool:
        """Non-blocking recovery latch, shared with the main-thread
        path: True exactly once. A loser must not start its own
        recovery — the winner is about to exec the process away."""
        return self._latch.acquire(blocking=False)

    def start(self) -> "Deadman":
        self._threads = [
            threading.Thread(target=self._pulse, daemon=True,
                             name="deadman-pulse"),
            threading.Thread(target=self._watch, daemon=True,
                             name="deadman-watch")]
        for t in self._threads:
            t.start()
        return self

    def stop(self) -> None:
        """Stop pulsing AND watching — call the moment the run loop
        returns, BEFORE teardown: a peer that finished and exited is
        silent, not dead."""
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)


def generation(environ: Mapping[str, str] = os.environ) -> int:
    """Which recovery incarnation this process is (0 = original launch)."""
    return int(environ.get(ENV_GENERATION, "0") or "0")


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Knobs for the supervisor; defaults sized for CI subprocess runs.

    ``peer_death_grace_s`` stretches the coordination service's
    missed-heartbeat windows at bootstrap (see
    :func:`bootstrap.grace_kwargs`) — without it the service terminates
    survivors ~100 s after a peer dies, racing the supervisor's
    detect → finalize → exec sequence."""
    teardown_timeout_s: float = 5.0
    init_timeout_s: float = 60.0
    retries: int = 3
    backoff_s: float = 0.5
    backoff_max_s: float = 8.0
    port_stride: int = 17            # coordinator port bump per generation
    peer_death_grace_s: float = 600.0


def teardown(timeout_s: float = 5.0, *, telemetry=obs.DISABLED) -> bool:
    """Best-effort ``jax.distributed.shutdown`` that cannot wedge the
    survivor: with a dead peer the call blocks on the coordination
    service, so it runs on a daemon thread and is abandoned after
    ``timeout_s`` (the process is about to exec away anyway). Returns
    True iff shutdown completed."""
    import jax

    def _shutdown():
        try:
            jax.distributed.shutdown()
        except Exception:            # noqa: BLE001 - already dying
            pass

    t = threading.Thread(target=_shutdown, daemon=True)
    t.start()
    t.join(timeout_s)
    ok = not t.is_alive()
    telemetry.emit("recovery_teardown", ok=ok, timeout_s=timeout_s)
    return ok


def shrink_config(cfg: bootstrap.BootstrapConfig, dead: Sequence[int],
                  new_generation: int, *, port_stride: int = 17
                  ) -> Optional[bootstrap.BootstrapConfig]:
    """The shrunken group's contract after ``dead`` ranks are removed:
    survivors re-rank in order, the new rank 0 (coordinator failover —
    the old coordinator host may be among the dead) serves on the old
    port bumped by ``new_generation * port_stride`` so a half-dead old
    coordination service can't collide with the new one. None when one
    process survives — a solo run needs no coordinator at all."""
    dead_set = set(dead)
    survivors = [p for p in range(cfg.num_processes) if p not in dead_set]
    if cfg.process_id not in survivors:
        raise ValueError(f"process {cfg.process_id} is among the dead")
    if len(survivors) <= 1:
        return None
    host, _, port = cfg.coordinator.rpartition(":")
    new_port = int(port) + new_generation * port_stride
    return bootstrap.BootstrapConfig(
        coordinator=f"{host}:{new_port}",
        num_processes=len(survivors),
        process_id=survivors.index(cfg.process_id),
        local_devices=cfg.local_devices)


def reexec(cfg: Optional[bootstrap.BootstrapConfig], new_generation: int, *,
           environ=os.environ, argv: Optional[Sequence[str]] = None,
           execv=os.execv) -> None:
    """Replace this process with a fresh interpreter carrying the
    shrunken group's env — the only way to re-run
    ``jax.distributed.initialize`` after jax has executed computations.
    ``cfg=None`` clears the group declaration (solo resume)."""
    for k in (bootstrap.ENV_COORDINATOR, bootstrap.ENV_NUM_PROCESSES,
              bootstrap.ENV_PROCESS_ID, bootstrap.ENV_COORDINATOR_EXTERNAL):
        environ.pop(k, None)
    if cfg is not None:
        environ.update(cfg.env())
    environ[ENV_GENERATION] = str(new_generation)
    args = list(argv if argv is not None else sys.argv)
    execv(sys.executable, [sys.executable] + args)


def bootstrap_with_retry(cfg: Optional[bootstrap.BootstrapConfig], *,
                         reco: RecoveryConfig = RecoveryConfig(),
                         telemetry=obs.DISABLED, sleep=time.sleep,
                         _bootstrap=bootstrap.bootstrap
                         ) -> Tuple[bootstrap.DistContext, int]:
    """``bootstrap()`` under bounded retry with exponential backoff —
    surviving peers of a shrunken group re-exec at slightly different
    times, so the first initialize attempts can race the new
    coordinator's socket. Returns ``(ctx, attempts_used)``; re-raises
    the last error once retries are exhausted."""
    last: Optional[BaseException] = None
    for attempt in range(reco.retries + 1):
        try:
            ctx = _bootstrap(cfg, init_timeout_s=reco.init_timeout_s,
                             peer_death_grace_s=reco.peer_death_grace_s)
            return ctx, attempt + 1
        except (RuntimeError, OSError, ValueError) as e:
            last = e
            telemetry.emit("bootstrap_retry", attempt=attempt,
                           error=repr(e))
            if attempt < reco.retries:
                sleep(min(reco.backoff_s * (2 ** attempt),
                          reco.backoff_max_s))
    raise last  # type: ignore[misc]


def startup(environ: Mapping[str, str] = os.environ, *,
            reco: RecoveryConfig = RecoveryConfig(),
            telemetry=obs.DISABLED) -> Tuple[bootstrap.DistContext, int]:
    """Worker-side entry: bootstrap (with retry when this is a recovery
    incarnation) and announce the rebootstrap in telemetry. Returns
    ``(ctx, generation)``."""
    gen = generation(environ)
    cfg = bootstrap.config_from_env(environ)
    if gen == 0:
        return bootstrap.bootstrap(
            cfg, peer_death_grace_s=reco.peer_death_grace_s), 0
    ctx, attempts = bootstrap_with_retry(cfg, reco=reco, telemetry=telemetry)
    telemetry.emit("rebootstrap", generation=gen, attempts=attempts,
                   num_processes=ctx.num_processes,
                   process_id=ctx.process_id)
    return ctx, gen


def recover(loss: HostLossDetected, ctx: bootstrap.DistContext, *,
            ckpt_dir: Optional[str] = None,
            cfg: Optional[bootstrap.BootstrapConfig] = None,
            reco: RecoveryConfig = RecoveryConfig(),
            environ=os.environ, telemetry=obs.DISABLED,
            execv=os.execv) -> None:
    """The supervisor: turn a detected host loss into a resumed run.
    Does not return (the process execs away) unless ``execv`` is a test
    double."""
    gen = generation(environ) + 1
    telemetry.emit("recovery_begin", round=loss.round,
                   dead=list(loss.dead), generation=gen)
    if ckpt_dir:
        from repro.checkpoint.distributed import DistributedCheckpointManager
        mgr = DistributedCheckpointManager(
            ckpt_dir, process_id=ctx.process_id, telemetry=telemetry)
        finalized = mgr.finalize_pending()
        telemetry.emit("recovery_finalize", step=finalized,
                       latest=mgr.latest_committed())
    if ctx.initialized and reco.teardown_timeout_s > 0:
        # best-effort only, and on a clock: the coordination service is
        # ALSO detecting the missed heartbeats, and its default reaction
        # is to terminate this process (~10 s after the peer died) — the
        # survivor must exec away before that. <= 0 skips teardown.
        teardown(reco.teardown_timeout_s, telemetry=telemetry)
    if cfg is None:
        cfg = bootstrap.config_from_env(environ)
    new_cfg = None
    if cfg is not None:
        new_cfg = shrink_config(cfg, loss.dead, gen,
                                port_stride=reco.port_stride)
    telemetry.emit("recovery_exec", generation=gen,
                   num_processes=new_cfg.num_processes if new_cfg else 1)
    telemetry.close()
    reexec(new_cfg, gen, environ=environ, execv=execv)
