"""Deterministic fault injection for the distributed runtime.

A :class:`FaultSchedule` is an explicit, seedable list of
:class:`FaultEvent`\\ s injected through three hook surfaces the runtime
already exposes:

* ``round_start(rnd)`` — called by both DIALS drivers at the top of each
  outer round (``DIALSTrainer.run(..., chaos=...)``): ``host_kill``
  SIGKILLs the targeted host at the round boundary (the only point where
  a peer death cannot strand survivors inside a collective),
  ``interrupt`` raises :class:`ChaosInterrupt` for in-process
  kill-and-resume tests.
* ``checkpoint_phase(step, phase, directory)`` — installed as
  ``CheckpointManager.hooks``: ``writer_crash`` dies (SIGKILL, or raises
  :class:`ChaosError` in ``mode=raise``) at a chosen write phase
  (``write_begin`` → ``leaves_written`` → ``prepared`` → ``pre_commit``
  → ``committed``), ``commit_delay`` stretches the prepare→commit window
  so a host kill lands between the two phases, and ``corrupt`` flips
  bytes in a just-committed step.
* ``heartbeat(rnd)`` — called by ``fault.HostMonitor.beat``:
  ``heartbeat_delay`` sleeps before beating, simulating a straggler.

Every injection emits a ``chaos_inject`` telemetry event *before*
acting (the JSONL sink flushes per line, so even a SIGKILL leaves its
cause in the merged log). Schedules come from an explicit event list,
the compact ``from_spec`` string used by tests/CI
(``"kill@2:host=1,corrupt@3:target=bytes"``), or ``seeded`` — a
``random.Random(seed)`` draw, so a CI chaos matrix is reproducible from
its seed alone. Events fire at most once and are filtered by the host's
identity and the recovery ``generation`` (a fault scheduled for
generation 0 must not re-fire after the survivor re-execs as
generation 1).
"""
from __future__ import annotations

import dataclasses
import os
import random
import signal
import time
from typing import List, Optional, Sequence, Tuple

from repro import obs

KINDS = ("host_kill", "interrupt", "writer_crash", "corrupt",
         "heartbeat_delay", "commit_delay")
_ALIASES = {"kill": "host_kill", "crash": "writer_crash",
            "delay": "heartbeat_delay"}
WRITE_PHASES = ("write_begin", "leaves_written", "prepared", "pre_commit",
                "committed")


class ChaosError(RuntimeError):
    """Raised by a ``writer_crash`` event in ``mode=raise`` — exercises
    the CheckpointManager async-error capture path in-process."""


class ChaosInterrupt(RuntimeError):
    """Raised by an ``interrupt`` event at a round boundary — an
    in-process stand-in for a SIGKILL in resume-equality tests."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``round`` is the outer-round index for ``host_kill`` / ``interrupt``
    / ``heartbeat_delay``, and the checkpoint *step* for
    ``writer_crash`` / ``corrupt`` / ``commit_delay``."""
    kind: str
    round: int
    host: int = 0
    phase: str = "leaves_written"     # writer_crash / commit_delay anchor
    mode: str = "kill"                # writer_crash: "kill" | "raise"
    target: str = "bytes"             # corrupt: "bytes" | "manifest" | "commit"
    delay_s: float = 0.25
    generation: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


def corrupt_checkpoint(step_dir: str, target: str = "bytes") -> Optional[str]:
    """Flip bytes in a checkpoint step dir: ``bytes`` damages the first
    leaf ``.npy`` found, ``manifest`` a ``manifest.json``, ``commit``
    truncates the COMMIT marker. Returns the damaged path (None if the
    dir holds nothing to damage)."""
    suffix = {"bytes": ".npy", "manifest": "manifest.json",
              "commit": "COMMIT"}[target]
    victims = []
    for root, _dirs, files in os.walk(step_dir):
        for fn in sorted(files):
            if fn.endswith(suffix):
                victims.append(os.path.join(root, fn))
    if not victims:
        return None
    path = sorted(victims)[0]
    if target == "commit":
        with open(path, "w") as f:
            f.write("{ torn")
        return path
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last[0] ^ 0xFF]))
    return path


class FaultSchedule:
    """The injection engine: holds the events, filters them by this
    host's identity and recovery generation, fires each at most once."""

    def __init__(self, events: Sequence[FaultEvent], *, host: int = 0,
                 generation: int = 0, telemetry=obs.DISABLED,
                 kill=os.kill, sleep=time.sleep):
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self.host = host
        self.generation = generation
        self.telemetry = telemetry
        self.fired: List[FaultEvent] = []
        self._kill = kill
        self._sleep = sleep

    # -- construction -------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str, **kw) -> "FaultSchedule":
        """Parse ``"kind@round[:k=v[:k=v...]],..."`` — e.g.
        ``"kill@2:host=1,crash@3:host=0:phase=pre_commit:mode=raise"``."""
        events = []
        for entry in filter(None, (s.strip() for s in spec.split(","))):
            head, *opts = entry.split(":")
            kind, _, rnd = head.partition("@")
            kind = _ALIASES.get(kind, kind)
            fields = {"kind": kind, "round": int(rnd)}
            for opt in opts:
                k, _, v = opt.partition("=")
                if k in ("host", "generation"):
                    fields[k] = int(v)
                elif k == "delay_s":
                    fields[k] = float(v)
                elif k in ("phase", "mode", "target"):
                    fields[k] = v
                else:
                    raise ValueError(f"unknown fault option {k!r} in "
                                     f"{entry!r}")
            events.append(FaultEvent(**fields))
        return cls(events, **kw)

    @classmethod
    def seeded(cls, seed: int, *, rounds: int, hosts: int, n_faults: int = 2,
               kinds: Sequence[str] = ("host_kill", "heartbeat_delay",
                                       "writer_crash"), **kw):
        """A reproducible random schedule: same seed ⇒ identical events
        on every host (each host filters to its own)."""
        rng = random.Random(seed)
        events = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            events.append(FaultEvent(
                kind=kind,
                round=rng.randrange(1, max(2, rounds)),
                host=rng.randrange(max(1, hosts)),
                phase=rng.choice(WRITE_PHASES[:4]),
                delay_s=round(rng.uniform(0.05, 0.5), 3)))
        return cls(events, **kw)

    # -- firing -------------------------------------------------------------
    def _due(self, kinds, round_: int):
        for ev in self.events:
            if ev.kind in kinds and ev.round == round_ \
                    and ev.generation == self.generation \
                    and ev.host == self.host and ev not in self.fired:
                yield ev

    def _fire(self, ev: FaultEvent, **ctx):
        self.fired.append(ev)
        self.telemetry.emit("chaos_inject", kind=ev.kind, round=ev.round,
                            host=self.host, phase=ev.phase, mode=ev.mode,
                            target=ev.target, delay_s=ev.delay_s,
                            generation=self.generation, **ctx)

    # -- hook surfaces ------------------------------------------------------
    def round_start(self, rnd: int) -> None:
        """Driver hook, top of every outer round (pre-heartbeat)."""
        for ev in self._due(("host_kill", "interrupt"), rnd):
            self._fire(ev)
            if ev.kind == "host_kill":
                self._kill(os.getpid(), signal.SIGKILL)
            else:
                raise ChaosInterrupt(f"chaos interrupt at round {rnd}")

    def checkpoint_phase(self, step: int, phase: str, directory: str) -> None:
        """``CheckpointManager.hooks`` surface (runs on the writer
        thread)."""
        for ev in self._due(("commit_delay",), step):
            if ev.phase == phase:
                self._fire(ev, write_phase=phase)
                self._sleep(ev.delay_s)
        for ev in self._due(("writer_crash",), step):
            if ev.phase == phase:
                self._fire(ev, write_phase=phase, directory=directory)
                if ev.mode == "kill":
                    self._kill(os.getpid(), signal.SIGKILL)
                raise ChaosError(
                    f"chaos writer crash at step {step} phase {phase}")
        if phase == "committed":
            for ev in self._due(("corrupt",), step):
                self._fire(ev, directory=directory)
                corrupt_checkpoint(directory, ev.target)

    def heartbeat(self, rnd: int) -> None:
        """``fault.HostMonitor.beat`` surface — delay before beating."""
        for ev in self._due(("heartbeat_delay",), rnd):
            self._fire(ev)
            self._sleep(ev.delay_s)
