"""Fault tolerance: straggler mitigation, bounded-staleness updates,
elastic resharding.

DIALS gives us an unusually clean fault story: between AIP refreshes the
per-region simulators are *independent*, so a slow or dead shard only
delays **its own** region's data — the paper's staleness tolerance
(Lemma 2 / Theorem 1) is exactly the license to keep training everyone
else on slightly-stale influence. These utilities implement that:

* :func:`straggler_plan` — deterministic work reassignment for late shards.
* :func:`masked_tree_update` — bounded-staleness parameter update: take the
  fresh AIP/grad only for shards that reported in time.
* :func:`reshard` — elastic scaling: move a checkpointed pytree onto a new
  mesh (different shape or device count) via resolved shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import mesh as mesh_lib


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StragglerPlan:
    """Deterministic reassignment: every late shard's work unit is re-run by
    the healthy shard that (cyclically) follows it, chosen by shard id so
    all hosts compute the same plan with no coordination."""
    reassign: Dict[int, int]          # late shard -> healthy shard
    healthy: Tuple[int, ...]

    def owner(self, work_unit: int) -> int:
        return self.reassign.get(work_unit, work_unit)


def straggler_plan(n_shards: int, late: Sequence[int]) -> StragglerPlan:
    late_set = set(late)
    healthy = tuple(i for i in range(n_shards) if i not in late_set)
    if not healthy:
        raise RuntimeError("all shards late — cannot build a plan")
    reassign = {}
    for j, shard in enumerate(sorted(late_set)):
        reassign[shard] = healthy[(shard + j) % len(healthy)]
    return StragglerPlan(reassign=reassign, healthy=healthy)


def masked_tree_update(old_tree, new_tree, fresh_mask: jax.Array):
    """Bounded-staleness update for per-agent stacked params.

    ``fresh_mask`` (N,) of {0,1}: agents whose data/update arrived in time
    take the new leaf; stale agents keep the old one (the DIALS move).
    Leaves have leading axis N.
    """
    def sel(old, new):
        m = fresh_mask.reshape((-1,) + (1,) * (old.ndim - 1)).astype(old.dtype)
        return old * (1 - m) + new * m

    return jax.tree.map(sel, old_tree, new_tree)


# ---------------------------------------------------------------------------
# Elastic resharding
# ---------------------------------------------------------------------------
def reshard(tree, spec_tree, new_mesh, *, rules=mesh_lib.TRAIN_RULES,
            fsdp_axes=()):
    """Place ``tree`` onto ``new_mesh`` under the resolved shardings —
    elastic scale-up/down and restart-on-different-topology both reduce to
    this plus a checkpoint restore."""
    shardings = mesh_lib.logical_to_sharding(
        spec_tree, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree),
        new_mesh, rules=rules, fsdp_axes=fsdp_axes)
    return jax.tree.map(jax.device_put, tree, shardings)


def heartbeat_mask(report_steps: jax.Array, current_step: int,
                   max_staleness: int) -> jax.Array:
    """(N,) last-report step per shard -> {0,1} fresh mask."""
    return (current_step - report_steps <= max_staleness).astype(jnp.float32)


def freshness_gate(fresh_mask: jax.Array, report_rounds: jax.Array,
                   data_round, current_round, max_staleness: int):
    """The bounded-staleness contract, enforced (Lemma 2 / Theorem 1).

    ``fresh_mask`` (N,) {0,1} says whose AIP update arrived in time this
    round (1 = apply, 0 = straggler keeps its old predictor).
    ``report_rounds`` (N,) is the collection round of the newest dataset
    each agent's predictor was trained on. Stragglers are tolerated only
    UP TO ``max_staleness`` rounds: an agent whose last report would fall
    further behind is **force-refreshed** — its mask entry is overridden
    to 1 so it takes the update trained on the current (``data_round``)
    dataset instead of silently training on arbitrarily old influence.

    Returns ``(effective_mask, new_report_rounds, forced)`` where
    ``forced`` (N,) {0,1} marks the agents whose refresh was forced.
    All ops are elementwise — safe inside a collective-free shard body.
    """
    within = heartbeat_mask(report_rounds, current_round, max_staleness)
    fresh_mask = fresh_mask.astype(jnp.float32)
    # forced = would have straggled AND already past the bound
    forced = (1.0 - within) * (1.0 - fresh_mask)
    effective = jnp.maximum(fresh_mask, forced)
    new_reports = jnp.where(
        effective > 0,
        jnp.asarray(data_round, report_rounds.dtype), report_rounds)
    return effective, new_reports, forced
