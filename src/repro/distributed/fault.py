"""Fault tolerance: straggler mitigation, bounded-staleness updates,
elastic resharding.

DIALS gives us an unusually clean fault story: between AIP refreshes the
per-region simulators are *independent*, so a slow or dead shard only
delays **its own** region's data — the paper's staleness tolerance
(Lemma 2 / Theorem 1) is exactly the license to keep training everyone
else on slightly-stale influence. These utilities implement that:

* :func:`straggler_plan` — deterministic work reassignment for late shards.
* :func:`masked_tree_update` — bounded-staleness parameter update: take the
  fresh AIP/grad only for shards that reported in time.
* :func:`reshard` — elastic scaling: move a checkpointed pytree onto a new
  mesh (different shape or device count) via resolved shardings.
* :func:`elastic_plan` / :class:`ElasticPlan` — the host-loss extension of
  the straggler plan: when a host's heartbeat lapses for good, its agent
  blocks are reassigned to the surviving shards on a shrunken mesh and
  training continues (DARL1N-style degradation instead of a crash).
* :class:`HostMonitor` — the heartbeat itself: a shared-directory beat
  file per host per round, with a timeout-gated wait that converts a
  silent host into a ``dead`` verdict every surviving host agrees on.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import mesh as mesh_lib


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StragglerPlan:
    """Deterministic reassignment: every late shard's work unit is re-run by
    the healthy shard that (cyclically) follows it, chosen by shard id so
    all hosts compute the same plan with no coordination."""
    reassign: Dict[int, int]          # late shard -> healthy shard
    healthy: Tuple[int, ...]

    def owner(self, work_unit: int) -> int:
        return self.reassign.get(work_unit, work_unit)


def straggler_plan(n_shards: int, late: Sequence[int]) -> StragglerPlan:
    late_set = set(late)
    healthy = tuple(i for i in range(n_shards) if i not in late_set)
    if not healthy:
        raise RuntimeError("all shards late — cannot build a plan")
    reassign = {}
    for j, shard in enumerate(sorted(late_set)):
        reassign[shard] = healthy[(shard + j) % len(healthy)]
    return StragglerPlan(reassign=reassign, healthy=healthy)


def masked_tree_update(old_tree, new_tree, fresh_mask: jax.Array):
    """Bounded-staleness update for per-agent stacked params.

    ``fresh_mask`` (N,) of {0,1}: agents whose data/update arrived in time
    take the new leaf; stale agents keep the old one (the DIALS move).
    Leaves have leading axis N.
    """
    def sel(old, new):
        m = fresh_mask.reshape((-1,) + (1,) * (old.ndim - 1)).astype(old.dtype)
        return old * (1 - m) + new * m

    return jax.tree.map(sel, old_tree, new_tree)


# ---------------------------------------------------------------------------
# Elastic shard reassignment (host loss)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Where every agent block lands after dead shards are removed.

    The straggler plan reassigns a late shard's *work* for one round; an
    elastic plan reassigns a dead host's *agents* permanently: the mesh
    shrinks from ``old_shards`` to ``new_shards`` and the agent axis is
    re-tiled over the survivors. Because the agent-sharded state is
    resharded as a whole (``reshard_agents``), ownership after the move
    is simply the new even tiling — the plan records it so drivers and
    tests can assert the partition without touching devices."""
    n_agents: int
    old_shards: int
    new_shards: int
    dead: Tuple[int, ...]            # dead shard ids in the OLD mesh
    survivors: Tuple[int, ...]       # surviving old shard ids, in order

    def __post_init__(self):
        if self.n_agents % self.old_shards or self.n_agents % self.new_shards:
            raise ValueError(
                f"{self.n_agents} agents must tile both the old "
                f"({self.old_shards}) and new ({self.new_shards}) meshes")

    def agent_owner(self, agent: int) -> int:
        """New shard id owning ``agent`` after the move (even tiling)."""
        if not 0 <= agent < self.n_agents:
            raise ValueError(f"agent {agent} outside [0, {self.n_agents})")
        return agent // (self.n_agents // self.new_shards)

    def owner(self, block: int) -> int:
        """New shard id owning OLD shard ``block``'s first agent — the
        work-unit view, mirroring :meth:`StragglerPlan.owner`."""
        if not 0 <= block < self.old_shards:
            raise ValueError(f"block {block} outside [0, {self.old_shards})")
        return self.agent_owner(block * (self.n_agents // self.old_shards))

    @property
    def reassigned_blocks(self) -> Tuple[int, ...]:
        return self.dead


def elastic_plan(n_agents: int, n_shards: int, dead: Sequence[int],
                 *, telemetry=None) -> ElasticPlan:
    """Plan the shrink after ``dead`` shards (hosts' shard slots) vanish.

    The new shard count is the largest divisor of ``n_agents`` that fits
    the surviving slots (``runtime.choose_shards``) — agents always tile
    exactly, even when the survivor count doesn't divide them.

    With ``telemetry`` set (a ``repro.obs.Telemetry``), the plan is
    emitted as an ``elastic_reassign`` event — dead blocks, the shrink,
    and the block → new-owner mapping — so the incident is
    reconstructable from the event log alone."""
    from repro.distributed import runtime
    dead_set = set(dead)
    if not dead_set <= set(range(n_shards)):
        raise ValueError(f"dead shards {sorted(dead_set)} outside "
                         f"[0, {n_shards})")
    survivors = tuple(i for i in range(n_shards) if i not in dead_set)
    if not survivors:
        raise RuntimeError("all shards dead — nothing to reassign to")
    new_shards = runtime.choose_shards(n_agents, len(survivors))
    plan = ElasticPlan(n_agents=n_agents, old_shards=n_shards,
                       new_shards=new_shards, dead=tuple(sorted(dead_set)),
                       survivors=survivors)
    if telemetry is not None:
        telemetry.emit(
            "elastic_reassign", n_agents=n_agents,
            old_shards=plan.old_shards, new_shards=plan.new_shards,
            dead_blocks=list(plan.dead), survivors=list(plan.survivors),
            # str keys: JSON objects cannot carry int keys
            moved={str(b): plan.owner(b) for b in plan.dead})
    return plan


# Logical rule for per-agent stacked state: leading axis "agents" maps to
# the 1-D ("shards",) mesh axis.
AGENT_RULES = (("agents", "shards"),)


def reshard_agents(tree, new_mesh):
    """Move an agent-stacked pytree (every leaf leading axis N) onto a
    new/shrunken ``("shards",)`` mesh — the tensor half of an
    :class:`ElasticPlan`.

    When the shrunken mesh still spans several surviving processes,
    plain ``device_put`` (what :func:`reshard` does) is not legal for
    host data; the tree is first brought fully to host and re-placed
    slice-by-slice via the runtime's per-host plumbing."""
    from repro.distributed import runtime
    if runtime.mesh_spans_processes(new_mesh):
        return runtime.shard_agent_tree(runtime.fetch_tree(tree), new_mesh)
    spec = jax.tree.map(lambda _: ("agents",), tree)
    return reshard(tree, spec, new_mesh, rules=AGENT_RULES)


class HostMonitor:
    """File-based heartbeat over a shared directory.

    Each host writes ``beat-{host}-{round}`` at the top of every round;
    :meth:`gate` then waits (up to ``timeout_s``) for every peer's beat
    for that round and returns the set of hosts that never produced one.
    Death is sticky: a host declared dead is never waited on again, so
    the surviving hosts keep full speed after a loss. A shared
    filesystem is the one medium that survives the peer's process — the
    in-band channel (collectives) is exactly what a dead host hangs.
    """

    def __init__(self, directory: str, *, host: int, n_hosts: int,
                 timeout_s: float = 30.0, poll_s: float = 0.05,
                 telemetry=None, chaos=None):
        self.directory = directory
        self.host = int(host)
        self.n_hosts = int(n_hosts)
        self.timeout_s = float(timeout_s)
        self.poll_s = float(poll_s)
        self.telemetry = telemetry      # optional repro.obs.Telemetry
        self.chaos = chaos              # optional chaos.FaultSchedule
        self.dead: Set[int] = set()
        os.makedirs(directory, exist_ok=True)

    def _beat_path(self, host: int, rnd: int) -> str:
        return os.path.join(self.directory, f"beat-{host}-{rnd}")

    def beat(self, rnd: int) -> None:
        if self.chaos is not None:       # injected straggler delay
            self.chaos.heartbeat(rnd)
        path = self._beat_path(self.host, rnd)
        with open(path + ".tmp", "w") as f:      # atomic publish
            f.write(str(time.time()))
        os.replace(path + ".tmp", path)

    def gate(self, rnd: int) -> Tuple[int, ...]:
        """Beat for ``rnd``, wait for live peers' beats, return newly
        dead hosts (empty tuple = everyone alive)."""
        self.beat(rnd)
        waiting = {h for h in range(self.n_hosts)
                   if h != self.host and h not in self.dead}
        deadline = time.monotonic() + self.timeout_s
        while waiting and time.monotonic() < deadline:
            waiting = {h for h in waiting
                       if not os.path.exists(self._beat_path(h, rnd))}
            if waiting:
                time.sleep(self.poll_s)
        newly_dead = tuple(sorted(waiting))
        self.dead |= waiting
        if newly_dead and self.telemetry is not None:
            self.telemetry.emit(
                "host_death", round=int(rnd),
                dead_hosts=list(newly_dead),
                all_dead=sorted(self.dead),
                timeout_s=self.timeout_s)
        return newly_dead


# ---------------------------------------------------------------------------
# Elastic resharding
# ---------------------------------------------------------------------------
def reshard(tree, spec_tree, new_mesh, *, rules=mesh_lib.TRAIN_RULES,
            fsdp_axes=()):
    """Place ``tree`` onto ``new_mesh`` under the resolved shardings —
    elastic scale-up/down and restart-on-different-topology both reduce to
    this plus a checkpoint restore."""
    shardings = mesh_lib.logical_to_sharding(
        spec_tree, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree),
        new_mesh, rules=rules, fsdp_axes=fsdp_axes)
    return jax.tree.map(jax.device_put, tree, shardings)


def heartbeat_mask(report_steps: jax.Array, current_step: int,
                   max_staleness: int) -> jax.Array:
    """(N,) last-report step per shard -> {0,1} fresh mask."""
    return (current_step - report_steps <= max_staleness).astype(jnp.float32)


def freshness_gate(fresh_mask: jax.Array, report_rounds: jax.Array,
                   data_round, current_round, max_staleness: int):
    """The bounded-staleness contract, enforced (Lemma 2 / Theorem 1).

    ``fresh_mask`` (N,) {0,1} says whose AIP update arrived in time this
    round (1 = apply, 0 = straggler keeps its old predictor).
    ``report_rounds`` (N,) is the collection round of the newest dataset
    each agent's predictor was trained on. Stragglers are tolerated only
    UP TO ``max_staleness`` rounds: an agent whose last report would fall
    further behind is **force-refreshed** — its mask entry is overridden
    to 1 so it takes the update trained on the current (``data_round``)
    dataset instead of silently training on arbitrarily old influence.

    Returns ``(effective_mask, new_report_rounds, forced)`` where
    ``forced`` (N,) {0,1} marks the agents whose refresh was forced.
    All ops are elementwise — safe inside a collective-free shard body.
    """
    within = heartbeat_mask(report_rounds, current_round, max_staleness)
    fresh_mask = fresh_mask.astype(jnp.float32)
    # forced = would have straggled AND already past the bound
    forced = (1.0 - within) * (1.0 - fresh_mask)
    effective = jnp.maximum(fresh_mask, forced)
    new_reports = jnp.where(
        effective > 0,
        jnp.asarray(data_round, report_rounds.dtype), report_rounds)
    return effective, new_reports, forced
