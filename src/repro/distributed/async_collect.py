"""Double-buffered asynchronous GS collect — overlap Algorithm 2 with
Algorithm 3.

The serial Algorithm-1 round pays the GS collect (Algorithm 2) on the
critical path of every round. The paper's staleness tolerance (Lemma 2 /
Theorem 1) licenses training round k's AIPs on influence data gathered
under the joint policy of round k-1, which is exactly the license to
pipeline: collect round k+1's datasets WHILE round k's F inner IALS
steps run (cf. Shacklett et al., *Large Batch Simulation for Deep RL* —
simulation/learning pipelining; and Suau et al., *IALS* — periodic,
lag-tolerant AIP retraining).

This module is the executor for that overlap, shared by both DIALS
driver paths:

* **Double-buffered dataset slots** — ``_current`` (the tagged dataset
  being consumed this round) and ``_pending`` (the one in flight). Every
  dataset is a :class:`TaggedDataset` carrying the **collection round**
  of the joint policy that produced it, so staleness is an auditable
  number, not a vibe.
* **Background dispatch**, two modes:
    - ``"dispatch"`` — the collect program is enqueued from the driver
      thread and runs under JAX async dispatch; with a ``spare_device``
      (a device outside the shard mesh) inputs are transferred there
      first, so the collect executes concurrently with the shard-train
      program instead of queueing behind it. This is the only safe mode
      next to donated-buffer programs: the enqueue happens before the
      trainer donates its carry.
    - ``"thread"`` — a single worker thread calls the jitted collector
      and blocks until ready; used by the single-device python-loop
      path, where it overlaps collect with the F host-dispatched inner
      steps (no donation hazard: that path never donates buffers).
* **The dataset-level freshness gate** — :meth:`AsyncCollector.obtain`
  swaps the double buffer at the round boundary: when the current slot
  is stale for the new round it harvests the in-flight slot, BLOCKING if
  the producer hasn't finished (a no-op in the steady state — the
  collect had a whole round of inner steps to complete). The blocking
  barrier is deliberate: which dataset trains round r must be a function
  of the round alone, never of thread scheduling, or per-seed
  determinism dies. If the harvested (or absent) dataset still exceeds
  ``max_staleness`` rounds of age, the collector **force-syncs** — a
  fresh blocking collect under the current policy. ``max_staleness=0``
  therefore degenerates to the serial schedule — the property the
  async-vs-serial equivalence tests pin down.

Per-agent staleness (stragglers inside one dataset) is the trainers'
job, via :func:`repro.distributed.fault.freshness_gate`.

:class:`DeviceRing` is the memory half of the pipeline: the loop
driver's datasets live in a ring of device-resident slots whose buffers
are DONATED back to the next collect once retired, so at large stream
counts S the wide dataset neither round-trips through the host nor
reallocates each round. (The sharded sync path needs no ring — its
round is one fused program and the dataset never materializes outside
it; the sharded async path double-buffers on the spare device/mesh,
already device-resident.)
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Any, Optional, Tuple

import jax

from repro import obs


@dataclasses.dataclass(frozen=True)
class TaggedDataset:
    """A collected dataset plus the outer round of the joint policy that
    generated it. ``age = current_round - round`` is the staleness the
    Lemma-2 bound is paid for."""
    data: Any
    round: int


class _Ready:
    """Future-like wrapper for dispatch-mode results: the computation is
    already enqueued on a device, so from the host's point of view it is
    always 'done' (the arrays resolve whenever the consumer needs them)."""

    def __init__(self, value):
        self._value = value

    def done(self) -> bool:
        return True

    def result(self):
        return self._value


class DeviceRing:
    """Device-resident ring of dataset slots: wide ``(N, S, T, ...)``
    datasets feed training without ever round-tripping through the host,
    and — past the first fill — without allocating at all.

    ``collect()`` rotates through K slots, every call running the
    DONATING collect variant (``gs.make_collector_into``): the first
    fill of a slot donates freshly allocated zero buffers, every later
    call donates the retired slot's, so XLA writes the fresh dataset
    straight into them. The collect overwrites every buffer cell, so
    the result is bitwise independent of the donated seed — and because
    first fills and steady state share ONE jitted program, nothing
    recompiles mid-run (the plain ``collect_fn`` is used only for its
    output structure, via ``eval_shape``). At large S this halves
    steady-state collect memory (no second dataset materializes) and
    removes the allocate/free churn from the hot loop; consumers (the
    fused AIP round, ``gs.split_dataset`` holdout slices) read the slot
    arrays in place.

    Safety contract, enforced by the callers' schedule rather than
    locks: a returned dataset stays valid for ``slots - 1`` subsequent
    ``collect()`` calls, after which its buffers are donated to the new
    collect. The loop driver consumes round r's dataset before round
    r+1 ends, and ``AsyncCollector``'s obtain-before-submit protocol
    totally orders every ``collect()`` call across the driver and worker
    threads (harvest blocks on the in-flight future before any
    force-sync), so the default two slots cover both the serial and the
    overlapped schedule.
    """

    def __init__(self, collect_fn, collect_into_fn, *, slots: int = 2):
        if slots < 2:
            raise ValueError("DeviceRing needs >= 2 slots (consuming + "
                             "in flight)")
        self._collect = collect_fn
        self._into = collect_into_fn
        self._slots = [None] * slots
        self._next = 0
        self._struct = None           # slot avals, from collect_fn

    @property
    def n_slots(self) -> int:
        return len(self._slots)

    def _fresh_slot(self, params, key):
        import jax.numpy as jnp
        if self._struct is None:
            self._struct = jax.eval_shape(self._collect, params, key)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self._struct)

    def collect(self, params, key):
        """A fresh dataset, written into the retired slot's donated
        buffers (first fill of a slot donates zeros instead — same
        program, so nothing recompiles mid-run). Drop-in for the plain
        ``collect_fn(params, key)``."""
        i = self._next
        slot = self._slots[i]
        if slot is None:
            slot = self._fresh_slot(params, key)
        else:
            self._slots[i] = None     # the donated python arrays are dead
        out = self._into(slot, params, key)
        self._slots[i] = out
        self._next = (i + 1) % len(self._slots)
        return out


class AsyncCollector:
    """Background executor for the GS collect with one in-flight slot.

    ``collect_fn(params, key) -> dataset`` must be a jitted, functionally
    pure program (both driver paths pass ``gs.make_collector``'s output).
    """

    def __init__(self, collect_fn, *, mode: str = "auto",
                 spare_device=None, telemetry=obs.DISABLED):
        if mode == "auto":
            mode = "dispatch" if spare_device is not None else "thread"
        if mode not in ("dispatch", "thread"):
            raise ValueError(f"unknown dispatch mode {mode!r}")
        self._collect = collect_fn
        self.mode = mode
        self.spare_device = spare_device
        self.telemetry = telemetry
        # host seconds obtain() spent blocked (harvest barrier +
        # force-sync) on its last call — the drivers' collect_s phase
        self.last_obtain_wait_s: Optional[float] = None
        self._executor = (
            concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="gs-collect")
            if mode == "thread" else None)
        self._current: Optional[TaggedDataset] = None
        self._pending: Optional[Tuple[int, Any]] = None   # (round, future)

    # -- dispatch -----------------------------------------------------------
    def _run(self, params, key):
        if self.spare_device is not None:
            # commit the inputs to the spare device so the jitted collect
            # executes there, off the trainer's devices (the transfers and
            # the collect itself all go through async dispatch)
            params = jax.device_put(params, self.spare_device)
            key = jax.device_put(key, self.spare_device)
        return self._collect(params, key)

    def _run_blocking(self, params, key):
        data = self._run(params, key)
        jax.block_until_ready(data)
        return data

    def idle(self) -> bool:
        """True when no collect is in flight — i.e. submit() is legal.
        Under the blocking-barrier schedule obtain() always drains the
        in-flight slot before the driver submits again, so this is a
        defensive guard on the single-slot contract rather than a state
        the steady loop ever observes as False."""
        return self._pending is None

    @property
    def pending_round(self):
        """The in-flight collect's round tag, or None when idle — what a
        checkpoint must persist (``extra["async_round"]``) so a resumed
        run can re-prime the double buffer with the same staleness
        schedule instead of force-syncing into drift."""
        return self._pending[0] if self._pending is not None else None

    def submit(self, params, key, round: int) -> None:
        """Launch the collect for ``round``'s joint policy in the
        background. One in-flight collect at a time: the double buffer
        has exactly two slots (consuming + in flight)."""
        if self._pending is not None:
            raise RuntimeError("a collect is already in flight — obtain() "
                               "must harvest it before the next submit()")
        if self._executor is not None:
            fut = self._executor.submit(self._run_blocking, params, key)
        else:
            fut = _Ready(self._run(params, key))
        self._pending = (int(round), fut)

    def collect_now(self, params, key, round: int) -> TaggedDataset:
        """Synchronous (force-sync) collect under the current policy."""
        return TaggedDataset(self._run(params, key), int(round))

    # -- the freshness gate -------------------------------------------------
    def obtain(self, current_round: int, params, key, *,
               max_staleness: int) -> Tuple[TaggedDataset, bool]:
        """The dataset to train on at ``current_round``, freshness-gated.

        Steady state: the current slot is one round stale, so the buffers
        swap — blocking on the in-flight collect if the producer hasn't
        finished (determinism over opportunism: the consumed dataset must
        depend on the round number, not on thread scheduling). Force-sync
        path (returns True): the dataset is still older than
        ``max_staleness`` rounds after the swap — or there is nothing in
        flight — so a fresh blocking collect runs under the current
        policy (tag = ``current_round``). The first call always primes
        the pipeline this way.
        """
        t0 = time.perf_counter()
        if self._pending is not None and (
                self._current is None or
                self._current.round < current_round):
            pending_round, fut = self._pending
            self._current = TaggedDataset(fut.result(), pending_round)
            self._pending = None
        forced = (self._current is None or
                  current_round - self._current.round > max_staleness)
        if forced:
            self._current = self.collect_now(params, key, current_round)
        self.last_obtain_wait_s = time.perf_counter() - t0
        self.telemetry.emit(
            "collect_obtain", round=int(current_round),
            data_round=self._current.round, forced=bool(forced),
            mode=self.mode, wait_s=self.last_obtain_wait_s)
        return self._current, forced

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=True)
        self._pending = None
        self._current = None
