"""Pallas TPU kernels for the compute hot spots.

Each kernel package has three files:
  kernel.py -- ``pl.pallas_call`` with explicit BlockSpec VMEM tiling
  ops.py    -- the jit'd public wrapper (dispatch, layout, interpret fallback)
  ref.py    -- the pure-jnp oracle the kernel is validated against

| kernel          | hot spot                                               |
|-----------------|--------------------------------------------------------|
| flash_attention | 32k-prefill quadratic attention (online softmax)       |
| ssd             | Mamba-2 intra-chunk block (decay . CB^T . X fused)     |
| gru             | AIP/policy GRU recurrence (fused gates per step)       |
| gae             | GAE-lambda reverse scan over rollouts                  |

``gru`` and ``gae`` are TRAINABLE (``jax.custom_vjp`` with Pallas
backward-scan kernels) and sit on the DIALS hot path: the
``use_kernels: auto|on|off`` knob on ``AIPConfig`` / ``PolicyConfig`` /
``PPOConfig`` (driven globally by ``DIALSConfig``) routes
``aip_sequence``/``train_aip``, ``policy_sequence``, and the inner-step
GAE through them — resolved once per call site by
``repro.kernels.dispatch``.

On CPU (this container) the kernels execute with ``interpret=True``; the
BlockSpecs encode the intended TPU VMEM tiling (MXU-aligned 128-multiples).
"""
from repro.kernels import dispatch, flash_attention, gae, gru, ssd  # noqa: F401
