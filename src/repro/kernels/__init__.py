"""Pallas TPU kernels for the compute hot spots.

Each kernel package has three files:
  kernel.py -- ``pl.pallas_call`` with explicit BlockSpec VMEM tiling
  ops.py    -- the jit'd public wrapper (dispatch, layout, interpret fallback)
  ref.py    -- the pure-jnp oracle the kernel is validated against

| kernel          | hot spot                                               |
|-----------------|--------------------------------------------------------|
| flash_attention | 32k-prefill quadratic attention (online softmax)       |
| ssd             | Mamba-2 intra-chunk block (decay . CB^T . X fused)     |
| gru             | AIP/policy GRU recurrence (fused gates per step)       |
| gae             | GAE-lambda reverse scan over rollouts                  |

On CPU (this container) the kernels execute with ``interpret=True``; the
BlockSpecs encode the intended TPU VMEM tiling (MXU-aligned 128-multiples).
"""
from repro.kernels import flash_attention, gae, gru, ssd  # noqa: F401
