"""Unified kernel dispatch — the one place ``use_kernels`` is resolved.

Every MARL hot spot (AIP GRU, policy GRU, GAE reverse scan) carries a
``use_kernels: "auto" | "on" | "off"`` knob on its config
(``AIPConfig`` / ``PolicyConfig`` / ``PPOConfig``, driven globally by
``DIALSConfig``). This module turns that string into a concrete
:class:`KernelDecision` exactly once per call site, at trace time:

* ``"off"``  — pure-jnp oracle (``repro.nn.gru`` / ``repro.marl.gae``).
* ``"on"``   — Pallas kernel, compiled on TPU, **interpret mode**
  elsewhere (CPU CI runs the real kernel logic through the Pallas
  interpreter; slow but numerically the kernel).
* ``"auto"`` — kernel on TPU, oracle elsewhere. The production default:
  "jax_pallas means Pallas on the hot path" without making CPU runs pay
  interpreter overhead.

Resolving here — rather than per kernel call with an ``interpret=None``
default — keeps ``interpret`` out of jit static arguments: the op
wrappers receive a concrete bool and each (kernel, interpret) pair is
built once (``functools.lru_cache`` in the kernel modules), so flipping
call sites between ``None``/``True`` can no longer trigger redundant
recompiles of identical programs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax

MODES = ("auto", "on", "off")


@dataclasses.dataclass(frozen=True)
class KernelDecision:
    """Resolved dispatch: route to Pallas? and under the interpreter?"""
    use: bool            # True -> Pallas kernel, False -> jnp oracle
    interpret: bool      # Pallas interpret mode (any non-TPU backend)


def resolve(mode: Union[str, KernelDecision] = "auto", *,
            backend: Optional[str] = None) -> KernelDecision:
    """Resolve a ``use_kernels`` mode against the (default) backend.

    Accepts an already-resolved :class:`KernelDecision` unchanged so
    callers can pre-resolve once and thread the decision through.
    """
    if isinstance(mode, KernelDecision):
        return mode
    if mode not in MODES:
        raise ValueError(
            f"use_kernels must be one of {MODES}, got {mode!r}")
    if backend is None:
        backend = jax.default_backend()
    on_tpu = backend == "tpu"
    use = on_tpu if mode == "auto" else (mode == "on")
    return KernelDecision(use=use, interpret=not on_tpu)


def interpret_default(backend: Optional[str] = None) -> bool:
    """The interpret flag a kernel op should use when called directly
    without a resolved decision (tests, benchmarks)."""
    return resolve("on", backend=backend).interpret


def override_mode(cfg, mode: str):
    """Propagate a driver-level ``use_kernels`` onto a sub-config.

    ``"auto"`` (the driver default) defers to whatever the sub-config
    says; an explicit ``"on"``/``"off"`` wins. Returns ``cfg`` itself
    when nothing changes so config identity (jit static hashing) is
    preserved on the common path.
    """
    if mode not in MODES:
        raise ValueError(
            f"use_kernels must be one of {MODES}, got {mode!r}")
    if mode == "auto" or cfg.use_kernels == mode:
        return cfg
    return dataclasses.replace(cfg, use_kernels=mode)
