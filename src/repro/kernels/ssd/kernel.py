"""Mamba-2 SSD intra-chunk Pallas kernel.

Fuses the per-chunk work of the SSD algorithm — cumulative log-decay,
the (L×L) decay·CBᵀ gating matrix, the masked (L×L)·(L×P) output matmul,
and the (N×L)·(L×P) chunk-state reduction — into one VMEM-resident block.
The (cheap, O(T/L)-step) inter-chunk recurrence and the off-diagonal
correction stay in XLA (``ops.py``), which is the right split on TPU: the
MXU does the L² work; the serial scan is latency-bound either way.

Grid = (B, H, num_chunks). VMEM per step at L=128, P=64, N=128:
x(L·P) + b/c(2·L·N) + decay(L·L) + cb(L·L) + y(L·P) + state(P·N) fp32
≈ 0.36 MB — comfortably double-bufferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams



def _ssd_chunk_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, st_ref, cd_ref, *,
                      chunk: int):
    """One (batch, head, chunk) cell.

    x_ref:  (1, L, 1, P)  dt-weighted inputs
    la_ref: (1, L, 1)     per-step log decay (dt·a)
    b_ref:  (1, L, N)     input projection
    c_ref:  (1, L, N)     output projection
    y_ref:  (1, L, 1, P)  intra-chunk output
    st_ref: (1, 1, 1, P, N) chunk-end state contribution
    cd_ref: (1, 1, 1)     total chunk decay exp(cs_L)
    """
    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (L, P)
    la = la_ref[0, :, 0].astype(jnp.float32)             # (L,)
    b = b_ref[0].astype(jnp.float32)                     # (L, N)
    c = c_ref[0].astype(jnp.float32)                     # (L, N)

    cs = jnp.cumsum(la)                                  # (L,)
    seg = cs[:, None] - cs[None, :]                      # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = li >= lj
    decay = jnp.where(tri, jnp.exp(seg), 0.0)            # (L, L)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    y = jax.lax.dot_general(cb * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # chunk state: S = Σ_j exp(cs_L - cs_j) b_j x_j^T  -> (P, N)
    w = jnp.exp(cs[-1] - cs)                             # (L,)
    st = jax.lax.dot_general(x, b * w[:, None],
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    st_ref[0, 0, 0] = st
    cd_ref[0, 0, 0] = jnp.exp(cs[-1])


def ssd_intra_chunk(xw, la, b, c, *, chunk: int, interpret: bool = True):
    """xw: (B, T, H, P) dt-weighted inputs; la: (B, T, H) log decays;
    b, c: (B, T, N). Returns (y_diag (B,T,H,P), states (B,nc,H,P,N),
    chunk_decay (B,nc,H), cum_logdecay (B,nc,H,L))."""
    bsz, t, h, p = xw.shape
    n = b.shape[-1]
    nc = t // chunk

    grid = (bsz, h, nc)
    y, st, cd = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, hi, ci: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ci: (bi, ci, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, h, p), xw.dtype),
            jax.ShapeDtypeStruct((bsz, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xw, la, b, c)
    return y, st, cd
