"""Public SSD op: Pallas intra-chunk kernel + XLA inter-chunk recurrence."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as k_mod


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, *, chunk: int = 128, initial_state=None,
        interpret: Optional[bool] = None):
    """Same contract as :func:`repro.nn.ssm.ssd_chunked`."""
    if interpret is None:
        interpret = not _on_tpu()
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    nc = t // chunk

    la = dt * a[None, None, :]                           # (B, T, H)
    xw = x * dt[..., None].astype(x.dtype)

    y_diag, states, chunk_decay = k_mod.ssd_intra_chunk(
        xw, la, b, c, chunk=chunk, interpret=interpret)

    # inter-chunk recurrence (serial over nc — latency-bound, stays in XLA)
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(s, inp):
        st, dec = inp
        prev = s
        s = s * dec[..., None, None] + st
        return s, prev

    st_t = jnp.moveaxis(states, 1, 0)                    # (nc, B, H, P, N)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)              # (nc, B, H)
    final, prev_states = jax.lax.scan(step, initial_state.astype(jnp.float32),
                                      (st_t, dec_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B, nc, H, P, N)

    # off-diagonal output: y_i += C_i · S_prev · exp(cs_i)
    lac = la.reshape(bsz, nc, chunk, h)
    cs = jnp.cumsum(jnp.moveaxis(lac, -1, 2), axis=-1)   # (B, nc, H, L)
    out_decay = jnp.exp(cs)
    cc = c.reshape(bsz, nc, chunk, n)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", cc.astype(jnp.float32),
                       prev_states, out_decay)
    y = y_diag.astype(jnp.float32) + y_off.reshape(bsz, t, h, p)
    return y.astype(x.dtype), final
