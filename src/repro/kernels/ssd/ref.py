"""Pure-jnp oracle for the SSD scan — delegates to the substrate's chunked
implementation (itself validated against the step-recurrent decode form)."""
from __future__ import annotations

from repro.nn import ssm as ssm_mod


def ssd(x, dt, a, b, c, *, chunk: int = 128, initial_state=None):
    """x: (B,T,H,P); dt: (B,T,H); a: (H,); b,c: (B,T,N)."""
    return ssm_mod.ssd_chunked(x, dt, a, b, c, chunk=chunk,
                               initial_state=initial_state)
