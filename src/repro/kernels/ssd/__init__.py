from repro.kernels.ssd import kernel, ops, ref  # noqa: F401
