"""Public GRU sequence op matching repro.nn.gru.gru_sequence's contract."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.gru import kernel as k_mod


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def gru_sequence(params, xs, h0=None, *, reset_mask=None,
                 interpret: Optional[bool] = None):
    """xs: (B, T, in) -> (hs (B, T, H), h_last (B, H))."""
    if interpret is None:
        interpret = not _on_tpu()
    b, t, _ = xs.shape
    hdim = params["wh"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((b, hdim), jnp.float32)
    # big input matmul outside the kernel (one MXU pass over all steps)
    gi = (jnp.einsum("bti,ij->btj", xs.astype(jnp.float32),
                     params["wi"].astype(jnp.float32))
          + params["bi"].astype(jnp.float32))
    gi = jnp.moveaxis(gi, 1, 0)                           # (T, B, 3H)
    if reset_mask is None:
        resets = jnp.zeros((t, b, 1), jnp.float32)
    else:
        resets = jnp.moveaxis(reset_mask, 1, 0)[..., None] \
            .astype(jnp.float32)
    hs = k_mod.gru_scan(gi, params["wh"].astype(jnp.float32),
                        params["bh"].astype(jnp.float32),
                        h0.astype(jnp.float32), resets, interpret=interpret)
    hs = jnp.moveaxis(hs, 0, 1).astype(xs.dtype)          # (B, T, H)
    return hs, hs[:, -1].astype(h0.dtype)
