"""Public GRU sequence op matching repro.nn.gru.gru_sequence's contract.

Dtype contract: ``hs`` and ``h_last`` come back in the ORACLE's output
dtype — ``h0.dtype`` when an initial state is given, else ``xs.dtype``
(the oracle threads the hidden state through ``astype(h.dtype)``) — the
kernel computes in fp32 internally but no longer silently upcasts the
caller.

``interpret`` is a concrete bool resolved by ``repro.kernels.dispatch``
(default: interpret everywhere but TPU); it is NOT a jit static argument
here — each (kernel, interpret) pair is built exactly once via the
``lru_cache`` in ``kernel.py``, so there is no per-call static recompile.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.gru import kernel as k_mod


def gru_cell(params, h, x, *, interpret: Optional[bool] = None):
    """One recurrent step matching ``repro.nn.gru.gru_cell``'s contract:
    h (B, H), x (B, in) -> new h in ``h.dtype``. Runs the fused scan
    kernel at T=1 (gate matmuls + nonlinearities + state update in one
    pallas_call) — the GS/LS rollout policy step's fast path, so the
    single-step call sites stop being the one oracle-only GRU path."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    gi = (x.astype(jnp.float32) @ params["wi"].astype(jnp.float32)
          + params["bi"].astype(jnp.float32))[None]           # (1, B, 3H)
    resets = jnp.zeros((1, x.shape[0], 1), jnp.float32)
    hs = k_mod.gru_scan(gi, params["wh"].astype(jnp.float32),
                        params["bh"].astype(jnp.float32),
                        h.astype(jnp.float32), resets,
                        interpret=bool(interpret))
    return hs[0].astype(h.dtype)


def gru_sequence(params, xs, h0=None, *, reset_mask=None,
                 interpret: Optional[bool] = None):
    """xs: (B, T, in) -> (hs (B, T, H), h_last (B, H)). Differentiable
    w.r.t. params/xs/h0 through the Pallas backward-scan kernel."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    out_dtype = h0.dtype if h0 is not None else xs.dtype
    b, t, _ = xs.shape
    hdim = params["wh"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((b, hdim), jnp.float32)
    # big input matmul outside the kernel (one MXU pass over all steps)
    gi = (jnp.einsum("bti,ij->btj", xs.astype(jnp.float32),
                     params["wi"].astype(jnp.float32))
          + params["bi"].astype(jnp.float32))
    gi = jnp.moveaxis(gi, 1, 0)                           # (T, B, 3H)
    if reset_mask is None:
        resets = jnp.zeros((t, b, 1), jnp.float32)
    else:
        resets = jnp.moveaxis(reset_mask, 1, 0)[..., None] \
            .astype(jnp.float32)
    hs = k_mod.gru_scan(gi, params["wh"].astype(jnp.float32),
                        params["bh"].astype(jnp.float32),
                        h0.astype(jnp.float32), resets,
                        interpret=bool(interpret))
    hs = jnp.moveaxis(hs, 0, 1).astype(out_dtype)         # (B, T, H)
    return hs, hs[:, -1]
