"""GRU sequence Pallas kernel — the AIP / recurrent-policy hot spot.

The input-side gate matmul (x_t · W_i for all t) is one big MXU-friendly
batched matmul done OUTSIDE the kernel by XLA. The kernel fuses what XLA
handles poorly: the strictly sequential per-step recurrent matmul
h·W_h (B×H · H×3H on the MXU) plus the gate nonlinearities and state
update, keeping h and W_h resident in VMEM across all T steps (grid
iterates over T with "arbitrary" semantics; h lives in scratch, W_h is
re-fetched from the same block every step so it stays cached).

VMEM at B=256, H=128: h(B·H) + gi(B·3H) + Wh(H·3H) fp32 ≈ 0.7 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams



def _gru_kernel(gi_ref, wh_ref, bh_ref, reset_ref, h0_ref, hs_ref, h_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    h = h_ref[...]                                        # (B, H)
    m = reset_ref[0]                                      # (B, 1)
    h = h * (1.0 - m)
    gh = jax.lax.dot_general(h, wh_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        + bh_ref[...]                                     # (B, 3H)
    gi = gi_ref[0]                                        # (B, 3H)
    hdim = h.shape[-1]
    i_r, i_z, i_n = gi[:, :hdim], gi[:, hdim:2 * hdim], gi[:, 2 * hdim:]
    h_r, h_z, h_n = gh[:, :hdim], gh[:, hdim:2 * hdim], gh[:, 2 * hdim:]
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    new_h = (1.0 - z) * n + z * h
    h_ref[...] = new_h
    hs_ref[0] = new_h.astype(hs_ref.dtype)


def gru_scan(gi, wh, bh, h0, resets, *, interpret: bool = True):
    """gi: (T, B, 3H) precomputed x·W_i + b_i (fp32); wh: (H, 3H);
    bh: (3H,); h0: (B, H); resets: (T, B, 1). Returns hs (T, B, H)."""
    t, bsz, h3 = gi.shape
    hdim = h3 // 3
    return pl.pallas_call(
        _gru_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, bsz, h3), lambda ti: (ti, 0, 0)),
            pl.BlockSpec((hdim, h3), lambda ti: (0, 0)),
            pl.BlockSpec((h3,), lambda ti: (0,)),
            pl.BlockSpec((1, bsz, 1), lambda ti: (ti, 0, 0)),
            pl.BlockSpec((bsz, hdim), lambda ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bsz, hdim), lambda ti: (ti, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, bsz, hdim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bsz, hdim), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(gi, wh, bh, resets, h0)
