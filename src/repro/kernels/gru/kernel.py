"""GRU sequence Pallas kernels — the AIP / recurrent-policy hot spot.

Forward: the input-side gate matmul (x_t · W_i for all t) is one big
MXU-friendly batched matmul done OUTSIDE the kernel by XLA. The kernel
fuses what XLA handles poorly: the strictly sequential per-step recurrent
matmul h·W_h (B×H · H×3H on the MXU) plus the gate nonlinearities and
state update, keeping h and W_h resident in VMEM across all T steps
(grid iterates over T with "arbitrary" semantics; h lives in scratch,
W_h is re-fetched from the same block every step so it stays cached).

Backward: :func:`gru_scan` carries a ``jax.custom_vjp`` whose reverse
pass is a second Pallas kernel walking the grid T-1→0 (reverse-indexed
BlockSpec maps). Gates are RECOMPUTED from the saved forward inputs and
hidden states rather than stashed — one extra h·W_h per step buys not
materialising (r, z, n) for all T. The adjoint carry dh, the weight
accumulator dW_h, and the bias accumulator db_h all stay resident in
VMEM across the whole scan; per-step gate gradients stream out as dgi,
which XLA then turns into dx/dW_i through the outer matmul's own VJP.

VMEM at B=256, H=128: h(B·H) + gi(B·3H) + Wh(H·3H) fp32 ≈ 0.7 MB
forward; backward adds the dWh/dbh accumulators (+0.2 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _gates(gi, gh, hdim):
    """Shared gate math: returns (r, z, n) from input/recurrent halves."""
    i_r, i_z, i_n = gi[:, :hdim], gi[:, hdim:2 * hdim], gi[:, 2 * hdim:]
    h_r, h_z, h_n = gh[:, :hdim], gh[:, hdim:2 * hdim], gh[:, 2 * hdim:]
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    return r, z, n, h_n


def _gru_kernel(gi_ref, wh_ref, bh_ref, reset_ref, h0_ref, hs_ref, h_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    h = h_ref[...]                                        # (B, H)
    m = reset_ref[0]                                      # (B, 1)
    h = h * (1.0 - m)
    gh = jax.lax.dot_general(h, wh_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        + bh_ref[...]                                     # (B, 3H)
    r, z, n, _h_n = _gates(gi_ref[0], gh, h.shape[-1])
    new_h = (1.0 - z) * n + z * h
    h_ref[...] = new_h
    hs_ref[0] = new_h.astype(hs_ref.dtype)


def _gru_forward(gi, wh, bh, h0, resets, interpret: bool):
    t, bsz, h3 = gi.shape
    hdim = h3 // 3
    return pl.pallas_call(
        _gru_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, bsz, h3), lambda ti: (ti, 0, 0)),
            pl.BlockSpec((hdim, h3), lambda ti: (0, 0)),
            pl.BlockSpec((h3,), lambda ti: (0,)),
            pl.BlockSpec((1, bsz, 1), lambda ti: (ti, 0, 0)),
            pl.BlockSpec((bsz, hdim), lambda ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bsz, hdim), lambda ti: (ti, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, bsz, hdim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bsz, hdim), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(gi, wh, bh, resets, h0)


def _gru_bwd_kernel(gi_ref, hprev_ref, reset_ref, wh_ref, bh_ref, g_ref,
                    dgi_ref, dwh_ref, dbh_ref, dh0_ref, dh_ref):
    """One reverse-time step: grid index t visits actual time T-1-t
    (through the BlockSpec index maps). dh_ref carries the hidden-state
    adjoint; dwh/dbh accumulate in their (constant-index) output blocks.
    """
    t = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        dh_ref[...] = jnp.zeros_like(dh_ref)
        dwh_ref[...] = jnp.zeros_like(dwh_ref)
        dbh_ref[...] = jnp.zeros_like(dbh_ref)

    m = reset_ref[0]                                      # (B, 1)
    hp = hprev_ref[0] * (1.0 - m)                         # masked h_{t-1}
    gh = jax.lax.dot_general(hp, wh_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        + bh_ref[...]
    r, z, n, h_n = _gates(gi_ref[0], gh, hp.shape[-1])

    d = g_ref[0] + dh_ref[...]          # total adjoint on h_t
    dn = d * (1.0 - z)
    dz = d * (hp - n)
    dhp = d * z
    da_n = dn * (1.0 - n * n)
    dr = da_n * h_n
    da_z = dz * z * (1.0 - z)
    da_r = dr * r * (1.0 - r)
    dgi_ref[0] = jnp.concatenate([da_r, da_z, da_n], axis=-1)
    dgh = jnp.concatenate([da_r, da_z, da_n * r], axis=-1)
    dhp = dhp + jax.lax.dot_general(
        dgh, wh_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    dwh_ref[...] += jax.lax.dot_general(
        hp, dgh, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dbh_ref[...] += dgh.sum(axis=0)
    dh_ref[...] = dhp * (1.0 - m)       # adjoint on h_{t-1}

    @pl.when(t == nt - 1)
    def _final():
        dh0_ref[...] = dh_ref[...]


def _gru_backward(gi, wh, bh, h0, resets, hs, g, interpret: bool):
    t, bsz, h3 = gi.shape
    hdim = h3 // 3
    # h_{t-1} for every step: [h0, hs[0], ..., hs[T-2]]
    hprev = jnp.concatenate([h0[None], hs[:-1]], axis=0)
    rev3 = lambda ti: (t - 1 - ti, 0, 0)
    const2 = lambda ti: (0, 0)
    return pl.pallas_call(
        _gru_bwd_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, bsz, h3), rev3),             # gi
            pl.BlockSpec((1, bsz, hdim), rev3),           # hprev
            pl.BlockSpec((1, bsz, 1), rev3),              # resets
            pl.BlockSpec((hdim, h3), const2),             # wh
            pl.BlockSpec((h3,), lambda ti: (0,)),         # bh
            pl.BlockSpec((1, bsz, hdim), rev3),           # g (dL/dhs)
        ],
        out_specs=[
            pl.BlockSpec((1, bsz, h3), rev3),             # dgi
            pl.BlockSpec((hdim, h3), const2),             # dwh
            pl.BlockSpec((h3,), lambda ti: (0,)),         # dbh
            pl.BlockSpec((bsz, hdim), const2),            # dh0
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, bsz, h3), jnp.float32),
            jax.ShapeDtypeStruct((hdim, h3), jnp.float32),
            jax.ShapeDtypeStruct((h3,), jnp.float32),
            jax.ShapeDtypeStruct((bsz, hdim), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bsz, hdim), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(gi, hprev, resets, wh, bh, g)


@functools.lru_cache(maxsize=None)
def _gru_scan_with_vjp(interpret: bool):
    """Build the differentiable scan once per interpret flag — the flag
    never enters a jit static argument, so there is exactly one compile
    per (shape, interpret) pair process-wide."""

    @jax.custom_vjp
    def scan_fn(gi, wh, bh, h0, resets):
        return _gru_forward(gi, wh, bh, h0, resets, interpret)

    def fwd(gi, wh, bh, h0, resets):
        hs = _gru_forward(gi, wh, bh, h0, resets, interpret)
        return hs, (gi, wh, bh, h0, resets, hs)

    def bwd(res, g):
        gi, wh, bh, h0, resets, hs = res
        dgi, dwh, dbh, dh0 = _gru_backward(
            gi, wh, bh, h0, resets, hs, g, interpret)
        return dgi, dwh, dbh, dh0, jnp.zeros_like(resets)

    scan_fn.defvjp(fwd, bwd)
    return scan_fn


def gru_scan(gi, wh, bh, h0, resets, *, interpret: bool = True):
    """gi: (T, B, 3H) precomputed x·W_i + b_i (fp32); wh: (H, 3H);
    bh: (3H,); h0: (B, H); resets: (T, B, 1). Returns hs (T, B, H).
    Differentiable w.r.t. (gi, wh, bh, h0) through the Pallas backward
    kernel; resets receive a zero cotangent (they are data, not weights).
    """
    return _gru_scan_with_vjp(bool(interpret))(gi, wh, bh, h0, resets)
