"""Pure-jnp GRU oracle — the substrate's lax.scan implementation."""
from __future__ import annotations

from repro.nn import gru as gru_mod


def gru_sequence(params, xs, h0=None, *, reset_mask=None):
    return gru_mod.gru_sequence(params, xs, h0, reset_mask=reset_mask)
