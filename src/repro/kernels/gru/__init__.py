from repro.kernels.gru import kernel, ops, ref  # noqa: F401
