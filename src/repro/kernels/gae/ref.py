"""Pure-jnp GAE oracle — the substrate's reverse lax.scan."""
from __future__ import annotations

from repro.marl import gae as gae_mod


def gae(rewards, values, dones, last_value, *, gamma=0.99, lam=0.95):
    return gae_mod.gae(rewards, values, dones, last_value,
                       gamma=gamma, lam=lam)
