"""GAE-λ reverse-scan Pallas kernel.

The advantage recursion is strictly sequential in t but embarrassingly
parallel over the (agents × envs) batch — on TPU that maps to a grid over
T (reverse-indexed through the BlockSpec index map, so block t reads slice
T-1-t) with the carry in VMEM scratch and the batch laid out on the
8×128 VPU lanes. One fused multiply-add per step instead of a scan of
tiny XLA kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams



def _gae_kernel(r_ref, v_ref, nv_ref, d_ref, adv_ref, carry_ref, *,
                gamma: float, lam: float):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    r, v, nv, d = r_ref[0], v_ref[0], nv_ref[0], d_ref[0]   # (B,)
    nd = 1.0 - d
    delta = r + gamma * nv * nd - v
    adv = delta + gamma * lam * nd * carry_ref[...]
    carry_ref[...] = adv
    adv_ref[0] = adv


def gae_reverse_scan(rewards, values, next_values, dones, *,
                     gamma: float, lam: float, interpret: bool = True):
    """All inputs (T, B) fp32, time-major. Returns advantages (T, B)."""
    t, b = rewards.shape
    rev = lambda ti: (t - 1 - ti, 0)       # reverse time through index map
    spec = pl.BlockSpec((1, b), rev)
    return pl.pallas_call(
        functools.partial(_gae_kernel, gamma=gamma, lam=lam),
        grid=(t,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((t, b), jnp.float32),
        scratch_shapes=[pltpu.VMEM((b,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(rewards, values, next_values, dones)
