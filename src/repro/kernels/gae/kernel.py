"""GAE-λ reverse-scan Pallas kernels.

Forward: the advantage recursion is strictly sequential in t but
embarrassingly parallel over the (agents × envs) batch — on TPU that
maps to a grid over T (reverse-indexed through the BlockSpec index map,
so block t reads slice T-1-t) with the carry in VMEM scratch and the
batch laid out on the 8×128 VPU lanes. One fused multiply-add per step
instead of a scan of tiny XLA kernels.

Backward: the recursion is LINEAR in (r, v, nv), so the adjoint is the
transposed recurrence — a FORWARD-time scan of the advantage cotangent
ā_t = g_t + γλ(1-d_{t-1})·ā_{t-1}, from which every input cotangent is
elementwise: dr = ā, dv = -ā, dnv = γ(1-d)·ā. :func:`gae_reverse_scan`
carries a ``jax.custom_vjp`` running that adjoint as a second Pallas
kernel (no residuals beyond the dones mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _gae_kernel(r_ref, v_ref, nv_ref, d_ref, adv_ref, carry_ref, *,
                gamma: float, lam: float):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    r, v, nv, d = r_ref[0], v_ref[0], nv_ref[0], d_ref[0]   # (B,)
    nd = 1.0 - d
    delta = r + gamma * nv * nd - v
    adv = delta + gamma * lam * nd * carry_ref[...]
    carry_ref[...] = adv
    adv_ref[0] = adv


def _gae_forward(rewards, values, next_values, dones, *,
                 gamma: float, lam: float, interpret: bool):
    t, b = rewards.shape
    rev = lambda ti: (t - 1 - ti, 0)       # reverse time through index map
    spec = pl.BlockSpec((1, b), rev)
    return pl.pallas_call(
        functools.partial(_gae_kernel, gamma=gamma, lam=lam),
        grid=(t,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((t, b), jnp.float32),
        scratch_shapes=[pltpu.VMEM((b,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(rewards, values, next_values, dones)


def _gae_bwd_kernel(g_ref, d_ref, dr_ref, dnv_ref, carry_ref, *,
                    gamma: float, lam: float):
    """Adjoint step, forward in time. carry holds γλ(1-d_{t-1})·ā_{t-1}."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    g, d = g_ref[0], d_ref[0]                               # (B,)
    nd = 1.0 - d
    abar = g + carry_ref[...]
    dr_ref[0] = abar
    dnv_ref[0] = gamma * nd * abar
    carry_ref[...] = gamma * lam * nd * abar


def _gae_backward(g, dones, *, gamma: float, lam: float, interpret: bool):
    t, b = g.shape
    spec = pl.BlockSpec((1, b), lambda ti: (ti, 0))         # forward time
    return pl.pallas_call(
        functools.partial(_gae_bwd_kernel, gamma=gamma, lam=lam),
        grid=(t,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((t, b), jnp.float32),
                   jax.ShapeDtypeStruct((t, b), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((b,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(g, dones)


@functools.lru_cache(maxsize=None)
def _gae_scan_with_vjp(gamma: float, lam: float, interpret: bool):
    @jax.custom_vjp
    def scan_fn(rewards, values, next_values, dones):
        return _gae_forward(rewards, values, next_values, dones,
                            gamma=gamma, lam=lam, interpret=interpret)

    def fwd(rewards, values, next_values, dones):
        adv = _gae_forward(rewards, values, next_values, dones,
                           gamma=gamma, lam=lam, interpret=interpret)
        return adv, dones

    def bwd(dones, g):
        dr, dnv = _gae_backward(g, dones, gamma=gamma, lam=lam,
                                interpret=interpret)
        return dr, -dr, dnv, jnp.zeros_like(dones)

    scan_fn.defvjp(fwd, bwd)
    return scan_fn


def gae_reverse_scan(rewards, values, next_values, dones, *,
                     gamma: float, lam: float, interpret: bool = True):
    """All inputs (T, B) fp32, time-major. Returns advantages (T, B).
    Differentiable w.r.t. (rewards, values, next_values) through the
    linear-adjoint Pallas kernel; dones get a zero cotangent."""
    return _gae_scan_with_vjp(float(gamma), float(lam), bool(interpret))(
        rewards, values, next_values, dones)
