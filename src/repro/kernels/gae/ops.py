"""Public GAE op matching repro.marl.gae.gae's contract.

``interpret`` is a concrete bool resolved by ``repro.kernels.dispatch``
(default: interpret everywhere but TPU), not a jit static argument —
each (gamma, lam, interpret) kernel is built once via the ``lru_cache``
in ``kernel.py``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.gae import kernel as k_mod


def gae(rewards, values, dones, last_value, *, gamma: float = 0.99,
        lam: float = 0.95, interpret: Optional[bool] = None):
    """rewards/values/dones: (..., T); last_value: (...,). Differentiable
    w.r.t. rewards/values/last_value through the adjoint Pallas kernel."""
    if interpret is None:
        interpret = dispatch.interpret_default()
    shape = rewards.shape
    t = shape[-1]
    flat = lambda x: jnp.moveaxis(
        x.reshape(-1, t).astype(jnp.float32), 1, 0)       # (T, B)
    rw, vl, dn = flat(rewards), flat(values), flat(dones)
    nv = jnp.concatenate(
        [vl[1:], last_value.reshape(1, -1).astype(jnp.float32)], axis=0)
    adv = k_mod.gae_reverse_scan(rw, vl, nv, dn, gamma=gamma, lam=lam,
                                 interpret=bool(interpret))
    # the scan runs in f32; cast back so reduced-precision inputs do
    # not silently widen through the kernel path (DtypeRoundTrip)
    adv = jnp.moveaxis(adv, 0, 1).reshape(shape).astype(values.dtype)
    return adv, adv + values
