"""Public GAE op matching repro.marl.gae.gae's contract."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.gae import kernel as k_mod


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("gamma", "lam", "interpret"))
def gae(rewards, values, dones, last_value, *, gamma: float = 0.99,
        lam: float = 0.95, interpret: Optional[bool] = None):
    """rewards/values/dones: (..., T); last_value: (...,)."""
    if interpret is None:
        interpret = not _on_tpu()
    shape = rewards.shape
    t = shape[-1]
    flat = lambda x: jnp.moveaxis(
        x.reshape(-1, t).astype(jnp.float32), 1, 0)       # (T, B)
    rw, vl, dn = flat(rewards), flat(values), flat(dones)
    nv = jnp.concatenate(
        [vl[1:], last_value.reshape(1, -1).astype(jnp.float32)], axis=0)
    adv = k_mod.gae_reverse_scan(rw, vl, nv, dn, gamma=gamma, lam=lam,
                                 interpret=interpret)
    adv = jnp.moveaxis(adv, 0, 1).reshape(shape)
    return adv, adv + values
