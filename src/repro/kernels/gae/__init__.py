from repro.kernels.gae import kernel, ops, ref  # noqa: F401
