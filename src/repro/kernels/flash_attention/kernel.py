"""Flash attention (Dao et al.) — Pallas TPU kernel.

Blockwise online-softmax attention. Grid = (batch·heads, num_q_blocks,
num_k_blocks); the k dimension is the innermost, sequentially-iterated
("arbitrary") axis, carrying the running max / normalizer / accumulator in
VMEM scratch — the canonical TPU flash pattern. Block shapes default to
(128, 128): MXU-aligned on both matmul dims, and the VMEM working set is
q(128·D) + k(128·D) + v(128·D) + acc(128·D) fp32 ≈ 0.4 MB at D=128, far
under the ~16 MB/core budget, leaving room for double buffering.

GQA is handled in the index maps: the kv grid row is h // group — repeated
K/V heads are never materialized. Causal and sliding-window masks skip
fully-masked k-blocks with ``pl.when`` (no MXU work issued).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, sliding_window: Optional[int],
                 softcap: Optional[float], block_q: int, block_k: int,
                 num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # Is any element of this (q-block, k-block) pair unmasked?
    q_max = qi * block_q + block_q - 1
    k_min = ki * block_k
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, k_min <= q_max)
    if sliding_window is not None:
        k_max = ki * block_k + block_k - 1
        q_min = qi * block_q
        relevant = jnp.logical_and(relevant, k_max > q_min - sliding_window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (bq, D)
        k = k_ref[0].astype(jnp.float32)                     # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if sliding_window is not None:
            mask &= k_pos > q_pos - sliding_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)                     # (bk, D)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]) \
            .astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         sliding_window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: (BH, Tq, D); k, v: (BH_kv, Tk, D) with BH = BH_kv · group.

    The caller flattens batch×heads; GQA group = BH // BH_kv.
    """
    bh, tq, d = q.shape
    bh_kv, tk, _ = k.shape
    group = bh // bh_kv
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    nq = pl.cdiv(tq, block_q)
    nk = pl.cdiv(tk, block_k)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, softcap=softcap,
        block_q=block_q, block_k=block_k, num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
