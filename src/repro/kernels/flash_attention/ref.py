"""Pure-jnp oracle for flash attention: masked softmax attention with GQA,
causal / sliding-window masks and logit softcap — delegates to the
substrate's :func:`repro.nn.attention.attend` (itself oracle-tested against
decode)."""
from __future__ import annotations

from typing import Optional

from repro.nn import attention as attn_mod


def attention(q, k, v, *, causal: bool = True,
              sliding_window: Optional[int] = None,
              softcap: Optional[float] = None):
    """q: (B, T, H, D); k, v: (B, T, Hkv, D) -> (B, T, H, D)."""
    return attn_mod.attend(q, k, v, causal=causal,
                           sliding_window=sliding_window, softcap=softcap)
