"""Public flash-attention op: (B, T, H, D) layout, GQA, jit-friendly."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as k_mod


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    sliding_window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, T, H, D); k, v: (B, T, Hkv, D) -> (B, T, H, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, tq, h, d = q.shape
    hkv = k.shape[2]
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, tq, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * hkv, k.shape[1], d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * hkv, v.shape[1], d)
    # GQA index math in the kernel assumes head-major flattening per batch:
    # row b*h + i maps to kv row b*hkv + i//group, which equals (b*h+i)//group
    # only when flattened batch-major. Reorder so heads vary fastest.
    out = k_mod.flash_attention_bhsd(
        qf, kf, vf, causal=causal, sliding_window=sliding_window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return jnp.moveaxis(out.reshape(b, h, tq, d), 1, 2)
