"""Deterministic synthetic data sources.

LM pretraining corpora are out of scope on an offline CPU box, so training
drivers use a *structured* synthetic stream: a Zipf-distributed unigram
background plus an order-2 Markov overlay, which gives a non-trivial,
learnable next-token distribution (loss decreases measurably within a few
hundred steps — used by the e2e examples and convergence tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zipf_logits(vocab: int, alpha: float = 1.1) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def lm_batch(key, batch: int, seq: int, vocab: int, *, alpha: float = 1.1):
    """Returns dict(tokens, labels) with labels = next-token shift.

    Tokens follow zipf(alpha) with a deterministic "grammar": every even
    position is followed by (t*7+3) % vocab with prob 1/2 — a structure a
    model can learn, so loss curves are meaningful.
    """
    k1, k2 = jax.random.split(key)
    base = jax.random.categorical(k1, zipf_logits(vocab)[None, None, :],
                                  shape=(batch, seq + 1))
    succ = (base * 7 + 3) % vocab
    coin = jax.random.bernoulli(k2, 0.5, (batch, seq + 1))
    toks = base.at[:, 1::2].set(
        jnp.where(coin[:, 1::2], succ[:, 0:seq:2][:, :base[:, 1::2].shape[1]],
                  base[:, 1::2]))
    return {"tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32)}


def frames(key, batch: int, n_frames: int, dim: int, dtype=jnp.bfloat16):
    """Stub audio-frontend output (whisper): smooth random embeddings."""
    x = jax.random.normal(key, (batch, n_frames, dim), jnp.float32)
    x = (x + jnp.roll(x, 1, axis=1) + jnp.roll(x, 2, axis=1)) / 3.0
    return x.astype(dtype)


def patches(key, batch: int, n_patches: int, dim: int, dtype=jnp.bfloat16):
    """Stub vision-tower output (llama-vision): patch embeddings."""
    return jax.random.normal(key, (batch, n_patches, dim), jnp.float32) \
        .astype(dtype)
