"""Sharded host→device feeding.

On a real multi-host pod each process feeds its addressable shard via
``jax.make_array_from_process_local_data``; on a single host this reduces
to ``device_put`` with the global batch sharding. The iterator is
deterministic in (seed, step) so restarts resume mid-epoch without
re-reading earlier data (checkpoint stores only the step).
"""
from __future__ import annotations

from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.data import synthetic
from repro.distributed import mesh as mesh_lib


def shard_batch(batch, mesh, *, long_context: bool = False):
    sh = mesh_lib.batch_sharding(mesh, long_context=long_context)
    def put(x):
        spec = sh.spec
        # pad spec to rank
        full = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                *(list(spec) + [None] * (x.ndim - len(spec)))))
        return jax.device_put(x, full)
    return jax.tree.map(put, batch)


def lm_iterator(seed: int, batch: int, seq: int, vocab: int,
                mesh=None, *, start_step: int = 0) -> Iterator[dict]:
    """Deterministic in (seed, step): restart-safe."""
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        b = synthetic.lm_batch(key, batch, seq, vocab)
        if mesh is not None:
            b = shard_batch(b, mesh)
        yield b
        step += 1


def with_extras(it: Iterator[dict], extra_fn: Callable[[int], dict],
                start_step: int = 0) -> Iterator[dict]:
    """Attach modality extras (frames/patches) to each LM batch."""
    step = start_step
    for b in it:
        yield {**b, **extra_fn(step)}
        step += 1
