"""Data pipeline: deterministic synthetic sources + sharded device feeding."""
from repro.data import pipeline, synthetic  # noqa: F401
