"""DIALS-outer optimizer: the paper's pattern at the pod level.

The paper's core move — run local regions independently, reconcile through
a compact coupling channel only every ``F`` steps, tolerate staleness in
between (Lemma 2 / Theorem 1 bound the cost) — is exactly the structure of
semi-synchronous multi-pod training. Each *pod* is a "local region": it
runs ``F`` inner AdamW steps with **zero cross-pod collectives**; every
``F`` steps the pods exchange the parameter *delta* (optionally int8-
compressed with error feedback) and apply a Nesterov outer step
(DiLoCo-style). This is what the ``pod`` mesh axis buys in the multi-pod
dry-run: inner ``train_step`` has no collective on ``pod`` at all.

Staleness knob ``F`` plays the same role as the AIP refresh frequency in
Algorithm 1 — and the same theory argues small/infrequent reconciliation
can *help* by keeping each pod's objective stationary between syncs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import compress as comp


@dataclasses.dataclass(frozen=True)
class OuterConfig:
    outer_lr: float = 0.7
    momentum: float = 0.9
    nesterov: bool = True
    sync_every: int = 50             # F, in inner steps
    compress_int8: bool = True       # shrink the only cross-pod collective 4x


def init(params):
    f32 = lambda p: p.astype(jnp.float32)
    state = {
        "anchor": jax.tree.map(f32, params),      # params at last sync
        "velocity": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params),
    }
    return state


def outer_step(local_params, state, cfg: OuterConfig, *,
               pod_axis: Optional[str] = None, err_tree=None):
    """Reconcile after F inner steps.

    delta_i = anchor - local_i; pod-mean(delta) (the only cross-pod
    collective, int8 if configured); Nesterov outer update on the anchor;
    every pod restarts from the new anchor. Returns
    (new_params, new_state, new_err_tree).
    """
    anchor, vel = state["anchor"], state["velocity"]
    delta = jax.tree.map(
        lambda a, p: a - p.astype(jnp.float32), anchor, local_params)

    if cfg.compress_int8:
        if err_tree is None:
            err_tree = comp.init_error(delta)
        q, s, err_tree = comp.tree_compress(delta, err_tree)
        if pod_axis is not None:
            # int8 stays int8 on the wire: all-gather the quantized deltas
            # (+ tiny fp32 scales) across pods, dequantize and mean locally.
            # Wire bytes: n_pods × size × 1B vs ≥4B for an fp32 all-reduce.
            def gather_mean(qq, ss, d):
                qg = jax.lax.all_gather(qq, pod_axis)          # (P, ...)
                sg = jax.lax.all_gather(ss, pod_axis)          # (P, rows)
                deq = jax.vmap(lambda a, b: comp.decompress(a, b, d.shape))(
                    qg, sg)
                return deq.mean(0)
            delta = jax.tree.map(gather_mean, q, s, delta)
        else:
            delta = comp.tree_decompress(q, s, delta)
    elif pod_axis is not None:
        delta = jax.tree.map(lambda d: jax.lax.pmean(d, pod_axis), delta)

    new_vel = jax.tree.map(lambda v, d: cfg.momentum * v + d, vel, delta)
    if cfg.nesterov:
        step_dir = jax.tree.map(lambda v, d: cfg.momentum * v + d,
                                new_vel, delta)
    else:
        step_dir = new_vel
    new_anchor = jax.tree.map(lambda a, s_: a - cfg.outer_lr * s_,
                              anchor, step_dir)
    new_params = jax.tree.map(lambda a, p: a.astype(p.dtype),
                              new_anchor, local_params)
    return new_params, {"anchor": new_anchor, "velocity": new_vel}, err_tree
