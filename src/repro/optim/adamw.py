"""AdamW with fp32 master weights over bf16 params (mixed-precision
training discipline: params/activations bf16, optimizer state fp32).

State layout: ``{"mu", "nu", "master", "step"}`` — ``mu``/``nu``/``master``
are pytrees parallel to params with fp32 leaves, sharded identically to the
params (so FSDP params ⇒ ZeRO-sharded optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # param labels with ndim <= 1 (norms, biases, scalars) skip decay.
    decay_min_ndim: int = 2


def init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        # copy=True: for fp32 params astype would alias the same buffer,
        # and a step that donates both params and opt would then donate
        # one buffer twice (runtime error).
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_logical_specs(param_specs):
    """Optimizer-state logical specs mirror the params."""
    return {"mu": param_specs, "nu": param_specs, "master": param_specs,
            "step": ()}


def update(grads, state, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params_bf16_tree, new_state). ``grads`` may be bf16; all
    moment math is fp32."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * jnp.square(g)
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if m.ndim >= cfg.decay_min_ndim:
            delta = delta + cfg.weight_decay * m
        m = m - lr * delta
        return mu, nu, m

    flat, treedef = jax.tree.flatten(state["mu"])
    gs = jax.tree.leaves(grads)
    nus = jax.tree.leaves(state["nu"])
    ms = jax.tree.leaves(state["master"])
    out = [upd(g, mu, nu, m) for g, mu, nu, m in zip(gs, flat, nus, ms)]
    new_mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_master, {"mu": new_mu, "nu": new_nu, "master": new_master,
                        "step": step}


def cast_like(master, params):
    """Cast fp32 master back to the params' dtypes (bf16)."""
    return jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
