"""int8 gradient compression with error feedback.

Used for the cross-pod reconciliation in the DIALS-outer optimizer: the
pod-to-pod delta all-reduce is the *only* inter-pod collective, so shrinking
it 4× (fp32→int8 + per-row scale) cuts the collective roofline term of the
multi-pod step directly. Error feedback keeps the quantization noise from
biasing convergence (Seide et al., 2014; Karimireddy et al., 2019).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _rowwise(x):
    """Flatten to (rows, cols) for per-row scales; rows = leading dim."""
    if x.ndim == 0:
        return x.reshape(1, 1)
    return x.reshape(x.shape[0], -1)


def compress(x: jax.Array, err: jax.Array):
    """Returns (q int8, scale fp32 (rows,), new_err). err has x's shape."""
    xf = x.astype(jnp.float32) + err
    rows = _rowwise(xf)
    scale = jnp.max(jnp.abs(rows), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(rows / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(xf.shape)
    return q.reshape(x.shape if x.ndim else (1,)), scale[:, 0], xf - deq


def decompress(q: jax.Array, scale: jax.Array, shape):
    rows = _rowwise(q.astype(jnp.float32))
    return (rows * scale[:, None]).reshape(shape)


def tree_compress(tree, err_tree):
    """Compress every leaf; returns (q_tree, scale_tree, new_err_tree)."""
    qs, ss, es = {}, {}, {}
    flat, treedef = jax.tree.flatten(tree)
    errs = jax.tree.leaves(err_tree)
    out = [compress(x, e) for x, e in zip(flat, errs)]
    q = jax.tree.unflatten(treedef, [o[0] for o in out])
    s = jax.tree.unflatten(treedef, [o[1] for o in out])
    e = jax.tree.unflatten(treedef, [o[2] for o in out])
    del qs, ss, es
    return q, s, e


def tree_decompress(q_tree, scale_tree, like_tree):
    return jax.tree.map(
        lambda q, s, x: decompress(q, s, x.shape), q_tree, scale_tree,
        like_tree)


def init_error(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
