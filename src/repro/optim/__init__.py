"""Optimizers built here (no optax in the environment): sharded AdamW with
fp32 master weights, LR schedules, global-norm clipping, int8 error-feedback
gradient compression, and the DIALS-style periodic outer optimizer."""
from repro.optim import adamw, clip, compress, outer, schedule  # noqa: F401
