"""Production mesh definition (a FUNCTION — importing this module never
touches jax device state).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips. The ``pod`` axis
carries ONLY the DIALS-outer reconciliation collective (every F steps) and
the batch sharding; the inner train_step has no per-step cross-pod
collective by construction.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (same axis names, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
