import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import/init: jax locks the device count on
#   first initialization. Dry-run only — tests/benches see 1 device.

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture × shape ×
mesh) cell on placeholder devices and record memory/cost/collective
numbers for the roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh multi ...

A cell PASSES iff lowering + SPMD compilation succeed (sharding mismatch,
OOM-at-compile or unsupported collectives are bugs), and the JSON record
feeds §Roofline.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import registry, shapes as shapes_mod
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_mod

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(pred|[sub]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")


def _type_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo: str) -> dict:
    """Sum result-shape bytes of every collective instruction in the
    (post-SPMD, per-device) HLO, by collective kind."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(r"^[%\w.\-]+\s*=\s*(.+)$", line)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # match the op name, not fusion names mentioning it
            if re.match(rf"^(\([^)]*\)|\S+)\s+{kind}[(\.]", rhs) or \
               re.match(rf"^{kind}[(\.]", rhs):
                sig = rhs.split(kind)[0]
                out[kind] += _type_bytes(sig)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _scaled_spec(spec, repeats: int):
    """Same architecture with the depth scan truncated to ``repeats``
    period applications (used by the two-point cost extrapolation)."""
    import dataclasses as dc
    if spec.kind == "encdec":
        dec = dc.replace(spec.cfg.decoder,
                         n_layers=repeats * len(spec.cfg.decoder.period),
                         scan_unroll=True)
        enc_l = repeats * len(spec.cfg.encoder_period)
        cfg = dc.replace(spec.cfg, decoder=dec, encoder_layers=enc_l)
    else:
        cfg = dc.replace(spec.cfg, n_layers=repeats * len(spec.cfg.period),
                         scan_unroll=True)
    return dc.replace(spec, cfg=cfg)


def _full_repeats(spec) -> int:
    if spec.kind == "encdec":
        dec = spec.cfg.decoder
        enc = spec.cfg.encoder_repeats
        assert dec.repeats == enc, "extrapolation needs equal enc/dec repeats"
        return dec.repeats
    return spec.cfg.repeats


def _cost_of(spec, shape, mesh, kw) -> dict:
    bundle = steps_mod.make_step(spec, shape, mesh, **kw)
    compiled = bundle.jit_fn.lower(*bundle.arg_sds).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "collective_bytes": coll["total_bytes"]}


def extrapolated_cost(spec, shape, mesh, kw) -> dict:
    """XLA's cost analysis visits a while-loop body ONCE, so the depth
    scan's flops/bytes/collectives are undercounted by ~``repeats``.
    Correct by two-point extrapolation: lower the same program with 1 and
    2 period applications; the difference is one body iteration, so

        total(R) = c(1) + (R - 1) * (c(2) - c(1)).
    """
    r_full = _full_repeats(spec)
    c1 = _cost_of(_scaled_spec(spec, 1), shape, mesh, kw)
    c2 = _cost_of(_scaled_spec(spec, 2), shape, mesh, kw)
    out = {}
    for k in c1:
        body = c2[k] - c1[k]
        out[k] = c1[k] + (r_full - 1) * body
        out[k + "_body"] = body
    out["repeats"] = r_full
    return out


def model_flops(spec, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for train, 2·N·D for inference, with
    N = active parameters (MoE expert weights scaled by top_k/E) minus
    the embedding table (the logits matmul is included via tying)."""
    import jax as _jax
    from repro.models import api as api_mod
    sds = _jax.eval_shape(lambda: api_mod.init(
        _jax.random.PRNGKey(0), spec))
    flat = _jax.tree_util.tree_flatten_with_path(sds)[0]
    cfgs = [spec.cfg.decoder] if spec.kind == "encdec" else [spec.cfg]
    moe_cfgs = [b.moe for c in cfgs for b in c.period if b.moe is not None]
    total = 0.0
    for path, leaf in flat:
        names = [str(getattr(k, "key", "")) for k in path]
        size = float(leaf.size)
        if "embed" in names and len(leaf.shape) == 2:
            continue                                   # lookup is not a matmul
        if moe_cfgs and any(n in ("up", "down", "gate") for n in names) \
                and "moe" in names:
            m = moe_cfgs[0]
            size *= m.top_k / m.num_experts
        total += size
    factor = 6.0 if shape.kind == "train" else 2.0
    tokens = (shape.global_batch if shape.kind == "decode"
              else shape.global_batch * shape.seq_len)
    return factor * total * tokens


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             variant: str = "baseline") -> dict:
    spec = registry.get(arch)
    shape = shapes_mod.SHAPES[shape_name]
    supported, reason = registry.cell_supported(spec, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "status": "skip", "reason": reason}
    if not supported:
        return rec
    if shape.kind == "decode" and not spec.has_decode:
        rec["reason"] = "no decode step for this arch"
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    kw = {}
    if variant != "baseline":
        from repro.launch import variants
        kw = variants.VARIANTS[variant](spec, shape)
        spec = kw.pop("spec", spec)
    bundle = steps_mod.make_step(spec, shape, mesh, **kw)
    lowered = bundle.jit_fn.lower(*bundle.arg_sds)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec.update({
        "status": "ok",
        "kind": bundle.kind,
        "n_devices": mesh.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {"flops": cost.get("flops"),
                 "bytes_accessed": cost.get("bytes accessed"),
                 "transcendentals": cost.get("transcendentals")},
        "collectives": coll,
    })
    # scan-corrected totals (cost_analysis counts a while body once)
    try:
        rec["cost_extrapolated"] = extrapolated_cost(spec, shape, mesh, kw)
        rec["model_flops_global"] = model_flops(spec, shape)
    except Exception as e:                    # pragma: no cover
        rec["cost_extrapolated"] = {"error": f"{type(e).__name__}: {e}"}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = ([(a, s) for a in registry.list_archs()
              for s in shapes_mod.SHAPES]
             if args.all else [(args.arch, args.shape)])

    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{args.mesh}__{args.variant}"
        try:
            rec = run_cell(arch, shape, args.mesh, variant=args.variant)
        except Exception as e:  # a failing cell is a bug — surface it
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "variant": args.variant, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        line = {k: rec.get(k) for k in
                ("arch", "shape", "mesh", "status", "reason", "compile_s")}
        print(json.dumps(line), flush=True)
        if rec["status"] == "ok":
            print(f"  mem(temp)={rec['memory']['temp_bytes']/2**30:.2f}GiB/dev"
                  f"  flops/dev={rec['cost']['flops']:.3e}"
                  f"  coll={rec['collectives']['total_bytes']/2**30:.3f}GiB",
                  flush=True)
    if failures:
        raise SystemExit(f"{failures} cells FAILED")


if __name__ == "__main__":
    main()
