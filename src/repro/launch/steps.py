"""AOT step builders: train_step / prefill_step / serve_step for any
(architecture × shape × mesh), with explicit in/out shardings resolved
from the logical-axis rules. Everything here works on ShapeDtypeStructs —
no parameter allocation — which is what the multi-pod dry-run needs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry, shapes as shapes_mod
from repro.distributed import mesh as mesh_lib
from repro.models import api, encdec as encdec_mod, lm as lm_mod, vlm as vlm_mod
from repro.optim import adamw, clip as clip_mod, schedule


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """A jit-wrapped step plus the ShapeDtypeStructs of its arguments —
    ``jit_fn.lower(*arg_sds).compile()`` is the dry-run."""
    jit_fn: object
    arg_sds: tuple
    kind: str


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _batch_shardings(batch_sds, mesh, *, long_context=False):
    spec = mesh_lib.batch_spec(mesh, long_context=long_context)

    def one(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, P(*(list(spec) + [None] * (x.ndim - len(spec)))))
    return jax.tree.map(one, batch_sds)


def model_cfg(spec):
    return spec.cfg.decoder if spec.kind == "encdec" else spec.cfg


def act_constraint_for(mesh, *, seq_axis: str = "model"):
    """Sequence-parallel residual-stream constraint: the scan carry (the
    only activation saved across the depth scan) is stored (batch → data,
    seq → model)-sharded, cutting saved-activation memory by the TP degree."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
             seq_axis if seq_axis in mesh.shape else None, None)
    sh = NamedSharding(mesh, spec)
    return lambda x: jax.lax.with_sharding_constraint(x, sh)


# ---------------------------------------------------------------------------
# Shardings for params / optimizer / caches
# ---------------------------------------------------------------------------
def param_sds(spec) -> dict:
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), spec))


def param_shardings(spec, mesh, *, rules=mesh_lib.TRAIN_RULES,
                    fsdp_axes=("pod", "data")):
    sds = param_sds(spec)
    return mesh_lib.logical_to_sharding(
        api.logical_specs(spec), sds, mesh, rules=rules,
        fsdp_axes=fsdp_axes), sds


def opt_shardings(spec, mesh, p_shardings, p_sds):
    o_sds = jax.eval_shape(adamw.init, p_sds)
    sh = {"mu": p_shardings, "nu": p_shardings, "master": p_shardings,
          "step": NamedSharding(mesh, P())}
    return sh, o_sds


def cache_sds(spec, shape: shapes_mod.Shape):
    """ShapeDtypeStructs of the decode caches for a shape cell."""
    p_sds = param_sds(spec)
    b = shape.global_batch

    def build(params):
        if spec.kind == "encdec":
            frames = jnp.zeros((b, spec.n_frames, spec.cfg.d_model),
                               jnp.bfloat16)
            return encdec_mod.init_decode_caches(params, spec.cfg, frames,
                                                 b, shape.seq_len)
        if spec.kind == "vlm":
            patches = jnp.zeros((b, spec.n_patches, spec.vision_dim),
                                jnp.bfloat16)
            return vlm_mod.init_decode_caches(params, spec.cfg, patches,
                                              b, shape.seq_len)
        return lm_mod.init_caches(params, spec.cfg, b, shape.seq_len)

    return jax.eval_shape(build, p_sds)


def cache_shardings(spec, mesh, c_sds, *, rules):
    cfg = model_cfg(spec)
    logical = lm_mod.cache_logical_specs(cfg)
    return mesh_lib.logical_to_sharding(logical, c_sds, mesh, rules=rules)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------
def make_train_step(spec, shape: shapes_mod.Shape, mesh, *,
                    rules=mesh_lib.TRAIN_RULES, fsdp_axes=("pod", "data"),
                    peak_lr: float = 3e-4, grad_clip: float = 1.0,
                    seq_parallel: bool = True,
                    batch_axes=None, microbatches: int = 1) -> StepBundle:
    if batch_axes is not None:
        # pure-DP (ZeRO-3) layout: batch over the given axes, no TP —
        # constrain the residual carry so every axis carries batch.
        axes = tuple(a for a in batch_axes if a in mesh.shape)
        bsh = NamedSharding(mesh, P(axes, None, None))
        act = lambda x: jax.lax.with_sharding_constraint(x, bsh)
    else:
        act = act_constraint_for(mesh) if seq_parallel else None
    loss_fn = api.loss_fn(spec, act_constraint=act)
    lr_fn = schedule.warmup_cosine(peak_lr, 2_000, 100_000)
    adamw_cfg = adamw.AdamWConfig()

    assert shape.global_batch % microbatches == 0, \
        (shape.global_batch, microbatches)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over microbatch slices of the batch
        # axis — peak activation memory scales down by `microbatches`
        # (the HBM-fit knob for the big train cells).
        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def one(carry, mb):
            g_acc, l_acc, m_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            return (jax.tree.map(jnp.add, g_acc, grads), l_acc + loss,
                    jax.tree.map(jnp.add, m_acc, metrics)), None

        zeros_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mb0 = jax.tree.map(lambda x: x[0], split)
        zeros_m = jax.tree.map(lambda x: jnp.zeros((), jnp.float32),
                               jax.eval_shape(loss_fn, params, mb0)[1])
        (g, loss, metrics), _ = jax.lax.scan(
            one, (zeros_g, jnp.zeros((), jnp.float32), zeros_m), split)
        scale = 1.0 / microbatches
        return (loss * scale, jax.tree.map(lambda x: x * scale, metrics)), \
            jax.tree.map(lambda x: x * scale, g)

    def train_step(params, opt, batch):
        (loss, metrics), grads = grads_of(params, batch)
        grads = clip_mod.sanitize(grads)
        grads, gnorm = clip_mod.clip_by_global_norm(grads, grad_clip)
        master, opt = adamw.update(grads, opt, lr_fn(opt["step"]), adamw_cfg)
        params = adamw.cast_like(master, params)
        return params, opt, {**metrics, "loss": loss, "grad_norm": gnorm}

    p_sh, p_sds = param_shardings(spec, mesh, rules=rules,
                                  fsdp_axes=fsdp_axes)
    o_sh, o_sds = opt_shardings(spec, mesh, p_sh, p_sds)
    b_sds = registry.input_specs(spec, shape)
    if batch_axes is not None:
        axes = tuple(a for a in batch_axes if a in mesh.shape)
        b_sh = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(*((axes,) + (None,) * (x.ndim - 1)))), b_sds)
    else:
        b_sh = _batch_shardings(b_sds, mesh)

    jit_fn = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    return StepBundle(jit_fn=jit_fn, arg_sds=(p_sds, o_sds, b_sds),
                      kind="train")


def make_prefill_step(spec, shape: shapes_mod.Shape, mesh, *,
                      rules=mesh_lib.TRAIN_RULES,
                      fsdp_axes=("pod", "data"),
                      seq_parallel: bool = True) -> StepBundle:
    """Forward over the full prompt; emits last-position logits (the
    sampling input). KV-cache write-back is the decode path's cache
    layout; its bytes are accounted in the roofline's memory term."""
    cfg = model_cfg(spec)
    act = act_constraint_for(mesh) if seq_parallel else None

    def prefill_step(params, batch):
        if spec.kind == "encdec":
            enc = encdec_mod.encode(params, batch["frames"], spec.cfg)
            x, _ = lm_mod.forward(params["decoder"], batch["tokens"],
                                  cfg, cross_kv=enc, act_constraint=act)
            params = params["decoder"]
        elif spec.kind == "vlm":
            x, _ = lm_mod.forward(params, batch["tokens"], cfg,
                                  cross_kv=batch["patches"],
                                  act_constraint=act)
        else:
            x, _ = lm_mod.forward(params, batch["tokens"], cfg,
                                  act_constraint=act)
        return lm_mod.logits_fn(params, x[:, -1:, :], cfg)

    p_sh, p_sds = param_shardings(spec, mesh, rules=rules,
                                  fsdp_axes=fsdp_axes)
    b_sds = registry.input_specs(spec, shape)
    b_sh = _batch_shardings(b_sds, mesh)
    jit_fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                     out_shardings=None)
    return StepBundle(jit_fn=jit_fn, arg_sds=(p_sds, b_sds), kind="prefill")


def make_serve_step(spec, shape: shapes_mod.Shape, mesh, *,
                    rules: Optional[tuple] = None,
                    fsdp_axes=(), sharded_softmax: bool = True) -> StepBundle:
    """One-token decode against a seq_len cache."""
    long_ctx = shape.name.startswith("long")
    if rules is None:
        rules = (mesh_lib.LONG_CONTEXT_RULES if long_ctx
                 else mesh_lib.DECODE_RULES)

    # Distributed softmax over the sharded cache-sequence axis: constrain
    # the (B, H, 1, slots) attention logits to (batch axes, ..., cache_seq
    # axis) so the partitioner reduces with small all-reduces instead of
    # all-gathering the whole K/V cache every layer (§Perf decode fix).
    seq_axis = dict(rules).get("cache_seq")
    lconstraint = None
    if sharded_softmax and isinstance(seq_axis, str) \
            and seq_axis in mesh.shape:
        batch_axes = mesh_lib.batch_spec(mesh, long_context=long_ctx)[0]
        lsh = NamedSharding(mesh, P(batch_axes, None, None, seq_axis))

        def lconstraint(t):
            return jax.lax.with_sharding_constraint(t, lsh)

    def serve_step(params, token, caches, index):
        if spec.kind == "encdec":
            return encdec_mod.decode_step(params, token, caches, index,
                                          spec.cfg,
                                          logits_constraint=lconstraint)
        return lm_mod.decode_step(params, token, caches, index,
                                  model_cfg(spec),
                                  logits_constraint=lconstraint)

    p_sh, p_sds = param_shardings(spec, mesh, rules=rules,
                                  fsdp_axes=fsdp_axes)
    c_sds = cache_sds(spec, shape)
    c_sh = cache_shardings(spec, mesh, c_sds, rules=rules)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = _batch_shardings(tok_sds, mesh, long_context=long_ctx)
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    jit_fn = jax.jit(serve_step,
                     in_shardings=(p_sh, tok_sh, c_sh,
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, c_sh),
                     donate_argnums=(2,))
    return StepBundle(jit_fn=jit_fn,
                      arg_sds=(p_sds, tok_sds, c_sds, idx_sds), kind="decode")


def make_step(spec, shape: shapes_mod.Shape, mesh, **kw) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(spec, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(spec, shape, mesh, **kw)
    return make_serve_step(spec, shape, mesh, **kw)
