"""Production training driver.

Builds the (arch × shape) train step with explicit shardings on the
requested mesh and runs it over the synthetic data pipeline with gradient
clipping, LR schedule, checkpoint/restart and the DIALS-outer multi-pod
reconciliation. On CPU the mesh degrades to (1, 1) and the same program
runs end-to-end (that is the smoke path); on a real pod slice, set
--mesh single|multi and the identical code lowers the dry-run's program.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 100 --reduced --batch 4 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry, shapes as shapes_mod
from repro.data import pipeline
from repro.distributed import mesh as mesh_lib
from repro.launch import mesh as prod_mesh
from repro.models import api
from repro.optim import adamw, clip, outer, schedule


def build(spec, mesh, *, peak_lr, total_steps, warmup):
    loss_fn = api.loss_fn(spec)
    lr_fn = schedule.warmup_cosine(peak_lr, warmup=warmup, total=total_steps)

    def train_step(params, opt, batch, step):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        grads, gnorm = clip.clip_by_global_norm(clip.sanitize(grads), 1.0)
        master, opt = adamw.update(grads, opt, lr_fn(step))
        return adamw.cast_like(master, params), opt, loss, gnorm

    p_sh, _ = __import__("repro.launch.steps", fromlist=["x"]) \
        .param_shardings(spec, mesh)
    return jax.jit(train_step), p_sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sync-every", type=int, default=0,
                    help=">0 enables DIALS-outer reconciliation")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    spec = registry.get(args.arch, reduced=args.reduced)
    cfg = spec.cfg.decoder if spec.kind == "encdec" else spec.cfg
    mesh = prod_mesh.make_host_mesh()

    params = api.init(jax.random.PRNGKey(0), spec)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{n_params/1e6:.2f}M params on mesh {dict(mesh.shape)}")
    opt = adamw.init(params)
    out_state = outer.init(params) if args.sync_every else None
    err = None
    train_step, _ = build(spec, mesh, peak_lr=args.lr,
                          total_steps=args.steps, warmup=args.steps // 10)

    mgr = CheckpointManager(args.ckpt, keep=2) if args.ckpt else None
    start = 0
    if mgr:
        tree = {"params": params, "opt": opt}
        restored, start = mgr.restore_latest(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            print(f"resumed from step {start}")
        start = max(0, start)

    it = pipeline.lm_iterator(seed=0, batch=args.batch, seq=args.seq,
                              vocab=cfg.vocab)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(it)
        if spec.kind == "encdec":
            batch = dict(batch, frames=jnp.zeros(
                (args.batch, spec.n_frames, spec.cfg.d_model), jnp.bfloat16))
        if spec.kind == "vlm":
            batch = dict(batch, patches=jnp.zeros(
                (args.batch, spec.n_patches, spec.vision_dim), jnp.bfloat16))
        params, opt, loss, gnorm = train_step(params, opt, batch,
                                              jnp.asarray(step))
        if args.sync_every and (step + 1) % args.sync_every == 0:
            params, out_state, err = outer.outer_step(
                params, out_state,
                outer.OuterConfig(sync_every=args.sync_every), err_tree=err)
            if mgr:
                mgr.save(step + 1, {"params": params, "opt": opt})
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step - start + 1) \
                / max(time.time() - t0, 1e-9)
            print(f"step {step:5d}  loss {float(loss):.4f}  "
                  f"gnorm {float(gnorm):.2f}  {tok_s:,.0f} tok/s")
    if mgr:
        mgr.wait()


if __name__ == "__main__":
    main()
