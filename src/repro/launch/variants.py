"""Named sharding/step variants for the §Perf hillclimb.

Each variant maps (spec, shape) -> kwargs for ``steps.make_step``; the
dry-run's ``--variant`` flag selects one, so every hypothesis in the
hillclimb log is a reproducible command line.
"""
from __future__ import annotations

from repro.distributed import mesh as mesh_lib

# 2D tensor-parallel decode: weights sharded over BOTH mesh axes (no
# per-step weight all-gather), batch replicated, cache sequence-sharded.
DECODE_TP2D_RULES: mesh_lib.Rules = (
    ("batch", None),
    ("cache_batch", ("pod", "data")),
    ("cache_seq", "model"),
    ("vocab", ("model", "data")),
    ("heads", ("model", "data")),
    ("kv_heads", ("model", "data")),
    ("mlp", ("model", "data")),
    ("expert", "model"),
    ("embed", None),
    ("layers", None),
    ("seq", None),
)


def _decode_tp2d(spec, shape):
    return {"rules": DECODE_TP2D_RULES, "fsdp_axes": ()}


def _train_no_fsdp(spec, shape):
    # pure TP+DP: params replicated over data (baseline ablation)
    return {"fsdp_axes": ()}


def _decode_gathered(spec, shape):
    # pre-optimization decode baseline: let the partitioner all-gather the
    # K/V cache instead of running the distributed softmax.
    return {"sharded_softmax": False}


def _decode_fsdp(spec, shape):
    # weight-gathered decode (capacity-first): ZeRO-sharded weights,
    # all-gathered per step — the baseline for big-model serving memory
    return {"fsdp_axes": ("pod", "data")}


def _replace_moe(spec, **moe_kw):
    """Rebuild an ArchSpec with every MoE block's config modified."""
    import dataclasses as dc

    def fix_cfg(cfg):
        period = tuple(
            dc.replace(b, moe=dc.replace(b.moe, **moe_kw))
            if b.moe is not None else b for b in cfg.period)
        return dc.replace(cfg, period=period)

    if spec.kind == "encdec":
        cfg = dc.replace(spec.cfg, decoder=fix_cfg(spec.cfg.decoder))
    else:
        cfg = fix_cfg(spec.cfg)
    return dc.replace(spec, cfg=cfg)


def _moe_dense(spec, shape):
    # Switch/Mesh-style one-hot einsum dispatch — the paper-standard MoE
    # baseline (pre-optimization defaults).
    return {"spec": _replace_moe(spec, dispatch="dense")}


def _moe_gather(spec, shape):
    # §Perf: scatter/gather MoE dispatch — removes the O(N·E·C·d) one-hot
    # dispatch matmuls that dominate fine-grained-MoE train steps.
    return {"spec": _replace_moe(spec, dispatch="gather")}


def _moe_gather_sharded(spec, shape):
    # §Perf iteration 3: group-local routing/capacity (16 groups = the
    # data axis) — the position scan and expert buffers shard instead of
    # being SPMD-replicated.
    return {"spec": _replace_moe(spec, dispatch="gather", token_shards=16)}


def _train_pod_local_fsdp(spec, shape):
    # §Perf (the paper's technique at the pod level): FSDP only WITHIN a
    # pod; across pods, parameters are replicated and reconciled every F
    # steps by the DIALS-outer optimizer — the per-step train program
    # carries ZERO cross-pod collectives.
    return {"fsdp_axes": ("data",)}


def _remat_dots(spec, shape):
    # §Perf: checkpoint only matmul outputs instead of full-block remat —
    # trades saved-activation bytes for less recompute (memory term vs
    # compute term).
    import dataclasses as dc
    if spec.kind == "encdec":
        cfg = dc.replace(spec.cfg,
                         decoder=dc.replace(spec.cfg.decoder, remat="dots"))
    else:
        cfg = dc.replace(spec.cfg, remat="dots")
    return {"spec": dc.replace(spec, cfg=cfg)}


# Pure ZeRO-3 data parallelism: batch over BOTH mesh axes (256-way DP),
# no tensor parallelism at all. Weights/optimizer fully sharded over all
# 256 chips, all-gathered layer-by-layer inside the scan. Eliminates the
# per-layer TP activation collectives (which dominate the baseline train
# cells) at the cost of one params-sized gather per sweep.
ZERO3_RULES: mesh_lib.Rules = (
    ("batch", ("pod", "data", "model")),
    ("vocab", None),
    ("heads", None),
    ("kv_heads", None),
    ("mlp", None),
    ("expert", None),
    ("embed", None),
    ("layers", None),
    ("seq", None),
    ("cache_batch", ("pod", "data", "model")),
    ("cache_seq", None),
)


def _train_zero3(spec, shape):
    return {"rules": ZERO3_RULES,
            "fsdp_axes": ("pod", "data", "model"),
            "seq_parallel": False,
            "batch_axes": ("pod", "data", "model")}


def _train_zero3_mb8(spec, shape):
    # zero3 + 8-way gradient accumulation: activation temp memory /8 —
    # the HBM-fit configuration for the big train cells on 16 GB v5e.
    return {**_train_zero3(spec, shape), "microbatches": 8}


def _train_zero3_dots(spec, shape):
    # zero3 + dots-remat: drop the full-forward recompute (and its second
    # weight all-gather sweep) in the backward pass.
    import dataclasses as dc
    cfg = dc.replace(spec.cfg, remat="dots")
    return {**_train_zero3(spec, shape), "spec": dc.replace(spec, cfg=cfg)}


def _train_no_seqpar(spec, shape):
    # §Perf ablation: drop the sequence-parallel residual constraint —
    # isolates how much collective traffic the seq<->full resharding costs.
    return {"seq_parallel": False}


# ---------------------------------------------------------------------------
# §DIALS MARL scenarios: named (env, side) cells resolved through
# repro.envs.registry — the env analogue of the arch/variant grid above,
# so launch scripts and benchmarks name a scenario instead of hardcoding
# an env module. Adding an env to the registry makes it launchable here
# by adding one line.
# ---------------------------------------------------------------------------
MARL_SCENARIOS = {
    "traffic-2x2": ("traffic", 2),
    "traffic-4x4": ("traffic", 4),
    "traffic-5x5": ("traffic", 5),
    "warehouse-2x2": ("warehouse", 2),
    "warehouse-4x4": ("warehouse", 4),
    "warehouse-5x5": ("warehouse", 5),
    "powergrid-ring4": ("powergrid", 2),
    "powergrid-ring16": ("powergrid", 4),
    "supplychain-line4": ("supplychain", 2),
    "supplychain-line16": ("supplychain", 4),
}


def marl_scenario(name, **overrides):
    """Resolve a named scenario to ``(env_module, env_cfg)``.

    ``overrides`` are env-config field overrides (e.g. ``horizon=32``).
    """
    from repro.envs import registry
    env_name, side = MARL_SCENARIOS[name]
    return registry.make(env_name, side=side, **overrides)


def launch_group(argv, *, processes, local_devices=None, env=None,
                 cwd=None, stdout=None, stderr=None):
    """Fork ``processes`` coordinated ``jax.distributed`` CPU processes
    running ``argv``, wired through the ``DIALS_*`` bootstrap contract
    (repro.distributed.bootstrap): a free coordinator port is picked,
    every child gets its rank/count/coordinator env vars (plus the
    forced host-device count when ``local_devices`` is set), and each
    child's own ``bootstrap.bootstrap()`` call joins the group. Returns
    the list of ``subprocess.Popen`` handles in rank order — the caller
    owns waiting and exit-code policy."""
    import os
    import socket
    import subprocess

    from repro.distributed import bootstrap

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    procs = []
    for rank in range(processes):
        cfg = bootstrap.BootstrapConfig(
            coordinator=f"127.0.0.1:{port}", num_processes=processes,
            process_id=rank, local_devices=local_devices)
        procs.append(subprocess.Popen(
            argv, env={**(env if env is not None else os.environ),
                       **cfg.env()},
            cwd=cwd, stdout=stdout, stderr=stderr))
    return procs


def dials_variant_for(shards, async_collect=False, sharded_gs="auto",
                      streams=None):
    """§DIALS runtime knobs: ``DIALSConfig`` overrides — the resolver
    behind every ``--shards N`` / ``--async-collect`` / ``--sharded-gs``
    / ``--streams S`` CLI flag (benchmarks/run.py, benchmarks/scaling.py,
    examples/traffic_gs_vs_dials.py). ``shards``: ``None`` = auto path
    selection (sharded iff >1 device visible), ``1`` = force the unfused
    python-loop path (F+3 host syncs per round), ``N`` = force an
    N-shard ``("shards",)`` mesh. ``async_collect`` overlaps round k+1's
    GS collect with round k's inner steps (one-round dataset lag,
    bounded by ``max_aip_staleness``). ``sharded_gs`` selects the
    region-decomposed GS collect/eval (repro.core.gs_sharded):
    auto = whenever the env's partition supports the mesh, on/off force.
    ``streams``: large-batch collect width S — overrides
    ``DIALSConfig.collect_streams`` (None keeps ``collect_envs``)."""
    out = {"shards": shards, "async_collect": async_collect,
           "sharded_gs": sharded_gs}
    if streams is not None:
        out["collect_streams"] = int(streams)
    return out


VARIANTS = {
    "train_no_seqpar": _train_no_seqpar,
    "train_zero3": _train_zero3,
    "train_zero3_dots": _train_zero3_dots,
    "train_zero3_mb8": _train_zero3_mb8,
    "decode_tp2d": _decode_tp2d,
    "decode_gathered": _decode_gathered,
    "train_no_fsdp": _train_no_fsdp,
    "decode_fsdp": _decode_fsdp,
    "moe_dense": _moe_dense,
    "moe_gather": _moe_gather,
    "moe_gather_sharded": _moe_gather_sharded,
    "train_pod_local_fsdp": _train_pod_local_fsdp,
    "remat_dots": _remat_dots,
}
