"""Launchers: production mesh, AOT dry-run, roofline analysis, train/serve
drivers."""
