"""Tabular IALM machinery (Definition 3 / Eq. 1) — exact, enumerative.

Used by the theory layer and tests to validate the paper's formal claims
on small instances where everything is computable exactly:

* :func:`q_values` — finite-horizon Q over action-local-state histories
  for an IALM with an arbitrary influence distribution I(u | l).
* :func:`exact_influence` — the TRUE influence of a 2-region coupled
  system (each region's influence source is the other region's state),
  computed by HMM filtering — Lemma 1's "joint policy ⇒ unique influence"
  made executable.

Histories are tuples ⟨x0, a0, x1, ..., xt⟩ (observations are the local
state itself, as in both paper envs' local views).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Callable, Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TabularIALM:
    """T: (nx, nu, na, nx) local transition; R: (nx, na); horizon H.
    influence: history tuple -> (nu,) probabilities."""
    T: np.ndarray
    R: np.ndarray
    horizon: int
    influence: Callable[[Tuple], np.ndarray]

    @property
    def nx(self):
        return self.T.shape[0]

    @property
    def nu(self):
        return self.T.shape[1]

    @property
    def na(self):
        return self.T.shape[2]


def q_values(m: TabularIALM, policy: Callable[[Tuple], np.ndarray]
             ) -> Dict[Tuple, np.ndarray]:
    """Exact Q^π(l, ·) for every reachable history, by backward recursion
    on the IALM dynamics P(x'|l,a) = Σ_u T(x'|x,u,a) I(u|l) (Eq. 1)."""
    q: Dict[Tuple, np.ndarray] = {}

    def recurse(l: Tuple, t: int) -> np.ndarray:
        if l in q:
            return q[l]
        x = l[-1]
        vals = np.array(m.R[x], dtype=np.float64)
        if t < m.horizon - 1:
            iu = m.influence(l)                       # (nu,)
            for a in range(m.na):
                px = np.einsum("u,ux->x", iu, m.T[x, :, a, :])
                for x2 in range(m.nx):
                    if px[x2] <= 0:
                        continue
                    l2 = l + (a, x2)
                    q2 = recurse(l2, t + 1)
                    v2 = float(np.dot(policy(l2), q2))
                    vals[a] += px[x2] * v2
        q[l] = vals
        return vals

    for x0 in range(m.nx):
        recurse((x0,), 0)
    return q


def optimal_policy(m: TabularIALM):
    """Greedy backward induction; returns (policy_fn, q_star dict)."""
    qstar: Dict[Tuple, np.ndarray] = {}

    def recurse(l: Tuple, t: int) -> np.ndarray:
        if l in qstar:
            return qstar[l]
        x = l[-1]
        vals = np.array(m.R[x], dtype=np.float64)
        if t < m.horizon - 1:
            iu = m.influence(l)
            for a in range(m.na):
                px = np.einsum("u,ux->x", iu, m.T[x, :, a, :])
                for x2 in range(m.nx):
                    if px[x2] <= 0:
                        continue
                    vals[a] += px[x2] * np.max(recurse(l + (a, x2), t + 1))
        qstar[l] = vals
        return vals

    for x0 in range(m.nx):
        recurse((x0,), 0)

    def pol(l):
        p = np.zeros(m.na)
        p[int(np.argmax(qstar[l]))] = 1.0
        return p

    return pol, qstar


# ---------------------------------------------------------------------------
# Exact influence for a symmetric 2-region coupled system
# ---------------------------------------------------------------------------
def exact_influence(T1: np.ndarray, T2: np.ndarray,
                    pi2: np.ndarray, b0: np.ndarray):
    """True I_1(u | l_1) where u = region 2's state.

    T1: (x1, u, a1, x1') — region 1's local transition (u = x2).
    T2: (x2, u2, a2, x2') — region 2's, with u2 = x1 (mutual coupling).
    pi2: (x2, a2) — agent 2's (memoryless) policy.
    b0: (nx2,) initial distribution over x2.

    Returns influence(l) -> (nu,) — an HMM filter over x2: each observed
    region-1 transition re-weights the belief by its likelihood under u,
    then the belief propagates through region 2's dynamics.
    """
    @functools.lru_cache(maxsize=None)
    def belief(l: Tuple) -> np.ndarray:
        if len(l) == 1:
            return b0
        *prev, a1, x1_new = l
        lp = tuple(prev)
        b = belief(lp)                               # P(x2_t | l_t)
        x1_old = lp[-1]
        # evidence: the observed region-1 transition
        lik = T1[x1_old, :, a1, x1_new]              # (nu,) = (nx2,)
        b = b * lik
        s = b.sum()
        b = b / s if s > 0 else np.full_like(b, 1.0 / len(b))
        # propagate region 2 one step (its influence source was x1_old)
        b2 = np.einsum("x,xa,xay->y", b, pi2,
                       T2[:, x1_old, :, :])
        return b2

    return lambda l: belief(tuple(l))


def random_system(rng: np.random.Generator, nx=2, na=2):
    """A random symmetric 2-region coupled system for property tests."""
    def rand_t():
        t = rng.random((nx, nx, na, nx)) + 0.1
        return t / t.sum(-1, keepdims=True)
    T1, T2 = rand_t(), rand_t()
    R = rng.random((nx, na))
    pi2 = rng.random((nx, na)) + 0.1
    pi2 = pi2 / pi2.sum(-1, keepdims=True)
    b0 = np.full((nx,), 1.0 / nx)
    return T1, T2, R, pi2, b0
