"""Algorithm 2 — collect per-agent influence datasets from the GS.

Rolls S independent global-simulator streams under the current joint
policy (one wide pool program — ``repro.core.env_pool``) and records,
for every agent i, stream s, and step t, the ALSH feature (local obs
x_i^t ++ one-hot of a_i^{t-1}) and the realized influence sources u_i^t.
One jitted scan; the output is already shaped (N, S, T, ...) for the
vmapped AIP trainer.

Two properties make S a real scaling axis here:

* **per-stream keys** — every stream's randomness folds in its absolute
  stream index (``env_pool.stream_keys``), so growing S preserves the
  prefix streams bitwise; the joint-action draw is a per-stream
  categorical (a ``vmap`` over stream keys), not one batch-shaped draw;
* **fused transpose** — the (N, S, T, ...) output buffers ride the scan
  carry and each step writes its (S, N, ...) record into the t-th time
  slice in place (``dynamic_update_index_in_dim`` on a scan carry is an
  in-place update under XLA). There is no post-scan ``moveaxis`` copy,
  so peak collect memory is one dataset, not two — the difference
  between S=512 fitting or not. :func:`make_collector_into` exposes the
  same program with the output buffers as a DONATED argument, which is
  what ``repro.distributed.async_collect.DeviceRing`` feeds with retired
  ring slots so steady-state collect allocates nothing at all.

This is the replicated implementation; its region-decomposed twin
(``repro.core.gs_sharded.make_sharded_collector``) runs the same
Algorithm 2 as block programs over the shard mesh and emits a
bitwise-identical dataset, already agent-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import env_pool
from repro.marl import policy as policy_mod


def split_dataset(data, n_eval: int):
    """Split a collected dataset (leaves (N, S, T, ...)) along the
    sequence axis S into (train, held_out): the LAST ``n_eval`` env
    streams per agent are held out of AIP training so ``eval_ce`` is the
    paper's true held-out Fig.-4 metric rather than train-set CE.

    ``n_eval <= 0`` returns the full dataset for both views (legacy
    train-set CE — the only option when only one sequence was collected).
    Static slicing: safe inside jit/shard_map, no collectives — and when
    it runs inside a consumer program (the fused AIP round, the shard
    body) the slices are fused views of the ring buffer, never
    materialized host-side copies.
    """
    if n_eval <= 0:
        return data, data
    n_seq = jax.tree.leaves(data)[0].shape[1]
    if n_eval >= n_seq:
        raise ValueError(
            f"cannot hold out {n_eval} of {n_seq} collected sequences — "
            f"at least one must remain for AIP training")
    train = jax.tree.map(lambda x: x[:, :n_seq - n_eval], data)
    held = jax.tree.map(lambda x: x[:, n_seq - n_eval:], data)
    return train, held


def _make_collect_impl(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                       *, n_envs: int, steps: int):
    """The shared collect program: ``impl(bufs, params, key) -> bufs'``
    where ``bufs`` seeds the (N, S, T, ...) output buffers carried
    through the scan. Every cell is overwritten, so the result is
    independent of the seed values — the plain collector seeds zeros,
    the ring path donates a retired slot."""
    info = env_cfg.info()
    n_agents = info.n_agents
    pool = env_pool.GSPool(env_mod, env_cfg, n_envs)
    apply_agents = jax.vmap(
        lambda p, o, h: policy_mod.policy_apply(p, o, h, policy_cfg),
        in_axes=(0, 1, 1), out_axes=(1, 1, 1))
    # per-stream joint-action draw: stream s samples all N agents from
    # its OWN step key, so the sampled bits depend on (key, s, t), never
    # on the batch width S
    sample_streams = jax.vmap(policy_mod.sample_action)

    def collect_impl(bufs, policy_params, key):
        skeys = env_pool.stream_keys(key, n_envs)
        env = pool.init(skeys)
        obs = pool.v_obs(env)
        h = policy_mod.initial_hidden(policy_cfg, n_envs, n_agents)
        prev_a = jnp.zeros((n_envs, n_agents), jnp.int32)
        prev_done = jnp.ones((n_envs,), bool)     # episode starts fresh

        def step(carry, t):
            env, obs, h, prev_a, prev_done, bufs = carry
            k_act, k_env, k_reset = env_pool.step_keys(skeys, t, 3)
            feat = jnp.concatenate(
                [obs, jax.nn.one_hot(prev_a, info.n_actions)], axis=-1)
            logits, _, h2 = apply_agents(policy_params, obs, h)
            action, _ = sample_streams(k_act, logits)
            env3, obs3, _rew, u, done = pool.step_reset(
                env, action, k_env, k_reset)
            h3, prev3 = env_pool.zero_on_done(done, (h2, action))
            # reset flag marks "new episode starts HERE" (before this feat)
            rec = {"feats": feat, "u": u,
                   "resets": jnp.broadcast_to(prev_done[:, None],
                                              (n_envs, n_agents))
                   .astype(jnp.float32)}
            # fused transpose: (S, N, ...) -> (N, S, ...) written into
            # the t-th time slice of the carried (N, S, T, ...) buffers
            def write(buf, x):
                return jax.lax.dynamic_update_index_in_dim(
                    buf, jnp.moveaxis(x, 0, 1), t, axis=2)
            bufs = {k: write(bufs[k], rec[k]) for k in bufs}
            return (env3, obs3, h3, prev3, done, bufs), None

        carry = (env, obs, h, prev_a, prev_done, bufs)
        carry, _ = jax.lax.scan(step, carry, jnp.arange(steps))
        return carry[-1]

    def zero_bufs():
        return {"feats": jnp.zeros((n_agents, n_envs, steps, info.alsh_dim),
                                   jnp.float32),
                "u": jnp.zeros((n_agents, n_envs, steps, info.n_influence),
                               jnp.float32),
                "resets": jnp.zeros((n_agents, n_envs, steps), jnp.float32)}

    return collect_impl, zero_bufs


def make_collector(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                   *, n_envs: int, steps: int):
    """``collect(policy_params, key) -> dataset`` with leaves
    (N, n_envs, steps, ...): feats, u, resets."""
    impl, zero_bufs = _make_collect_impl(
        env_mod, env_cfg, policy_cfg, n_envs=n_envs, steps=steps)
    return jax.jit(lambda params, key: impl(zero_bufs(), params, key))


def make_collector_into(env_mod, env_cfg,
                        policy_cfg: policy_mod.PolicyConfig,
                        *, n_envs: int, steps: int):
    """``collect_into(bufs, policy_params, key) -> dataset`` — the same
    program with the output buffers passed in and DONATED: XLA writes
    the fresh dataset into the caller's buffers (the ring's retired
    slot), so a steady-state collect performs zero dataset allocation
    and the wide (N, S, T, ...) arrays never leave the device."""
    impl, _ = _make_collect_impl(
        env_mod, env_cfg, policy_cfg, n_envs=n_envs, steps=steps)
    return jax.jit(impl, donate_argnums=0)
