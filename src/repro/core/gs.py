"""Algorithm 2 — collect per-agent influence datasets from the GS.

Rolls the global simulator under the current joint policy and records, for
every agent i and step t, the ALSH feature (local obs x_i^t ++ one-hot of
a_i^{t-1}) and the realized influence sources u_i^t. One jitted scan; the
output is already shaped (N, S, T, ...) for the vmapped AIP trainer.

This is the replicated implementation; its region-decomposed twin
(``repro.core.gs_sharded.make_sharded_collector``) runs the same
Algorithm 2 as block programs over the shard mesh and emits a
bitwise-identical dataset, already agent-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.marl import policy as policy_mod


def split_dataset(data, n_eval: int):
    """Split a collected dataset (leaves (N, S, T, ...)) along the
    sequence axis S into (train, held_out): the LAST ``n_eval`` env
    streams per agent are held out of AIP training so ``eval_ce`` is the
    paper's true held-out Fig.-4 metric rather than train-set CE.

    ``n_eval <= 0`` returns the full dataset for both views (legacy
    train-set CE — the only option when only one sequence was collected).
    Static slicing: safe inside jit/shard_map, no collectives.
    """
    if n_eval <= 0:
        return data, data
    n_seq = jax.tree.leaves(data)[0].shape[1]
    if n_eval >= n_seq:
        raise ValueError(
            f"cannot hold out {n_eval} of {n_seq} collected sequences — "
            f"at least one must remain for AIP training")
    train = jax.tree.map(lambda x: x[:, :n_seq - n_eval], data)
    held = jax.tree.map(lambda x: x[:, n_seq - n_eval:], data)
    return train, held


def make_collector(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                   *, n_envs: int, steps: int):
    info = env_cfg.info()
    n_agents = info.n_agents

    v_gs_init = jax.vmap(lambda k: env_mod.gs_init(k, env_cfg))
    v_gs_step = jax.vmap(lambda s, a, k: env_mod.gs_step(s, a, k, env_cfg))
    v_gs_obs = jax.vmap(lambda s: env_mod.gs_obs(s, env_cfg))
    apply_agents = jax.vmap(
        lambda p, o, h: policy_mod.policy_apply(p, o, h, policy_cfg),
        in_axes=(0, 1, 1), out_axes=(1, 1, 1))

    def collect(policy_params, key):
        """Returns dataset dict with leaves (N, n_envs, steps, ...):
        feats, u, resets."""
        ke, kr = jax.random.split(key)
        env = v_gs_init(jax.random.split(ke, n_envs))
        obs = v_gs_obs(env)
        h = policy_mod.initial_hidden(policy_cfg, n_envs, n_agents)
        prev_a = jnp.zeros((n_envs, n_agents), jnp.int32)
        prev_done = jnp.ones((n_envs,), bool)     # episode starts fresh

        def step(carry, k):
            env, obs, h, prev_a, prev_done = carry
            k_act, k_env, k_reset = jax.random.split(k, 3)
            feat = jnp.concatenate(
                [obs, jax.nn.one_hot(prev_a, info.n_actions)], axis=-1)
            logits, _, h2 = apply_agents(policy_params, obs, h)
            action, _ = policy_mod.sample_action(k_act, logits)
            env2, obs2, _rew, u, done = v_gs_step(
                env, action, jax.random.split(k_env, n_envs))
            fresh = v_gs_init(jax.random.split(k_reset, n_envs))
            # broadcast the per-env done flag by RANK, not by a
            # hard-coded [:, None, None]: obs/hidden leaves are (E, N, O)
            # here, but the same reset logic must hold for envs whose
            # per-agent obs is not a flat vector.
            sel = lambda f, c: jnp.where(
                done.reshape((-1,) + (1,) * (c.ndim - 1)), f, c)
            env3 = jax.tree.map(sel, fresh, env2)
            obs3 = sel(v_gs_obs(env3), obs2)
            h3 = sel(jnp.zeros_like(h2), h2)
            prev3 = sel(jnp.zeros_like(action), action)
            # reset flag marks "new episode starts HERE" (before this feat)
            rec = {"feats": feat, "u": u,
                   "resets": jnp.broadcast_to(prev_done[:, None],
                                              (n_envs, n_agents))
                   .astype(jnp.float32)}
            return (env3, obs3, h3, prev3, done), rec

        _, recs = jax.lax.scan(step, (env, obs, h, prev_a, prev_done),
                               jax.random.split(kr, steps))
        # (T, E, N, ...) -> (N, E, T, ...)
        def rearrange(x):
            return jnp.moveaxis(x, (0, 1, 2), (2, 1, 0))
        return {"feats": rearrange(recs["feats"]),
                "u": rearrange(recs["u"]),
                "resets": rearrange(recs["resets"])}

    return jax.jit(collect)
