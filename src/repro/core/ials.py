"""Algorithm 3 — influence-augmented local simulators, batched.

Each agent trains on its OWN local simulator whose inflow/coupling
variables are sampled from its AIP every step: u ~ Î_θi(·|l_i^t), then
x^{t+1} ~ T̂_i(·|x, a, u). There is NO cross-agent interaction inside this
loop — N agents × E envs roll and update as one embarrassingly-parallel
batched program (vmap over agents; shard the agent axis over the mesh and
between AIP refreshes the program has zero cross-shard collectives, which
is the paper's runtime-stays-constant claim).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import influence
from repro.marl import gae as gae_mod
from repro.marl import policy as policy_mod
from repro.marl import ppo as ppo_mod
from repro.optim import adamw


def make_ials_trainer(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                      aip_cfg: influence.AIPConfig,
                      ppo_cfg: ppo_mod.PPOConfig, *, n_envs: int,
                      rollout_steps: int):
    info = env_cfg.info()
    n_agents = info.n_agents

    # local sims batched over (E, N)
    v_ls_init = jax.vmap(jax.vmap(lambda k: env_mod.ls_init(k, env_cfg)))
    v_ls_step = jax.vmap(jax.vmap(
        lambda l, a, u, k: env_mod.ls_step(l, a, u, k, env_cfg)))
    v_ls_obs = jax.vmap(jax.vmap(lambda l: env_mod.ls_obs(l, env_cfg)))

    apply_agents = jax.vmap(
        lambda p, o, h: policy_mod.policy_apply(p, o, h, policy_cfg),
        in_axes=(0, 1, 1), out_axes=(1, 1, 1))
    aip_agents = jax.vmap(
        lambda p, f, h: influence.aip_apply(p, f, h, aip_cfg),
        in_axes=(0, 1, 1), out_axes=(1, 1))

    def init_fn(key):
        kp, ke, kr = jax.random.split(key, 3)
        params = jax.vmap(lambda k: policy_mod.policy_init(k, policy_cfg))(
            jax.random.split(kp, n_agents))
        opt = jax.vmap(adamw.init)(params)
        locals_ = v_ls_init(
            jax.random.split(ke, n_envs * n_agents).reshape(
                n_envs, n_agents, 2))
        return {
            "params": params, "opt": opt, "locals": locals_,
            "obs": v_ls_obs(locals_),
            "h": policy_mod.initial_hidden(policy_cfg, n_envs, n_agents),
            "aip_h": influence.initial_hidden(aip_cfg, n_envs, n_agents),
            "prev_a": jnp.zeros((n_envs, n_agents), jnp.int32),
            "key": kr, "iter": jnp.zeros((), jnp.int32),
        }

    def _rollout(state, aip_params):
        def step(carry, key):
            locals_, obs, h, aip_h, prev_a, prev_done = carry
            k_act, k_u, k_env, k_reset = jax.random.split(key, 4)

            # AIP consumes (x_t, a_{t-1}) and proposes u_t  (Alg. 3 line 8)
            feat = jnp.concatenate(
                [obs, jax.nn.one_hot(prev_a, info.n_actions)], axis=-1)
            u_logits, aip_h2 = aip_agents(aip_params, feat, aip_h)
            u = influence.sample_sources(k_u, u_logits)      # (E, N, M)

            logits, value, h2 = apply_agents(state["params"], obs, h)
            action, logp = policy_mod.sample_action(k_act, logits)

            locals2, obs2, rew, done = v_ls_step(
                locals_, action, u,
                jax.random.split(k_env, n_envs * n_agents).reshape(
                    n_envs, n_agents, 2))                    # done (E, N)

            fresh = v_ls_init(
                jax.random.split(k_reset, n_envs * n_agents).reshape(
                    n_envs, n_agents, 2))
            sel = lambda f, c: jnp.where(
                done.reshape(done.shape + (1,) * (c.ndim - 2)), f, c)
            locals3 = jax.tree.map(sel, fresh, locals2)
            obs3 = jnp.where(done[..., None], v_ls_obs(locals3), obs2)
            h3 = jnp.where(done[..., None], jnp.zeros_like(h2), h2)
            aip_h3 = jnp.where(done[..., None], jnp.zeros_like(aip_h2),
                               aip_h2)
            prev3 = jnp.where(done, jnp.zeros_like(action), action)

            tr = {"obs": obs, "action": action, "logp": logp, "value": value,
                  "reward": rew, "done": done, "h_pre": h,
                  "reset_pre": prev_done}
            return (locals3, obs3, h3, aip_h3, prev3, done), tr

        carry0 = (state["locals"], state["obs"], state["h"], state["aip_h"],
                  state["prev_a"], jnp.zeros((n_envs, n_agents), bool))
        carry, traj = jax.lax.scan(
            step, carry0, jax.random.split(state["key"], rollout_steps))
        return carry, traj

    def train_fn(state, aip_params):
        """One DIALS inner iteration: rollout on the IALS + PPO for every
        agent. ``aip_params`` stacked (N, ...) — frozen here (Alg. 1 line 9)."""
        k_iter = jax.random.fold_in(state["key"], state["iter"])
        state = {**state, "key": k_iter}
        carry, traj = _rollout(state, aip_params)
        locals_, obs, h, aip_h, prev_a, _ = carry

        _, last_value, _ = apply_agents(state["params"], obs, h)  # (E, N)

        def nea(x):                            # (T,E,N) -> (E,N,T)
            return jnp.moveaxis(x, (0, 1, 2), (2, 0, 1))
        adv, ret = gae_mod.gae(nea(traj["reward"]), nea(traj["value"]),
                               nea(traj["done"]), last_value,
                               gamma=ppo_cfg.gamma, lam=ppo_cfg.lam)

        def net(x):                            # (T,E,N,...) -> (N,E,T,...)
            return jnp.moveaxis(x, (0, 1, 2), (2, 1, 0))
        batch = {
            "obs": net(traj["obs"]),
            "actions": net(traj["action"]).astype(jnp.int32),
            "logp_old": net(traj["logp"]),
            "values_old": net(traj["value"]),
            "adv": jnp.swapaxes(adv, 0, 1),
            "ret": jnp.swapaxes(ret, 0, 1),
            "resets": net(traj["reset_pre"]).astype(jnp.float32),
            "h0": jnp.moveaxis(traj["h_pre"][0], 1, 0),
        }
        keys = jax.random.split(jax.random.fold_in(k_iter, 1), n_agents)
        new_params, new_opt, metrics = jax.vmap(
            lambda p, o, b, k: ppo_mod.ppo_update(p, o, b, k, policy_cfg,
                                                  ppo_cfg))(
            state["params"], state["opt"], batch, keys)
        new_state = {**state, "params": new_params, "opt": new_opt,
                     "locals": locals_, "obs": obs, "h": h, "aip_h": aip_h,
                     "prev_a": prev_a, "iter": state["iter"] + 1}
        return new_state, {**jax.tree.map(jnp.mean, metrics),
                           "reward": traj["reward"].mean()}

    return init_fn, jax.jit(train_fn)
