"""Algorithm 3 — influence-augmented local simulators, batched.

Each agent trains on its OWN local simulator whose inflow/coupling
variables are sampled from its AIP every step: u ~ Î_θi(·|l_i^t), then
x^{t+1} ~ T̂_i(·|x, a, u). There is NO cross-agent interaction inside this
loop — the whole inner iteration is written as a *single-agent* program
(:func:`make_agent_trainer`) and vmapped over an agent-major state, so N
agents × E envs roll and update as one embarrassingly-parallel batched
program.

Shard-equivariance contract (the sharded DIALS runtime depends on it):
every random draw inside the per-agent step derives from that agent's OWN
key (``state["key"][i]``, fixed at init from the absolute agent id) — no
draw depends on how many agents share the batch. Slicing the agent axis
and running a shard therefore computes exactly what the full-batch program
computes for those agents, which is how ``repro.core.dials_sharded`` gets
single-device ≡ sharded numerics and zero cross-shard collectives between
AIP refreshes (the paper's runtime-stays-constant claim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import env_pool
from repro.core import influence
from repro.marl import gae as gae_mod
from repro.marl import policy as policy_mod
from repro.marl import ppo as ppo_mod
from repro.optim import adamw


def make_agent_trainer(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                       aip_cfg: influence.AIPConfig,
                       ppo_cfg: ppo_mod.PPOConfig, *, n_envs: int,
                       rollout_steps: int):
    """One DIALS inner iteration for ONE agent (Algorithm 3 body).

    Returns ``agent_train(astate, aip_params) -> (astate', metrics)`` where
    ``astate`` holds E local sims and the agent's policy/opt/key/iter —
    leaves WITHOUT the agent axis. ``jax.vmap(agent_train)`` is the
    full-batch trainer; a shard_map'd vmap over an agent slice is the
    sharded one.
    """
    info = env_cfg.info()

    # local sims batched over E streams of one agent (auto-reset pool)
    pool = env_pool.LSPool(env_mod, env_cfg, n_envs)
    # per-stream draws: stream e samples its action and its influence
    # sources from its OWN step keys, so the bits depend on (key, e, t),
    # never on how many streams share the batch (S-prefix invariance)
    sample_act_streams = jax.vmap(policy_mod.sample_action)
    sample_u_streams = jax.vmap(influence.sample_sources)

    def _rollout(astate, aip_params, k_roll):
        skeys = env_pool.stream_keys(k_roll, n_envs)

        def step(carry, t):
            locals_, obs, h, aip_h, prev_a, prev_done = carry   # (E, ...)
            k_act, k_u, k_env, k_reset = env_pool.step_keys(skeys, t, 4)

            # AIP consumes (x_t, a_{t-1}) and proposes u_t  (Alg. 3 line 8)
            feat = jnp.concatenate(
                [obs, jax.nn.one_hot(prev_a, info.n_actions)], axis=-1)
            u_logits, aip_h2 = influence.aip_apply(
                aip_params, feat, aip_h, aip_cfg)
            u = sample_u_streams(k_u, u_logits)                 # (E, M)

            logits, value, h2 = policy_mod.policy_apply(
                astate["params"], obs, h, policy_cfg)
            action, logp = sample_act_streams(k_act, logits)

            locals3, obs3, rew, done = pool.step_reset(
                locals_, action, u, k_env, k_reset)
            h3, aip_h3, prev3 = env_pool.zero_on_done(
                done, (h2, aip_h2, action))

            tr = {"obs": obs, "action": action, "logp": logp, "value": value,
                  "reward": rew, "done": done, "h_pre": h,
                  "reset_pre": prev_done}
            return (locals3, obs3, h3, aip_h3, prev3, done), tr

        carry0 = (astate["locals"], astate["obs"], astate["h"],
                  astate["aip_h"], astate["prev_a"],
                  jnp.zeros((n_envs,), bool))
        carry, traj = jax.lax.scan(
            step, carry0, jnp.arange(rollout_steps))
        return carry, traj                     # traj leaves (T, E, ...)

    def agent_train(astate, aip_params):
        """Rollout on the IALS + one PPO update. ``aip_params`` — this
        agent's predictor, frozen here (Alg. 1 line 9)."""
        k_iter = jax.random.fold_in(astate["key"], astate["iter"])
        # separate roots for the rollout's stream chains and the PPO
        # minibatch shuffle — fold_in(k_iter, e) is the STREAM-e root,
        # so the PPO key must not be a small fold-in of k_iter itself
        k_roll, k_ppo = jax.random.split(k_iter)
        carry, traj = _rollout(astate, aip_params, k_roll)
        locals_, obs, h, aip_h, prev_a, _ = carry

        _, last_value, _ = policy_mod.policy_apply(
            astate["params"], obs, h, policy_cfg)               # (E,)

        et = lambda x: jnp.swapaxes(x, 0, 1)   # (T, E, ...) -> (E, T, ...)
        adv, ret = gae_mod.gae(et(traj["reward"]), et(traj["value"]),
                               et(traj["done"]), last_value,
                               gamma=ppo_cfg.gamma, lam=ppo_cfg.lam,
                               use_kernels=ppo_cfg.use_kernels)
        batch = {
            "obs": et(traj["obs"]),
            "actions": et(traj["action"]).astype(jnp.int32),
            "logp_old": et(traj["logp"]),
            "values_old": et(traj["value"]),
            "adv": adv,
            "ret": ret,
            "resets": et(traj["reset_pre"]).astype(jnp.float32),
            "h0": traj["h_pre"][0],            # (E, H)
        }
        new_params, new_opt, metrics = ppo_mod.ppo_update(
            astate["params"], astate["opt"], batch,
            k_ppo, policy_cfg, ppo_cfg)
        new_astate = {**astate, "params": new_params, "opt": new_opt,
                      "locals": locals_, "obs": obs, "h": h, "aip_h": aip_h,
                      "prev_a": prev_a, "iter": astate["iter"] + 1}
        return new_astate, {**metrics, "reward": traj["reward"].mean()}

    return agent_train


def make_ials_init(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                   aip_cfg: influence.AIPConfig, *, n_envs: int):
    """Agent-major IALS state init: every leaf has leading axis N, so the
    whole state shards over the agent axis with one PartitionSpec."""
    info = env_cfg.info()
    n_agents = info.n_agents
    pool = env_pool.LSPool(env_mod, env_cfg, n_envs)

    def init_fn(key):
        kp, ke, kr = jax.random.split(key, 3)
        params = jax.vmap(lambda k: policy_mod.policy_init(k, policy_cfg))(
            jax.random.split(kp, n_agents))
        opt = jax.vmap(adamw.init)(params)
        # per-(agent, stream) init chains fold in the ABSOLUTE agent id
        # then the ABSOLUTE stream id: growing E (or slicing the agent
        # axis onto shards) preserves every existing local sim bitwise
        locals_ = jax.vmap(
            lambda ka: pool.init(env_pool.stream_keys(ka, n_envs)))(
            env_pool.stream_keys(ke, n_agents))
        v_ls_obs = jax.vmap(jax.vmap(lambda l: env_mod.ls_obs(l, env_cfg)))
        # per-agent keys fold in the ABSOLUTE agent id: the draw stream of
        # agent i is identical no matter how the agent axis is sliced.
        keys = jax.vmap(lambda i: jax.random.fold_in(kr, i))(
            jnp.arange(n_agents))
        return {
            "params": params, "opt": opt, "locals": locals_,
            "obs": v_ls_obs(locals_),
            "h": policy_mod.initial_hidden(policy_cfg, n_agents, n_envs),
            "aip_h": influence.initial_hidden(aip_cfg, n_agents, n_envs),
            "prev_a": jnp.zeros((n_agents, n_envs), jnp.int32),
            "key": keys, "iter": jnp.zeros((n_agents,), jnp.int32),
        }

    return init_fn


def make_ials_trainer(env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                      aip_cfg: influence.AIPConfig,
                      ppo_cfg: ppo_mod.PPOConfig, *, n_envs: int,
                      rollout_steps: int):
    """Full-batch (single-device) trainer: ``(init_fn, train_fn)`` with
    ``train_fn(state, aip_params (N, ...)) -> (state, scalar metrics)``."""
    init_fn = make_ials_init(env_mod, env_cfg, policy_cfg, aip_cfg,
                             n_envs=n_envs)
    agent_train = make_agent_trainer(
        env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg,
        n_envs=n_envs, rollout_steps=rollout_steps)
    train_agents = jax.vmap(agent_train)

    def train_fn(state, aip_params):
        state, metrics = train_agents(state, aip_params)
        return state, jax.tree.map(jnp.mean, metrics)

    return init_fn, jax.jit(train_fn)
