"""Algorithm 1 — MARL with Distributed Influence-Augmented Local Simulators.

The orchestrator alternates:
  1. collect per-agent (ALSH, u) datasets from the GS under the current
     joint policy (Algorithm 2; ``repro.core.gs``),
  2. train all AIPs in parallel — one vmapped update (Section 3.2),
  3. run F inner steps of IALS rollouts + PPO for every agent in parallel
     (Algorithm 3; ``repro.core.ials``) with the AIPs FROZEN,
until the step budget is exhausted. ``F`` (``aip_refresh``) is the paper's
central hyperparameter: infrequent refresh keeps each agent's local
dynamics stationary (Section 4.3), and Lemma 2/Theorem 1 bound the cost of
the staleness.

Production hooks: periodic GS evaluation, checkpoint/restart via
``CheckpointManager``, the ``untrained`` ablation (the paper's
untrained-DIALS baseline), and **bounded staleness made real**:

* ``async_collect=True`` overlaps round k+1's GS collect with round k's
  F inner steps (``repro.distributed.async_collect`` — double-buffered
  dataset slots, spare-device or host-thread dispatch). The dataset
  consumed each round carries its collection-round tag in the round
  record (``data_round``); the steady-state lag is exactly one round,
  the staleness Lemma 2 licenses.
* ``max_aip_staleness`` is enforced, not decorative: a dataset older
  than the bound triggers a blocking force-sync collect
  (``forced_sync`` in the record), and an agent whose predictor would
  fall further behind than the bound — e.g. a straggler that keeps
  missing its refresh — is force-refreshed through
  ``repro.distributed.fault.freshness_gate`` (``stale_forced``).
  ``async_collect=True, max_aip_staleness=0`` degenerates to the serial
  schedule, which is how the equivalence tests pin the semantics.

Checkpoint-resume under ``async_collect``: the in-flight dataset is not
checkpointed, but its round tag is (``extra["async_round"]``, along
with the per-agent ``reports`` vector), so a resumed run *re-primes*
the double buffer — it re-collects that dataset from the prior round's
checkpointed params under the prior round's collect key and resumes on
the exact staleness schedule of the uninterrupted run (bitwise on the
loop path; see ``_reprime_collector``). Only when the needed prior step
has been rotated away does the resume fall back to a force-sync collect
(``forced_sync=True`` — fresher data, the safe direction under
Lemma 2).

Fault tolerance: ``run(..., chaos=FaultSchedule)`` threads the
deterministic fault injector through the round loop, the checkpoint
writer, and the heartbeat monitor; on a mesh spanning processes the
sharded path checkpoints through
``checkpoint.distributed.DistributedCheckpointManager`` (per-process
agent slices, two-phase rank-0 commit), and a ``heartbeats`` callback
that raises ``recovery.HostLossDetected`` hands the loss to the
re-bootstrap supervisor (``distributed.recovery``) instead of the
in-group elastic path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core import gs as gs_mod
from repro.core import ials as ials_mod
from repro.core import influence
from repro.distributed import async_collect as async_mod
from repro.distributed import fault
from repro.marl import policy as policy_mod
from repro.marl import ppo as ppo_mod
from repro.marl import runner as runner_mod
from repro.obs import metrics as obs_metrics


@dataclasses.dataclass(frozen=True)
class DIALSConfig:
    aip_refresh: int = 50          # F, in inner train iterations
    outer_rounds: int = 4
    collect_envs: int = 8
    collect_steps: int = 128       # per env -> dataset size = envs*steps
    collect_holdout: int = 1       # env streams per agent held out of AIP
    #                                training; eval_ce runs on these (the
    #                                paper's held-out Fig.-4 CE). 0 = legacy
    #                                train-set CE (forced when collect_envs=1)
    untrained: bool = False        # paper's untrained-DIALS ablation
    eval_episodes: int = 8
    n_envs: int = 16
    rollout_steps: int = 16
    # The large-batch S knobs (repro.core.env_pool): stream counts for
    # the GS collect pool and the per-agent IALS pool. None defers to
    # the legacy collect_envs / n_envs values; setting them makes S a
    # pure width axis — per-stream fold-in keys mean a wider run
    # contains every narrower run's streams bitwise, and the donated
    # ring buffers + chunked AIP training keep peak memory ~one dataset
    # no matter how large S grows.
    collect_streams: Optional[int] = None
    ials_streams: Optional[int] = None
    max_aip_staleness: int = 2     # rounds; straggler/async-lag tolerance
    async_collect: bool = False    # overlap round k+1's GS collect with
    #                                round k's inner steps (one-round
    #                                dataset lag, bounded by
    #                                max_aip_staleness)
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    # agent-sharded runtime (repro.core.dials_sharded): None = auto
    # (sharded whenever >1 device is visible), <=1 = force the
    # single-device path, N = force an N-shard ("shards",) mesh.
    shards: Optional[int] = None
    # Region-decomposed GS (repro.core.gs_sharded): run Algorithm 2 and
    # the periodic GS eval as shard_map'd block programs with halo
    # exchange instead of replicated joint rollouts. "auto" uses it
    # whenever the env's region_partition supports the mesh's block
    # count (and falls back to the replicated GS otherwise, e.g. a 2x2
    # grid on 4 shards); "on" requires it (raises when the topology
    # cannot tile); "off" keeps the replicated GS. Loop-path runs
    # (shards<=1 without a mesh) always use the replicated GS.
    sharded_gs: str = "auto"
    # Pallas fast paths for the inner-loop hot spots (AIP GRU, policy
    # GRU, GAE). "auto" defers to the sub-configs (which themselves
    # default to auto = kernel on TPU, oracle elsewhere); an explicit
    # "on"/"off" here overrides all three (repro.kernels.dispatch).
    use_kernels: str = "auto"
    # Runtime observability (repro.obs): a shared directory for
    # per-process JSONL event logs (typed round records, collect/fault
    # events). None = disabled — no files, no overhead, and (on the
    # sharded path) provably no change to the traced round program.
    telemetry_dir: Optional[str] = None
    # Fence host spans with block_until_ready for honest device timings
    # (loop path only — the sharded round is one fused program). Off by
    # default: fencing adds host syncs the drivers otherwise avoid.
    telemetry_fence: bool = False


def apply_kernel_mode(policy_cfg, aip_cfg, ppo_cfg, mode: str):
    """Propagate a driver-level ``use_kernels`` onto the three
    sub-configs that own a hot spot. Idempotent; "auto" is a no-op."""
    from repro.kernels import dispatch
    return (dispatch.override_mode(policy_cfg, mode),
            dispatch.override_mode(aip_cfg, mode),
            dispatch.override_mode(ppo_cfg, mode))


def collect_stream_count(cfg: DIALSConfig) -> int:
    """S for the GS collect pool: ``collect_streams``, defaulting to the
    legacy ``collect_envs``."""
    return (cfg.collect_streams if cfg.collect_streams is not None
            else cfg.collect_envs)


def ials_stream_count(cfg: DIALSConfig) -> int:
    """E for each agent's IALS pool: ``ials_streams``, defaulting to the
    legacy ``n_envs``."""
    return cfg.ials_streams if cfg.ials_streams is not None else cfg.n_envs


def holdout_sequences(cfg: DIALSConfig) -> int:
    """How many collected env streams per agent are held out for the
    held-out CE metric: ``collect_holdout`` clamped so at least one
    sequence always remains for AIP training."""
    return max(0, min(cfg.collect_holdout, collect_stream_count(cfg) - 1))


class DIALSTrainer:
    """Python-level orchestrator; every inner piece is a jitted program."""

    def __init__(self, env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                 aip_cfg: influence.AIPConfig, ppo_cfg: ppo_mod.PPOConfig,
                 cfg: DIALSConfig):
        self.env_mod, self.env_cfg = env_mod, env_cfg
        if cfg.sharded_gs not in ("auto", "on", "off"):
            raise ValueError(
                f"sharded_gs must be auto|on|off, got {cfg.sharded_gs!r}")
        policy_cfg, aip_cfg, ppo_cfg = apply_kernel_mode(
            policy_cfg, aip_cfg, ppo_cfg, cfg.use_kernels)
        self.policy_cfg, self.aip_cfg = policy_cfg, aip_cfg
        self.ppo_cfg, self.cfg = ppo_cfg, cfg
        self.info = env_cfg.info()
        self.n_eval_seqs = holdout_sequences(cfg)

        self.collect = gs_mod.make_collector(
            env_mod, env_cfg, policy_cfg,
            n_envs=collect_stream_count(cfg), steps=cfg.collect_steps)
        # the donating twin + ring: steady-state collects write into the
        # retired slot's buffers — the wide dataset never reallocates or
        # visits the host on the loop path
        self.collect_into = gs_mod.make_collector_into(
            env_mod, env_cfg, policy_cfg,
            n_envs=collect_stream_count(cfg), steps=cfg.collect_steps)
        self._ring = async_mod.DeviceRing(self.collect, self.collect_into)
        self.ials_init, self.ials_train = ials_mod.make_ials_trainer(
            env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg,
            n_envs=ials_stream_count(cfg), rollout_steps=cfg.rollout_steps)
        _, _, self.gs_eval = runner_mod.make_gs_trainer(
            env_mod, env_cfg, policy_cfg, ppo_cfg,
            runner_mod.RunConfig(n_envs=cfg.n_envs,
                                 rollout_steps=cfg.rollout_steps))
        self.train_aips = jax.jit(jax.vmap(
            lambda p, d, k: influence.train_aip(p, d, k, aip_cfg)))
        self.eval_aips = jax.jit(jax.vmap(
            lambda p, d: influence.eval_ce(p, d, aip_cfg)))
        self.aip_round = self._make_aip_round()
        self.manager = (CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
                        if cfg.ckpt_dir else None)
        self._sharded = None       # lazily-built ShardedDIALSRunner
        self._dist_manager = None  # lazily-built DistributedCheckpointManager
        self._resume_extra = {}    # checkpoint extra of the restored step

    # -- the fused AIP round -------------------------------------------------
    def _make_aip_round(self):
        """Holdout split + held-out CE + vmapped AIP training + the
        bounded-staleness gate as ONE jitted program — the loop-path
        mirror of the sharded runner's shard body. Fusing it matters at
        large S: ``split_dataset``'s train/eval slices become in-program
        views of the ring slot instead of materialized device copies,
        and ``train_aip``'s minibatching / ``eval_ce``'s ``eval_chunk``
        already bound the per-step working set, so peak memory stays
        ~one dataset regardless of the stream count."""
        cfg, aip_cfg = self.cfg, self.aip_cfg
        n_eval = self.n_eval_seqs
        train_aips = jax.vmap(
            lambda p, d, k: influence.train_aip(p, d, k, aip_cfg))
        eval_aips = jax.vmap(lambda p, d: influence.eval_ce(p, d, aip_cfg))

        def aip_round(aips, data, aip_keys, fresh_mask, reports, rnd,
                      data_round):
            train_data, eval_data = gs_mod.split_dataset(data, n_eval)
            ce_before = eval_aips(aips, eval_data)
            forced = jnp.zeros_like(fresh_mask)
            if not cfg.untrained:
                new_aips, _ = train_aips(aips, train_data, aip_keys)
                eff, reports, forced = fault.freshness_gate(
                    fresh_mask, reports, data_round, rnd,
                    cfg.max_aip_staleness)
                aips = fault.masked_tree_update(aips, new_aips, eff)
            ce_after = eval_aips(aips, eval_data)
            return aips, reports, ce_before, ce_after, forced

        return jax.jit(aip_round)

    # -- state --------------------------------------------------------------
    def init(self, key):
        k1, k2 = jax.random.split(key)
        state = self.ials_init(k1)
        aip_params = jax.vmap(
            lambda k: influence.aip_init(k, self.aip_cfg))(
            jax.random.split(k2, self.info.n_agents))
        return {"ials": state, "aips": aip_params,
                "round": 0, "key": key}

    def _state_struct(self, state):
        return jax.tree.map(
            lambda x: (jax.ShapeDtypeStruct(x.shape, x.dtype)
                       if hasattr(x, "shape") else x), state)

    def restore_or_init(self, key):
        state = self.init(key)
        self._resume_extra = {}
        if self.manager is not None:
            tree, step = self.manager.restore_latest(
                self._state_struct(state))
            if tree is not None:
                self._resume_extra = dict(self.manager.last_extra)
                tree["round"] = int(step)
                # the base key drives the per-round fold-in stream; a
                # resumed run must continue it exactly
                tree["key"] = jnp.asarray(tree["key"], state["key"].dtype)
                return tree
        return state

    # -- path selection ------------------------------------------------------
    def _select_shards(self) -> int:
        """Shard count for the sharded runtime; 0 = single-device path."""
        from repro.distributed import runtime as runtime_lib
        cfg, n_agents = self.cfg, self.info.n_agents
        n_dev = len(jax.devices())
        if cfg.shards is not None:
            if cfg.shards <= 1:
                return 0
            if cfg.shards > n_dev:
                raise ValueError(
                    f"shards={cfg.shards} but only {n_dev} devices")
            if n_agents % cfg.shards:
                raise ValueError(
                    f"{n_agents} agents cannot tile {cfg.shards} shards")
            return cfg.shards
        if n_dev <= 1:
            return 0
        s = runtime_lib.choose_shards(n_agents, n_dev)
        return s if s > 1 else 0

    # -- key plumbing --------------------------------------------------------
    def _collect_key(self, base_key, rnd: int):
        """The round-``rnd`` collect key of the per-round fold-in stream —
        the same derivation the serial path (and the fused sharded round
        program) performs, so async and serial runs draw identical
        collect randomness for any given round."""
        return jax.random.split(jax.random.fold_in(base_key, rnd), 3)[0]

    # -- checkpoint-resume plumbing ------------------------------------------
    def _ckpt_extra(self, collector, reports) -> dict:
        """What a checkpoint must carry beyond the state tree for an
        exact resume: the in-flight async collect's round tag and the
        per-agent data-report rounds (staleness bookkeeping)."""
        return {"async_round": (collector.pending_round
                                if collector is not None else None),
                "reports": jax.device_get(reports).tolist()}

    def _restored_reports(self, state):
        """The resumed ``reports`` vector: the checkpointed one when
        present, else the legacy treat-AIPs-as-fresh default."""
        saved = self._resume_extra.get("reports")
        if saved is not None and len(saved) == self.info.n_agents:
            return jnp.asarray(saved, jnp.int32)
        return jnp.full((self.info.n_agents,), state["round"] - 1,
                        jnp.int32)

    def _params_at_round(self, p: int, state):
        """The joint policy params as of the TOP of round ``p`` — what
        the original run submitted its tag-``p`` collect with: the
        step-``p`` checkpoint (end of round p-1), or the deterministic
        init for p == 0. None when step ``p`` was rotated away."""
        if p <= 0:
            return self.init(state["key"])["ials"]["params"]
        tree, step = self.manager.restore_step(p, self._state_struct(state))
        return None if tree is None else tree["ials"]["params"]

    def _reprime_collector(self, collector, state, *, runner=None) -> bool:
        """Exact async resume: re-submit the interrupted run's in-flight
        collect — same params (from the prior checkpoint), same key,
        same round tag — so the resumed staleness schedule is identical
        to the uninterrupted one. False → caller falls back to the
        force-sync prime (fresher data, Lemma-2-safe)."""
        p = self._resume_extra.get("async_round")
        if p is None:
            return False
        params = self._params_at_round(int(p), state)
        if params is None:
            return False
        if runner is not None:
            from repro.distributed import runtime as runtime_lib
            params = runtime_lib.shard_agent_tree(params, runner.mesh)
        collector.submit(params, self._collect_key(state["key"], int(p) + 1),
                         int(p))
        return True

    def _sharded_manager(self, telemetry=obs.DISABLED):
        """The sharded path's checkpoint manager: the distributed
        per-process-slice layout with a two-phase rank-0 commit — the
        same format on one process or many, so checkpoints move freely
        across process/shard counts (elastic restarts, post-loss
        re-bootstrap)."""
        from repro.checkpoint.distributed import DistributedCheckpointManager
        if self._dist_manager is None:
            self._dist_manager = DistributedCheckpointManager(
                self.cfg.ckpt_dir, keep=self.cfg.ckpt_keep,
                process_id=jax.process_index())
        self._dist_manager.telemetry = telemetry
        return self._dist_manager

    def _make_collector_executor(self, telemetry=obs.DISABLED):
        """Loop-path executor: a host worker thread driving the ring's
        collect — every dataset still lands in a donated device slot
        (the ring's obtain-before-submit ordering makes the worker-thread
        calls safe: obtain() harvests the in-flight future before any
        force-sync submits another). Placement is deliberately left
        untouched: committing the dataset to a spare device would drag
        every downstream jit (AIP train, inner steps) into recompiles
        and cross-device transfers. The sharded driver is the one that
        collects on a spare device — it re-places the dataset onto the
        mesh explicitly."""
        return async_mod.AsyncCollector(self._ring.collect, mode="thread",
                                        telemetry=telemetry)

    # -- Algorithm 1 --------------------------------------------------------
    def run(self, key, *, log: Optional[Callable] = None,
            straggler_mask: Optional[Callable] = None,
            heartbeats: Optional[Callable] = None,
            chaos=None):
        """Runs ``outer_rounds`` rounds of (collect → AIP train → F inner
        steps). Returns (state, history). ``straggler_mask(round) ->
        (N,) {0,1}`` simulates late shards (bounded-staleness refresh,
        force-refreshed past ``max_aip_staleness``).

        ``heartbeats(round) -> iterable of dead host (process) ids``
        turns host loss survivable: called at the top of every round
        (typically ``fault.HostMonitor.gate``), and when it reports a
        host dead, that host's agent blocks are reassigned to the
        surviving shards on a shrunken mesh and training continues —
        the round record carries ``n_shards``/``reassigned``/
        ``dead_hosts``. Requires the sharded path. Detection is at
        round granularity: a host that dies *inside* a round program
        stalls that program's collectives — the monitor converts silence
        *between* rounds into a plan.

        ``chaos`` (a ``distributed.chaos.FaultSchedule``) injects the
        deterministic fault schedule: round-boundary host kills /
        interrupts via its ``round_start`` hook, checkpoint-writer
        faults via ``CheckpointManager.hooks``.

        Dispatches to the agent-sharded fused runtime whenever more than
        one device is visible (or ``cfg.shards`` forces a mesh); both
        paths compute the same numbers — the sharded one in a single
        program per round instead of ``F + 3``.
        """
        cfg = self.cfg
        state = self.restore_or_init(key)
        n_shards = self._select_shards()
        if n_shards:
            return self._run_sharded(state, n_shards, log=log,
                                     straggler_mask=straggler_mask,
                                     heartbeats=heartbeats, chaos=chaos)
        if heartbeats is not None:
            raise ValueError(
                "heartbeats= (elastic host-loss handling) requires the "
                "sharded runtime — the single-device loop path has no "
                "mesh to shrink")
        if cfg.sharded_gs == "on":
            # honor the forced mode instead of silently benchmarking the
            # replicated GS: the region-decomposed GS is a mesh program
            raise ValueError(
                "sharded_gs='on' requires the sharded runtime (more than "
                "one device, or DIALSConfig.shards > 1); the "
                "single-device loop path always uses the replicated GS")
        n = self.info.n_agents
        tel = obs.maybe(cfg.telemetry_dir, fence=cfg.telemetry_fence)
        kernels = obs_metrics.kernel_summary(self.policy_cfg, self.aip_cfg,
                                             self.ppo_cfg)
        collector = (self._make_collector_executor(tel)
                     if cfg.async_collect else None)
        if chaos is not None and self.manager is not None:
            self.manager.hooks = chaos.checkpoint_phase
        # collection round of each agent's newest trained-on dataset —
        # checkpointed (extra["reports"]) so resume keeps the schedule
        reports = self._restored_reports(state)
        if collector is not None and state["round"] > 0 \
                and cfg.max_aip_staleness > 0:
            # re-prime the interrupted in-flight collect; on failure the
            # first obtain() below force-syncs (the legacy resume)
            self._reprime_collector(collector, state)
        history = []
        t_start = time.time()
        tel.emit("run_start", path="loop", env=self.info.name,
                 n_shards=1, start_round=state["round"],
                 outer_rounds=cfg.outer_rounds,
                 async_collect=cfg.async_collect, kernels=kernels)
        try:
            for rnd in range(state["round"], cfg.outer_rounds):
                if chaos is not None:
                    chaos.round_start(rnd)
                tel.reset_spans()
                t_round = time.perf_counter()
                key = jax.random.fold_in(state["key"], rnd)
                kc, kt, ke = jax.random.split(key, 3)

                # (1) Algorithm 2: datasets from the GS. Async: consume
                # the double buffer (freshness-gated; round 0 primes with
                # a blocking collect) and launch the NEXT round's collect
                # under THIS round's entry policy — it overlaps the F
                # inner steps below and is consumed one round later.
                with tel.span("collect") as sp:
                    if collector is not None:
                        tagged, forced_sync = collector.obtain(
                            rnd, state["ials"]["params"], kc,
                            max_staleness=cfg.max_aip_staleness)
                        # pipeline the next round's collect — unless the
                        # bound forbids any lag (a tag-rnd dataset could
                        # never be consumed at rnd+1, so don't collect it)
                        if (rnd + 1 < cfg.outer_rounds and collector.idle()
                                and cfg.max_aip_staleness > 0):
                            collector.submit(
                                state["ials"]["params"],
                                self._collect_key(state["key"], rnd + 1),
                                rnd)
                        data, data_round = tagged.data, tagged.round
                    else:
                        data = self._ring.collect(state["ials"]["params"],
                                                  kc)
                        data_round, forced_sync = rnd, False
                    sp.fence(data)

                # (2) fused AIP round: holdout split + held-out CE + AIP
                # training + bounded-staleness gate, one jitted program
                # reading the ring slot in place (training is skipped for
                # untrained-DIALS — a static branch of the program)
                with tel.span("aip_train") as sp:
                    mask = (jnp.asarray(straggler_mask(rnd), jnp.float32)
                            if straggler_mask is not None
                            else jnp.ones((n,), jnp.float32))
                    (state["aips"], reports, ce_before, ce_after,
                     forced) = self.aip_round(
                        state["aips"], data, jax.random.split(kt, n),
                        mask, reports, rnd, data_round)
                    stale_forced = int(forced.sum())
                    sp.fence((ce_before, ce_after))

                # (3) F inner IALS+PPO steps, AIPs frozen
                with tel.span("inner_steps") as sp:
                    metrics = None
                    for _ in range(cfg.aip_refresh):
                        state["ials"], metrics = self.ials_train(
                            state["ials"], state["aips"])
                    sp.fence(state["ials"])

                with tel.span("gs_eval") as sp:
                    ret = sp.fence(self.gs_eval(
                        state["ials"]["params"], ke,
                        episodes=cfg.eval_episodes))
                phases = tel.phase_seconds()
                stats = obs_metrics.staleness_stats(reports, rnd)
                # collect throughput (sync path only — the async span
                # measures obtain wait, not simulator time)
                collect_span = phases.get("collect")
                env_steps = collect_stream_count(cfg) * cfg.collect_steps
                env_rate = (env_steps / collect_span
                            if collector is None and collect_span
                            else None)
                rec = obs_metrics.round_record(
                    round=rnd,
                    gs_return=ret,
                    ials_reward=(None if metrics is None
                                 else metrics["reward"]),
                    aip_ce_before=ce_before.mean(),
                    aip_ce_after=ce_after.mean(),
                    data_round=data_round,
                    forced_sync=forced_sync,
                    stale_forced=stale_forced,
                    staleness_min=stats["staleness_min"],
                    staleness_mean=stats["staleness_mean"],
                    staleness_max=stats["staleness_max"],
                    n_shards=1,
                    reassigned=0,
                    dead_hosts=[],
                    kernels=kernels,
                    collect_s=collect_span,
                    env_steps_per_s=env_rate,
                    aip_s=phases.get("aip_train"),
                    inner_s=phases.get("inner_steps"),
                    eval_s=phases.get("gs_eval"),
                    mirror_s=None,
                    round_s=time.perf_counter() - t_round,
                    wall_s=time.time() - t_start)
                tel.emit_round(rec)
                history.append(rec)
                if log:
                    log(rec)
                state["round"] = rnd + 1
                if self.manager is not None:
                    self.manager.save(rnd + 1, state,
                                      extra=self._ckpt_extra(collector,
                                                             reports))
        finally:
            if collector is not None:
                collector.close()
            tel.emit("run_end", rounds=len(history))
            tel.close()
        if self.manager is not None:
            self.manager.wait()
        return state, history

    # -- sharded path --------------------------------------------------------
    def _sharded_runner(self, n_shards: int):
        from repro.core import dials_sharded
        if self._sharded is None or self._sharded.n_shards != n_shards:
            self._sharded = dials_sharded.ShardedDIALSRunner(
                self.env_mod, self.env_cfg, self.policy_cfg, self.aip_cfg,
                self.ppo_cfg, self.cfg, n_shards=n_shards)
        return self._sharded

    def _make_sharded_collector(self, runner, telemetry=obs.DISABLED):
        """Async double-buffer for the sharded path — dispatch mode only:
        a host thread could race the donation. The region-decomposed
        collect is a mesh program — it runs on the shard devices
        themselves, so it is dispatched directly, without the
        spare-device input copy (JAX async dispatch still enqueues it
        ahead of the train program). ``spare_device`` is None on a
        multi-process mesh (runtime.spare_device owns that guard)."""
        from repro.distributed import runtime as runtime_lib
        return async_mod.AsyncCollector(
            runner.collect, mode="dispatch",
            spare_device=(None if runner.use_sharded_gs else
                          runtime_lib.spare_device(runner.n_shards)),
            telemetry=telemetry)

    def _reassign(self, runner, carry, mirror, collector, dead_hosts,
                  telemetry=obs.DISABLED):
        """Elastic shard reassignment after host loss.

        The dead hosts' shard slots are dropped, ``fault.elastic_plan``
        re-tiles the agent axis over the survivors, a new runner is
        built on the shrunken mesh, and the carry is re-placed from the
        host ``mirror`` (the end-of-previous-round snapshot every host
        holds — the on-mesh carry references the dead process's buffers
        and is unusable). Any in-flight async collect belongs to the
        dead mesh and is discarded; the next ``obtain`` force-syncs.
        Returns ``(runner, carry, collector, n_reassigned_blocks)``."""
        from repro.core import dials_sharded
        from repro.distributed import runtime as runtime_lib
        dead_shards = runtime_lib.shards_on_hosts(runner.mesh, dead_hosts)
        if not dead_shards:
            return runner, carry, collector, 0
        plan = fault.elastic_plan(
            self.info.n_agents, runner.n_shards, dead_shards,
            telemetry=telemetry if telemetry.enabled else None)
        survivors = runtime_lib.surviving_devices(runner.mesh, dead_hosts)
        new_mesh = runtime_lib.shard_mesh(plan.new_shards,
                                          devices=survivors)
        runner = dials_sharded.ShardedDIALSRunner(
            self.env_mod, self.env_cfg, self.policy_cfg, self.aip_cfg,
            self.ppo_cfg, self.cfg, mesh=new_mesh)
        self._sharded = runner
        carry = fault.reshard_agents(mirror, new_mesh)
        if collector is not None:
            collector.close()
            collector = self._make_sharded_collector(runner, telemetry)
        return runner, carry, collector, len(dead_shards)

    def _run_sharded(self, state, n_shards: int, *, log, straggler_mask,
                     heartbeats=None, chaos=None):
        """The same round loop over the mesh. Sync: one fused donated
        program per round. Async: the round is split into a collect
        program and a shard-train program — round k+1's collect is
        dispatched (onto a spare device when one exists) BEFORE round k's
        shard-train program, so it runs while the shard_map section does.
        Dispatch order also makes this donation-safe: the collect is
        enqueued with the pre-donation parameter buffers.

        With ``heartbeats`` set the run is *elastic*: every round ends
        by refreshing a host-side mirror of the carry (an all-gather on
        a multi-process mesh — the availability tax), and a lapsed
        heartbeat at the top of a round triggers ``_reassign`` before
        training continues on the shrunken mesh."""
        from repro.distributed import runtime as runtime_lib
        cfg = self.cfg
        runner = self._sharded_runner(n_shards)
        n = self.info.n_agents
        base_key = state["key"]
        carry = runner.shard_carry(
            {"aips": state["aips"], "ials": state["ials"],
             "reports": self._restored_reports(state)})
        tel = obs.maybe(cfg.telemetry_dir, fence=cfg.telemetry_fence)
        kernels = obs_metrics.kernel_summary(self.policy_cfg, self.aip_cfg,
                                             self.ppo_cfg)
        # the distributed per-slice manager works on any process count —
        # each process writes only its local agent rows, rank 0 commits
        mgr = (self._sharded_manager(tel)
               if self.manager is not None else None)
        if chaos is not None and mgr is not None:
            mgr.hooks = chaos.checkpoint_phase
        collector = (self._make_sharded_collector(runner, tel)
                     if cfg.async_collect else None)
        if collector is not None and state["round"] > 0 \
                and cfg.max_aip_staleness > 0:
            self._reprime_collector(collector, state, runner=runner)
        elastic = heartbeats is not None
        mirror = runner.unshard_carry(carry) if elastic else None
        history = []
        t_start = time.time()
        tel.emit("run_start", path="sharded", env=self.info.name,
                 n_shards=runner.n_shards, start_round=state["round"],
                 outer_rounds=cfg.outer_rounds,
                 async_collect=cfg.async_collect, elastic=elastic,
                 sharded_gs=runner.use_sharded_gs, kernels=kernels)
        try:
            for rnd in range(state["round"], cfg.outer_rounds):
                if chaos is not None:
                    # the round boundary: the one point where killing a
                    # host cannot strand survivors inside a collective
                    chaos.round_start(rnd)
                t_round = time.perf_counter()
                dead_hosts, reassigned = (), 0
                if elastic:
                    dead_hosts = tuple(heartbeats(rnd))
                    if dead_hosts:
                        runner, carry, collector, reassigned = \
                            self._reassign(runner, carry, mirror,
                                           collector, dead_hosts, tel)
                mask = (jnp.asarray(straggler_mask(rnd), jnp.float32)
                        if straggler_mask is not None and not cfg.untrained
                        else jnp.ones((n,), jnp.float32))
                if collector is None:
                    carry, rec = runner.round(carry, base_key, rnd, mask)
                    forced_sync, collect_s = False, None
                else:
                    tagged, forced_sync = collector.obtain(
                        rnd, carry["ials"]["params"],
                        self._collect_key(base_key, rnd),
                        max_staleness=cfg.max_aip_staleness)
                    # a tag-rnd dataset can only be consumed if the bound
                    # tolerates one round of lag
                    if (rnd + 1 < cfg.outer_rounds and collector.idle()
                            and cfg.max_aip_staleness > 0):
                        collector.submit(
                            carry["ials"]["params"],
                            self._collect_key(base_key, rnd + 1), rnd)
                    # agent-shard the dataset onto the mesh (it arrives on
                    # the spare device when one exists); an async transfer.
                    # Identity for the region-decomposed collect — its
                    # output is born mesh-sharded.
                    data = runner.place_dataset(tagged.data)
                    carry, rec = runner.train_round(
                        carry, data, base_key, rnd, tagged.round, mask)
                    collect_s = collector.last_obtain_wait_s
                # the ONE deliberate host sync of the round: fetching the
                # on-mesh record (telemetry scalars included — they were
                # computed inside the round program, not by extra fetches)
                raw = {k: float(v) for k, v in rec.items()}
                mirror_s = None
                if elastic:
                    # the availability tax: refresh the host mirror the
                    # NEXT round's reassignment would restore from (an
                    # all-gather on a multi-process mesh)
                    t_mirror = time.perf_counter()
                    mirror = runner.unshard_carry(carry)
                    if tel.tracer.fenced:
                        jax.block_until_ready(mirror)
                    mirror_s = time.perf_counter() - t_mirror
                rec = obs_metrics.round_record(
                    round=rnd,
                    gs_return=raw["gs_return"],
                    ials_reward=(None if cfg.aip_refresh == 0
                                 else raw["ials_reward"]),
                    aip_ce_before=raw["aip_ce_before"],
                    aip_ce_after=raw["aip_ce_after"],
                    data_round=raw["data_round"],
                    forced_sync=forced_sync,
                    stale_forced=raw["stale_forced"],
                    staleness_min=raw["staleness_min"],
                    staleness_mean=raw["staleness_mean"],
                    staleness_max=raw["staleness_max"],
                    n_shards=runner.n_shards,
                    reassigned=reassigned,
                    dead_hosts=list(dead_hosts),
                    kernels=kernels,
                    collect_s=collect_s,
                    env_steps_per_s=None,
                    aip_s=None, inner_s=None, eval_s=None,
                    mirror_s=mirror_s,
                    round_s=time.perf_counter() - t_round,
                    wall_s=time.time() - t_start)
                tel.emit_round(rec)
                history.append(rec)
                if log:
                    log(rec)
                if mgr is not None:
                    # the local-slice copy inside save() runs before the
                    # next round donates these buffers; reports is tiny
                    # ((N,) int32) but global — fetch for the extra
                    mgr.save(rnd + 1, {
                        "ials": carry["ials"], "aips": carry["aips"],
                        "round": rnd + 1, "key": base_key},
                        extra=self._ckpt_extra(
                            collector,
                            runtime_lib.fetch_tree(carry["reports"])))
        finally:
            tel.emit("run_end", rounds=len(history))
            tel.close()
        unshard = runner.unshard_carry(carry)
        unshard.pop("reports", None)     # keep both paths' state schema
        state = {**unshard, "round": cfg.outer_rounds, "key": base_key}
        if mgr is not None:
            mgr.wait()
        return state, history
