"""Algorithm 1 — MARL with Distributed Influence-Augmented Local Simulators.

The orchestrator alternates:
  1. collect per-agent (ALSH, u) datasets from the GS under the current
     joint policy (Algorithm 2; ``repro.core.gs``),
  2. train all AIPs in parallel — one vmapped update (Section 3.2),
  3. run F inner steps of IALS rollouts + PPO for every agent in parallel
     (Algorithm 3; ``repro.core.ials``) with the AIPs FROZEN,
until the step budget is exhausted. ``F`` (``aip_refresh``) is the paper's
central hyperparameter: infrequent refresh keeps each agent's local
dynamics stationary (Section 4.3), and Lemma 2/Theorem 1 bound the cost of
the staleness.

Production hooks: periodic GS evaluation, checkpoint/restart via
``CheckpointManager``, bounded-staleness AIP refresh (straggler
mitigation — late agents keep their previous AIP, which DIALS tolerates by
design), and the ``untrained`` ablation (the paper's untrained-DIALS
baseline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import gs as gs_mod
from repro.core import ials as ials_mod
from repro.core import influence
from repro.distributed import fault
from repro.marl import policy as policy_mod
from repro.marl import ppo as ppo_mod
from repro.marl import runner as runner_mod


@dataclasses.dataclass(frozen=True)
class DIALSConfig:
    aip_refresh: int = 50          # F, in inner train iterations
    outer_rounds: int = 4
    collect_envs: int = 8
    collect_steps: int = 128       # per env -> dataset size = envs*steps
    untrained: bool = False        # paper's untrained-DIALS ablation
    eval_episodes: int = 8
    n_envs: int = 16
    rollout_steps: int = 16
    max_aip_staleness: int = 2     # rounds; straggler tolerance
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    # agent-sharded runtime (repro.core.dials_sharded): None = auto
    # (sharded whenever >1 device is visible), <=1 = force the
    # single-device path, N = force an N-shard ("shards",) mesh.
    shards: Optional[int] = None


class DIALSTrainer:
    """Python-level orchestrator; every inner piece is a jitted program."""

    def __init__(self, env_mod, env_cfg, policy_cfg: policy_mod.PolicyConfig,
                 aip_cfg: influence.AIPConfig, ppo_cfg: ppo_mod.PPOConfig,
                 cfg: DIALSConfig):
        self.env_mod, self.env_cfg = env_mod, env_cfg
        self.policy_cfg, self.aip_cfg = policy_cfg, aip_cfg
        self.ppo_cfg, self.cfg = ppo_cfg, cfg
        self.info = env_cfg.info()

        self.collect = gs_mod.make_collector(
            env_mod, env_cfg, policy_cfg,
            n_envs=cfg.collect_envs, steps=cfg.collect_steps)
        self.ials_init, self.ials_train = ials_mod.make_ials_trainer(
            env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg,
            n_envs=cfg.n_envs, rollout_steps=cfg.rollout_steps)
        _, _, self.gs_eval = runner_mod.make_gs_trainer(
            env_mod, env_cfg, policy_cfg, ppo_cfg,
            runner_mod.RunConfig(n_envs=cfg.n_envs,
                                 rollout_steps=cfg.rollout_steps))
        self.train_aips = jax.jit(jax.vmap(
            lambda p, d, k: influence.train_aip(p, d, k, aip_cfg)))
        self.eval_aips = jax.jit(jax.vmap(
            lambda p, d: influence.eval_ce(p, d, aip_cfg)))
        self.manager = (CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
                        if cfg.ckpt_dir else None)
        self._sharded = None       # lazily-built ShardedDIALSRunner

    # -- state --------------------------------------------------------------
    def init(self, key):
        k1, k2 = jax.random.split(key)
        state = self.ials_init(k1)
        aip_params = jax.vmap(
            lambda k: influence.aip_init(k, self.aip_cfg))(
            jax.random.split(k2, self.info.n_agents))
        return {"ials": state, "aips": aip_params,
                "round": 0, "key": key}

    def restore_or_init(self, key):
        state = self.init(key)
        if self.manager is not None:
            tree, step = self.manager.restore_latest(
                jax.tree.map(
                    lambda x: (jax.ShapeDtypeStruct(x.shape, x.dtype)
                               if hasattr(x, "shape") else x), state))
            if tree is not None:
                tree["round"] = int(step)
                # the base key drives the per-round fold-in stream; a
                # resumed run must continue it exactly
                tree["key"] = jnp.asarray(tree["key"], state["key"].dtype)
                return tree
        return state

    # -- path selection ------------------------------------------------------
    def _select_shards(self) -> int:
        """Shard count for the sharded runtime; 0 = single-device path."""
        from repro.distributed import runtime as runtime_lib
        cfg, n_agents = self.cfg, self.info.n_agents
        n_dev = len(jax.devices())
        if cfg.shards is not None:
            if cfg.shards <= 1:
                return 0
            if cfg.shards > n_dev:
                raise ValueError(
                    f"shards={cfg.shards} but only {n_dev} devices")
            if n_agents % cfg.shards:
                raise ValueError(
                    f"{n_agents} agents cannot tile {cfg.shards} shards")
            return cfg.shards
        if n_dev <= 1:
            return 0
        s = runtime_lib.choose_shards(n_agents, n_dev)
        return s if s > 1 else 0

    # -- Algorithm 1 --------------------------------------------------------
    def run(self, key, *, log: Optional[Callable] = None,
            straggler_mask: Optional[Callable] = None):
        """Runs ``outer_rounds`` rounds of (collect → AIP train → F inner
        steps). Returns (state, history). ``straggler_mask(round) ->
        (N,) {0,1}`` simulates late shards (bounded-staleness refresh).

        Dispatches to the agent-sharded fused runtime whenever more than
        one device is visible (or ``cfg.shards`` forces a mesh); both
        paths compute the same numbers — the sharded one in a single
        program per round instead of ``F + 3``.
        """
        cfg = self.cfg
        state = self.restore_or_init(key)
        n_shards = self._select_shards()
        if n_shards:
            return self._run_sharded(state, n_shards, log=log,
                                     straggler_mask=straggler_mask)
        history = []
        t_start = time.time()
        for rnd in range(state["round"], cfg.outer_rounds):
            key = jax.random.fold_in(state["key"], rnd)
            kc, kt, ke = jax.random.split(key, 3)

            # (1) Algorithm 2: datasets from the GS
            data = self.collect(state["ials"]["params"], kc)

            # (2) parallel AIP training (skipped for untrained-DIALS)
            ce_before = self.eval_aips(state["aips"], data)
            if not cfg.untrained:
                new_aips, _ = self.train_aips(
                    state["aips"], data,
                    jax.random.split(kt, self.info.n_agents))
                if straggler_mask is not None:
                    mask = jnp.asarray(straggler_mask(rnd), jnp.float32)
                    new_aips = fault.masked_tree_update(
                        state["aips"], new_aips, mask)
                state["aips"] = new_aips
            ce_after = self.eval_aips(state["aips"], data)

            # (3) F inner IALS+PPO steps, AIPs frozen
            metrics = None
            for _ in range(cfg.aip_refresh):
                state["ials"], metrics = self.ials_train(
                    state["ials"], state["aips"])

            ret = self.gs_eval(state["ials"]["params"], ke,
                               episodes=cfg.eval_episodes)
            rec = {"round": rnd,
                   "gs_return": float(ret),
                   "ials_reward": float(metrics["reward"]),
                   "aip_ce_before": float(ce_before.mean()),
                   "aip_ce_after": float(ce_after.mean()),
                   "wall_s": time.time() - t_start}
            history.append(rec)
            if log:
                log(rec)
            state["round"] = rnd + 1
            if self.manager is not None:
                self.manager.save(rnd + 1, state)
        if self.manager is not None:
            self.manager.wait()
        return state, history

    # -- sharded path --------------------------------------------------------
    def _sharded_runner(self, n_shards: int):
        from repro.core import dials_sharded
        if self._sharded is None or self._sharded.n_shards != n_shards:
            self._sharded = dials_sharded.ShardedDIALSRunner(
                self.env_mod, self.env_cfg, self.policy_cfg, self.aip_cfg,
                self.ppo_cfg, self.cfg, n_shards=n_shards)
        return self._sharded

    def _run_sharded(self, state, n_shards: int, *, log, straggler_mask):
        """The same round loop, one fused donated program per round; the
        only per-round host sync is reading the metrics record."""
        cfg = self.cfg
        runner = self._sharded_runner(n_shards)
        n = self.info.n_agents
        base_key = state["key"]
        carry = runner.shard_carry(
            {"aips": state["aips"], "ials": state["ials"]})
        history = []
        t_start = time.time()
        for rnd in range(state["round"], cfg.outer_rounds):
            mask = (jnp.asarray(straggler_mask(rnd), jnp.float32)
                    if straggler_mask is not None and not cfg.untrained
                    else jnp.ones((n,), jnp.float32))
            carry, rec = runner.round(carry, base_key, rnd, mask)
            rec = {"round": rnd, **{k: float(v) for k, v in rec.items()},
                   "wall_s": time.time() - t_start}
            history.append(rec)
            if log:
                log(rec)
            if self.manager is not None:
                # device_get inside save() copies out before the next
                # round donates these buffers
                self.manager.save(rnd + 1, {
                    "ials": carry["ials"], "aips": carry["aips"],
                    "round": rnd + 1, "key": base_key})
        state = {**runner.unshard_carry(carry),
                 "round": cfg.outer_rounds, "key": base_key}
        if self.manager is not None:
            self.manager.wait()
        return state, history
