"""Sharded DIALS runtime — Algorithm 1 as ONE program over a device mesh.

The single-device :class:`~repro.core.dials.DIALSTrainer` pays a host
round-trip per inner step (``F + 3`` syncs per outer round). This runner
executes one full outer round — GS collect → per-shard AIP training →
F inner IALS+PPO steps → GS eval — as a **single jitted, donated-buffer
program** with the agent axis of params/opt/AIPs/locals sharded over a
1-D ``("shards",)`` mesh (``repro.distributed.runtime``):

* the per-shard section (AIP train + bounded-staleness refresh + a
  ``lax.scan`` over the F inner steps) runs under ``shard_map`` and is
  **collective-free by construction** — :meth:`inner_jaxpr` /
  :meth:`split_inner_jaxpr` expose its jaxpr so tests assert no
  cross-shard communication exists between AIP refreshes (the paper's
  runtime-stays-constant claim, made checkable);
* GS collect and the periodic GS eval run **region-decomposed on the
  same mesh** (``repro.core.gs_sharded``) whenever the env's
  ``region_partition`` supports the block count
  (``DIALSConfig.sharded_gs``: auto/on/off): block-local dynamics plus
  one halo exchange per step, the dataset emitted already agent-sharded.
  The audit extends accordingly — :meth:`audit_collectives` asserts the
  train body stays collective-free while every GS body contains ONLY
  halo-exchange collectives (``runtime.HALO_PRIMS``). With the
  replicated fallback the GS programs are the joint-policy gather points
  the partitioner inserts at the refresh boundary, as before;
* per-agent randomness comes from ``repro.core.ials``'s shard-equivariant
  keying, so the sharded round is numerically the single-device round —
  the driver can switch paths freely.

For the overlapped-collect driver (``DIALSConfig.async_collect``) the
fused round is **split in two**: :attr:`collect` (Algorithm 2 alone) and
:meth:`train_round` (everything after it, taking the dataset plus its
collection-round tag as arguments). The driver dispatches round k+1's
collect — on a spare device when the machine has one beyond the mesh —
before round k's shard-train program, so the two overlap; the per-shard
body enforces ``max_aip_staleness`` through
``repro.distributed.fault.freshness_gate`` (stragglers are tolerated up
to the bound, then force-refreshed), with the per-agent report rounds
carried on-mesh.

Host syncs per round: 1 (reading the metrics record). Telemetry holds
that line: the observability scalars (staleness distribution, CE, forced
counts — ``repro.obs.metrics``) accumulate on-mesh inside this program
and ride the same record fetch; host-side spans and sinks live entirely
in the driver, so enabling telemetry does not change the traced round
program at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dials as dials_mod
from repro.core import gs as gs_mod
from repro.core import gs_sharded
from repro.core import ials as ials_mod
from repro.core import influence
from repro.distributed import fault
from repro.distributed import runtime as runtime_lib
from repro.marl import runner as runner_mod
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


class ShardedDIALSRunner:
    """Mesh-resident executor of one Algorithm-1 outer round.

    Built by ``DIALSTrainer`` when more than one device is available (or a
    shard count is forced); owns no training-loop policy — checkpointing,
    logging, the round loop, and the async-collect double buffer stay in
    the driver.
    """

    def __init__(self, env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg, cfg,
                 *, mesh=None, n_shards=None):
        self.env_mod, self.env_cfg, self.cfg = env_mod, env_cfg, cfg
        # idempotent: a DIALSTrainer-built runner arrives pre-overridden
        policy_cfg, aip_cfg, ppo_cfg = dials_mod.apply_kernel_mode(
            policy_cfg, aip_cfg, ppo_cfg, cfg.use_kernels)
        self.aip_cfg = aip_cfg
        self.info = env_cfg.info()
        self.n_eval_seqs = dials_mod.holdout_sequences(cfg)
        n_agents = self.info.n_agents
        if mesh is None:
            if n_shards is None:
                n_shards = runtime_lib.choose_shards(n_agents)
            mesh = runtime_lib.shard_mesh(n_shards)
        self.mesh = mesh
        self.n_shards = mesh.shape[runtime_lib.SHARD_AXIS]
        if n_agents % self.n_shards:
            raise ValueError(
                f"{n_agents} agents cannot tile {self.n_shards} shards")

        self.use_sharded_gs = self._resolve_sharded_gs()
        if self.use_sharded_gs:
            # region-decomposed GS on the mesh: block-local dynamics +
            # halo exchange; dataset lands agent-sharded, no re-placement
            self.collect = gs_sharded.make_sharded_collector(
                env_mod, env_cfg, policy_cfg,
                n_envs=dials_mod.collect_stream_count(cfg),
                steps=cfg.collect_steps, mesh=self.mesh)
            self.gs_eval = gs_sharded.make_sharded_evaluator(
                env_mod, env_cfg, policy_cfg, mesh=self.mesh)
        else:
            self.collect = gs_mod.make_collector(
                env_mod, env_cfg, policy_cfg,
                n_envs=dials_mod.collect_stream_count(cfg),
                steps=cfg.collect_steps)
            _, _, self.gs_eval = runner_mod.make_gs_trainer(
                env_mod, env_cfg, policy_cfg, ppo_cfg,
                runner_mod.RunConfig(n_envs=cfg.n_envs,
                                     rollout_steps=cfg.rollout_steps))
        self.ials_init = ials_mod.make_ials_init(
            env_mod, env_cfg, policy_cfg, aip_cfg,
            n_envs=dials_mod.ials_stream_count(cfg))
        self._agent_train = ials_mod.make_agent_trainer(
            env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg,
            n_envs=dials_mod.ials_stream_count(cfg),
            rollout_steps=cfg.rollout_steps)
        self._shard_body = self._make_shard_body()
        self._train_fn = self._make_train()
        self._round_fn = self._make_round()
        # sync path: the whole round fused. async path: the driver calls
        # self.collect and train_round separately so they can overlap.
        self.round = jax.jit(self._round_fn, donate_argnums=0)
        self.train_round = jax.jit(self._train_fn, donate_argnums=0)

    # -- GS decomposition selection ------------------------------------------
    def _resolve_sharded_gs(self) -> bool:
        mode = self.cfg.sharded_gs
        if mode not in ("auto", "on", "off"):
            raise ValueError(
                f"sharded_gs must be auto|on|off, got {mode!r}")
        if mode == "off":
            return False
        ok, why = gs_sharded.partition_supported(
            self.env_mod, self.env_cfg, self.n_shards)
        if mode == "on" and not ok:
            raise ValueError(
                f"sharded_gs='on' but the GS cannot decompose into "
                f"{self.n_shards} blocks: {why}")
        return ok

    # -- per-shard program ---------------------------------------------------
    def _make_shard_body(self):
        """The collective-free section: everything between AIP refreshes.

        All arguments arrive pre-sliced to this shard's agents (leading
        axis N/num_shards) except the two replicated scalars (current
        round, dataset collection round); nothing here may touch another
        shard — the freshness gate and masked update are elementwise.
        """
        cfg, aip_cfg = self.cfg, self.aip_cfg
        n_eval = self.n_eval_seqs
        train_aips = jax.vmap(
            lambda p, d, k: influence.train_aip(p, d, k, aip_cfg))
        eval_aips = jax.vmap(lambda p, d: influence.eval_ce(p, d, aip_cfg))
        train_agents = jax.vmap(self._agent_train)

        def shard_body(aips, ials, reports, data, aip_keys, fresh_mask,
                       rnd, data_round):
            train_data, eval_data = gs_mod.split_dataset(data, n_eval)
            ce_before = eval_aips(aips, eval_data)
            forced = jnp.zeros_like(fresh_mask)
            if not cfg.untrained:
                new_aips, _ = train_aips(aips, train_data, aip_keys)
                eff, reports, forced = fault.freshness_gate(
                    fresh_mask, reports, data_round, rnd,
                    cfg.max_aip_staleness)
                aips = fault.masked_tree_update(aips, new_aips, eff)
            ce_after = eval_aips(aips, eval_data)

            def inner(ials, _):
                return train_agents(ials, aips)

            if cfg.aip_refresh:
                ials, metrics = jax.lax.scan(
                    inner, ials, None, length=cfg.aip_refresh)
                metrics = jax.tree.map(lambda x: x[-1], metrics)  # last F
            else:
                # no inner steps ran; a well-shaped placeholder keeps the
                # shard_map out_specs intact — the driver reports
                # ials_reward as null for this (static) config
                metrics = {"reward": jnp.zeros(reports.shape, jnp.float32)}
            return aips, ials, reports, ce_before, ce_after, metrics, forced

        return shard_body

    # -- abstract tracing (tests / audits) -----------------------------------
    def _abstract_carry(self):
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return {"aips": jax.eval_shape(
                    lambda k: jax.vmap(
                        lambda kk: influence.aip_init(kk, self.aip_cfg))(
                        jax.random.split(k, self.info.n_agents)), key),
                "ials": jax.eval_shape(self.ials_init, key),
                "reports": jax.ShapeDtypeStruct(
                    (self.info.n_agents,), jnp.int32)}

    def round_jaxpr(self):
        """Jaxpr of the whole fused round, traced abstractly at this
        runner's shapes (no FLOPs)."""
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        carry = self._abstract_carry()
        rnd = jax.ShapeDtypeStruct((), jnp.int32)
        mask = jax.ShapeDtypeStruct((self.info.n_agents,), jnp.float32)
        return jax.make_jaxpr(self._round_fn)(carry, key, rnd, mask)

    def train_round_jaxpr(self):
        """Jaxpr of the shard-train program of the SPLIT round (the async
        path's second half: AIP train + F inner steps + GS eval, dataset
        passed in)."""
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        carry = self._abstract_carry()
        data = jax.eval_shape(self.collect, carry["ials"]["params"], key)
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        mask = jax.ShapeDtypeStruct((self.info.n_agents,), jnp.float32)
        return jax.make_jaxpr(self._train_fn)(
            carry, data, key, scalar, scalar, mask)

    def _classify_bodies(self, jaxpr, what):
        """Split a traced program's shard_map bodies into (train body,
        GS bodies). The train body is the unique collective-free one;
        every other shard_map is a region-decomposed GS program, which
        always carries its halo ppermutes. With the replicated-GS
        fallback the program contains exactly the one train shard_map."""
        bodies = runtime_lib.find_shard_map_jaxprs(jaxpr)
        train = [b for b in bodies
                 if not runtime_lib.collectives_in_jaxpr(b)]
        gs_bodies = [b for b in bodies
                     if runtime_lib.collectives_in_jaxpr(b)]
        assert len(train) == 1, \
            f"expected exactly one collective-free shard_map (the " \
            f"train body) in the {what}, found {len(train)} among " \
            f"{len(bodies)} shard_maps"
        n_gs = (2 if self.use_sharded_gs and what == "round" else
                1 if self.use_sharded_gs else 0)
        assert len(gs_bodies) == n_gs, \
            f"expected {n_gs} GS shard_maps in the {what}, " \
            f"found {len(gs_bodies)}"
        return train[0], gs_bodies

    def inner_jaxpr(self):
        """The per-shard train body of the round, EXTRACTED from the
        traced fused round program (not re-traced separately) — the
        artifact the no-collectives assertion runs against. Everything
        between AIP refreshes lives inside this one shard_map."""
        return self._classify_bodies(self.round_jaxpr(), "round")[0]

    def split_inner_jaxpr(self):
        """Same audit artifact, extracted from the split shard-train
        program the async-collect driver actually runs."""
        return self._classify_bodies(
            self.train_round_jaxpr(), "shard-train program")[0]

    def gs_jaxprs(self):
        """The region-decomposed GS bodies of the fused round (collect +
        eval; empty with the replicated fallback) — the artifacts the
        halo-only assertion runs against."""
        return self._classify_bodies(self.round_jaxpr(), "round")[1]

    def contract_programs(self):
        """Both round programs and their extracted bodies as tagged
        ``repro.analysis.contracts.Program`` records — what the static
        checker (``tools/check_programs.py``) and
        :meth:`audit_collectives` feed the rule engine."""
        from repro.analysis.contracts import Program
        programs = []
        for what, role, jaxpr in (
                ("round", "round", self.round_jaxpr()),
                ("shard-train program", "train_round",
                 self.train_round_jaxpr())):
            train, gs_bodies = self._classify_bodies(jaxpr, what)
            programs.append(Program(
                name=f"{what} per-shard train body",
                roles=("train_body",), jaxpr=train))
            programs.extend(Program(
                name=f"{what} GS body", roles=("gs_body",), jaxpr=body)
                for body in gs_bodies)
        return programs

    def audit_collectives(self):
        """The full communication contract of both round programs, as
        one executable check through the rule engine: the train body is
        collective-free, and every GS body contains exactly the
        halo-exchange collectives and nothing else — violations raise
        with the offending primitive's source line."""
        from repro.analysis import contracts
        contracts.raise_findings(contracts.run_rules(
            self.contract_programs(),
            rules=(contracts.CollectiveFree(), contracts.HaloOnly())))

    # -- the shard-train program ---------------------------------------------
    def _make_train(self):
        cfg, mesh = self.cfg, self.mesh
        n_agents = self.info.n_agents
        sharded = P(runtime_lib.SHARD_AXIS)
        body = runtime_lib.shard_map_nocheck(
            self._shard_body, mesh,
            in_specs=(sharded,) * 6 + (P(), P()),
            out_specs=(sharded,) * 7)

        def train_fn(carry, data, base_key, rnd, data_round, fresh_mask):
            """carry = {"aips", "ials", "reports"} (donated). ``data`` is
            the round's dataset, ``data_round`` its collection tag (= rnd
            on the serial schedule, rnd-1 in the async steady state).
            Returns (carry', rec)."""
            key = jax.random.fold_in(base_key, rnd)
            _kc, kt, ke = jax.random.split(key, 3)

            # (2)+(3) per-shard: AIP train + staleness gate + F frozen-AIP
            # inner steps
            with obs_trace.annotate("shard_train"):
                aips, ials, reports, ce_before, ce_after, metrics, \
                    forced = body(
                        carry["aips"], carry["ials"], carry["reports"],
                        data, jax.random.split(kt, n_agents), fresh_mask,
                        jnp.asarray(rnd, jnp.int32),
                        jnp.asarray(data_round, jnp.int32))

            # (4) periodic GS eval — the once-per-round joint-policy sync
            with obs_trace.annotate("gs_eval"):
                ret = self.gs_eval(ials["params"], ke,
                                   episodes=cfg.eval_episodes)
            # telemetry scalars accumulate here, ON-MESH, outside the
            # shard_map body (cross-shard reductions are legal at this
            # level, like the CE means): they ride the one existing
            # per-round record fetch — zero extra host syncs
            rec = {"gs_return": ret,
                   "ials_reward": metrics["reward"].mean(),
                   "aip_ce_before": ce_before.mean(),
                   "aip_ce_after": ce_after.mean(),
                   "data_round": jnp.asarray(data_round, jnp.int32),
                   "stale_forced": forced.sum(),
                   **obs_metrics.staleness_stats(reports, rnd)}
            return {"aips": aips, "ials": ials, "reports": reports}, rec

        return train_fn

    # -- the fused round -----------------------------------------------------
    def _make_round(self):
        def round_fn(carry, base_key, rnd, fresh_mask):
            """The serial schedule: collect under THIS round's policy
            (data_round = rnd), then the shard-train section, one fused
            donated program."""
            key = jax.random.fold_in(base_key, rnd)
            kc, _kt, _ke = jax.random.split(key, 3)

            # (1) Algorithm 2: datasets from the GS under the joint policy
            with obs_trace.annotate("gs_collect"):
                data = self.collect(carry["ials"]["params"], kc)
            return self._train_fn(carry, data, base_key, rnd, rnd,
                                  fresh_mask)

        return round_fn

    # -- placement -----------------------------------------------------------
    def place_dataset(self, data):
        """Agent-shard a collected dataset onto the mesh (leaves are
        agent-major, (N, S, T, ...)). The async driver uses this to move
        a spare-device collect result next to the shard-train program;
        the region-decomposed collector already emits mesh-sharded
        leaves, so this is the identity there (no post-collect
        re-placement — the contract of the sharded GS)."""
        if self.use_sharded_gs:
            return data
        return runtime_lib.shard_agent_tree(data, self.mesh)

    def shard_carry(self, carry):
        """Move an {"aips", "ials", "reports"} carry onto the mesh,
        agent-sharded."""
        return runtime_lib.shard_agent_tree(carry, self.mesh)

    def unshard_carry(self, carry):
        """Fetch a mesh-resident carry back to host-addressable arrays
        (checkpointing, path switching, the elastic driver's host
        mirror). On a mesh spanning processes this is an all-gather —
        every process ends up holding every agent's block, which is
        exactly what lets a surviving host adopt a dead host's agents."""
        return runtime_lib.fetch_tree(carry)
