"""Sharded DIALS runtime — Algorithm 1 as ONE program over a device mesh.

The single-device :class:`~repro.core.dials.DIALSTrainer` pays a host
round-trip per inner step (``F + 3`` syncs per outer round). This runner
executes one full outer round — GS collect → per-shard AIP training →
F inner IALS+PPO steps → GS eval — as a **single jitted, donated-buffer
program** with the agent axis of params/opt/AIPs/locals sharded over a
1-D ``("shards",)`` mesh (``repro.distributed.runtime``):

* the per-shard section (AIP train + bounded-staleness refresh + a
  ``lax.scan`` over the F inner steps) runs under ``shard_map`` and is
  **collective-free by construction** — :meth:`inner_jaxpr` exposes its
  jaxpr so tests assert no cross-shard communication exists between AIP
  refreshes (the paper's runtime-stays-constant claim, made checkable);
* GS collect and the periodic GS eval need the full joint policy and
  happen at the refresh boundary, where the partitioner inserts the one
  gather per round that DIALS fundamentally requires;
* per-agent randomness comes from ``repro.core.ials``'s shard-equivariant
  keying, so the sharded round is numerically the single-device round —
  the driver can switch paths freely.

Host syncs per round: 1 (reading the metrics record).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import gs as gs_mod
from repro.core import ials as ials_mod
from repro.core import influence
from repro.distributed import fault
from repro.distributed import runtime as runtime_lib
from repro.marl import runner as runner_mod


class ShardedDIALSRunner:
    """Mesh-resident executor of one Algorithm-1 outer round.

    Built by ``DIALSTrainer`` when more than one device is available (or a
    shard count is forced); owns no training-loop policy — checkpointing,
    logging and the round loop stay in the driver.
    """

    def __init__(self, env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg, cfg,
                 *, mesh=None, n_shards=None):
        self.env_mod, self.env_cfg, self.cfg = env_mod, env_cfg, cfg
        self.aip_cfg = aip_cfg
        self.info = env_cfg.info()
        n_agents = self.info.n_agents
        if mesh is None:
            if n_shards is None:
                n_shards = runtime_lib.choose_shards(n_agents)
            mesh = runtime_lib.shard_mesh(n_shards)
        self.mesh = mesh
        self.n_shards = mesh.shape[runtime_lib.SHARD_AXIS]
        if n_agents % self.n_shards:
            raise ValueError(
                f"{n_agents} agents cannot tile {self.n_shards} shards")

        self.collect = gs_mod.make_collector(
            env_mod, env_cfg, policy_cfg,
            n_envs=cfg.collect_envs, steps=cfg.collect_steps)
        self.ials_init = ials_mod.make_ials_init(
            env_mod, env_cfg, policy_cfg, aip_cfg, n_envs=cfg.n_envs)
        self._agent_train = ials_mod.make_agent_trainer(
            env_mod, env_cfg, policy_cfg, aip_cfg, ppo_cfg,
            n_envs=cfg.n_envs, rollout_steps=cfg.rollout_steps)
        _, _, self.gs_eval = runner_mod.make_gs_trainer(
            env_mod, env_cfg, policy_cfg, ppo_cfg,
            runner_mod.RunConfig(n_envs=cfg.n_envs,
                                 rollout_steps=cfg.rollout_steps))
        self._shard_body = self._make_shard_body()
        self._round_fn = self._make_round()
        self.round = jax.jit(self._round_fn, donate_argnums=0)

    # -- per-shard program ---------------------------------------------------
    def _make_shard_body(self):
        """The collective-free section: everything between AIP refreshes.

        All arguments arrive pre-sliced to this shard's agents (leading
        axis N/num_shards); nothing here may touch another shard.
        """
        cfg, aip_cfg = self.cfg, self.aip_cfg
        train_aips = jax.vmap(
            lambda p, d, k: influence.train_aip(p, d, k, aip_cfg))
        eval_aips = jax.vmap(lambda p, d: influence.eval_ce(p, d, aip_cfg))
        train_agents = jax.vmap(self._agent_train)

        def shard_body(aips, ials, data, aip_keys, fresh_mask):
            ce_before = eval_aips(aips, data)
            if not cfg.untrained:
                new_aips, _ = train_aips(aips, data, aip_keys)
                aips = fault.masked_tree_update(aips, new_aips, fresh_mask)
            ce_after = eval_aips(aips, data)

            def inner(ials, _):
                return train_agents(ials, aips)

            ials, metrics = jax.lax.scan(
                inner, ials, None, length=cfg.aip_refresh)
            metrics = jax.tree.map(lambda x: x[-1], metrics)  # last F step
            return aips, ials, ce_before, ce_after, metrics

        return shard_body

    def round_jaxpr(self):
        """Jaxpr of the whole fused round, traced abstractly at this
        runner's shapes (no FLOPs)."""
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        carry = {"aips": jax.eval_shape(
                     lambda k: jax.vmap(
                         lambda kk: influence.aip_init(kk, self.aip_cfg))(
                         jax.random.split(k, self.info.n_agents)), key),
                 "ials": jax.eval_shape(self.ials_init, key)}
        rnd = jax.ShapeDtypeStruct((), jnp.int32)
        mask = jax.ShapeDtypeStruct((self.info.n_agents,), jnp.float32)
        return jax.make_jaxpr(self._round_fn)(carry, key, rnd, mask)

    def inner_jaxpr(self):
        """The per-shard body of the round, EXTRACTED from the traced
        round program (not re-traced separately) — the artifact the
        no-collectives assertion runs against. Everything between AIP
        refreshes lives inside this one shard_map."""
        bodies = runtime_lib.find_shard_map_jaxprs(self.round_jaxpr())
        assert len(bodies) == 1, \
            f"expected exactly one shard_map in the round, found {len(bodies)}"
        return bodies[0]

    # -- the fused round -----------------------------------------------------
    def _make_round(self):
        cfg, mesh = self.cfg, self.mesh
        n_agents = self.info.n_agents
        sharded = P(runtime_lib.SHARD_AXIS)
        body = runtime_lib.shard_map_nocheck(
            self._shard_body, mesh,
            in_specs=(sharded,) * 5,
            out_specs=(sharded,) * 5)

        def round_fn(carry, base_key, rnd, fresh_mask):
            """carry = {"aips", "ials"} (donated). Returns (carry', rec)."""
            key = jax.random.fold_in(base_key, rnd)
            kc, kt, ke = jax.random.split(key, 3)

            # (1) Algorithm 2: datasets from the GS under the joint policy
            data = self.collect(carry["ials"]["params"], kc)

            # (2)+(3) per-shard: AIP train + F frozen-AIP inner steps
            aips, ials, ce_before, ce_after, metrics = body(
                carry["aips"], carry["ials"], data,
                jax.random.split(kt, n_agents), fresh_mask)

            # (4) periodic GS eval — the once-per-round joint-policy sync
            ret = self.gs_eval(ials["params"], ke,
                               episodes=cfg.eval_episodes)
            rec = {"gs_return": ret,
                   "ials_reward": metrics["reward"].mean(),
                   "aip_ce_before": ce_before.mean(),
                   "aip_ce_after": ce_after.mean()}
            return {"aips": aips, "ials": ials}, rec

        return round_fn

    # -- placement -----------------------------------------------------------
    def shard_carry(self, carry):
        """Move an {"aips", "ials"} carry onto the mesh, agent-sharded."""
        return runtime_lib.shard_agent_tree(carry, self.mesh)

    def unshard_carry(self, carry):
        """Fetch a mesh-resident carry back to host-addressable arrays
        (checkpointing, path switching)."""
        return jax.tree.map(jax.device_get, carry)
