"""Approximate Influence Predictors (AIPs) — Section 3.2 / Appendix E.

The AIP Î_θi(u_i^t | l_i^t) estimates the posterior over the binary
influence sources given the action-local-state history. Following the
paper: an FNN head when the current local state d-separates the history
(traffic), a GRU otherwise (warehouse); M independent Bernoulli heads
share a representation trunk (Eq. 25); trained with cross-entropy on
(ALSH, u) pairs collected from the GS (Algorithm 2).

Per-agent AIPs are stacked along a leading agent axis and trained with a
single vmapped update — N agents' predictors optimize as one batched
program.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn import gru as gru_mod
from repro.nn import init as initializers
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class AIPConfig:
    in_dim: int                 # ALSH feature dim: local obs + prev action
    n_sources: int              # M binary influence sources
    kind: str = "fnn"           # fnn (traffic) | gru (warehouse)
    hidden: Tuple[int, ...] = (128, 128)
    gru_hidden: int = 64
    lr: float = 1e-4
    epochs: int = 100
    batch: int = 128
    use_kernels: str = "auto"   # Pallas GRU scan in aip_sequence/train_aip:
    #                             auto (kernel on TPU) | on | off
    eval_chunk: int = 64        # eval_ce sequence-chunk size (memory cap)


def _dense_init(key, din, dout):
    return {"w": initializers.orthogonal(jnp.sqrt(2.0))(
        key, (din, dout), jnp.float32),
        "b": jnp.zeros((dout,), jnp.float32)}


def _dense(p, x):
    return x @ p["w"] + p["b"]


def aip_init(key, cfg: AIPConfig):
    keys = jax.random.split(key, 5)
    params = {}
    din = cfg.in_dim
    trunk = []
    for i, hdim in enumerate(cfg.hidden):
        trunk.append(_dense_init(keys[i], din, hdim))
        din = hdim
    params["trunk"] = trunk
    if cfg.kind == "gru":
        params["gru"] = gru_mod.gru_init(
            keys[3], gru_mod.GRUConfig(in_dim=din, hidden=cfg.gru_hidden))
        din = cfg.gru_hidden
    params["heads"] = _dense_init(keys[4], din, cfg.n_sources)
    return params


def initial_hidden(cfg: AIPConfig, *batch):
    return jnp.zeros(tuple(batch) + (cfg.gru_hidden,), jnp.float32)


def _trunk(params, x):
    for p in params["trunk"]:
        x = jax.nn.relu(_dense(p, x))
    return x


def aip_apply(params, feat, h, cfg: AIPConfig):
    """One step. feat: (..., F); h: (..., Hg). Returns (logits (..., M), h')."""
    x = _trunk(params, feat)
    if cfg.kind == "gru":
        flat = x.reshape(-1, x.shape[-1])
        hf = gru_mod.gru_cell(params["gru"], h.reshape(-1, h.shape[-1]),
                              flat, use_kernels=cfg.use_kernels)
        h = hf.reshape(h.shape)
        x = h
    return _dense(params["heads"], x), h


def aip_sequence(params, feats, h0, resets, cfg: AIPConfig):
    """feats: (B, T, F) -> logits (B, T, M). resets (B, T) restart the GRU
    at episode boundaries."""
    x = _trunk(params, feats)
    if cfg.kind == "gru":
        hs, _ = gru_mod.gru_sequence(params["gru"], x, h0, reset_mask=resets,
                                     use_kernels=cfg.use_kernels)
        x = hs
    return _dense(params["heads"], x)


def sample_sources(key, logits):
    """u ~ ∏_m Bernoulli(σ(logit_m)) — Eq. 25 independent heads."""
    return jax.random.bernoulli(key, jax.nn.sigmoid(logits)) \
        .astype(jnp.float32)


def _bce_elementwise(logits, targets):
    """Per-element stable sigmoid cross-entropy (..., M)."""
    return jnp.maximum(logits, 0) - logits * targets + \
        jnp.log1p(jnp.exp(-jnp.abs(logits)))


def bce_loss(params, feats, targets, resets, cfg: AIPConfig):
    """Expected cross-entropy (Section 3.2). feats (B,T,F), targets (B,T,M)."""
    h0 = initial_hidden(cfg, feats.shape[0])
    logits = aip_sequence(params, feats, h0, resets, cfg)
    return _bce_elementwise(logits, targets).mean()


def epoch_minibatch_indices(perm, batch: int):
    """Cover a permutation of S sequence indices with ceil(S/batch)
    fixed-size minibatches. When batch does not divide S, the last
    minibatch wraps around to the permutation's head instead of dropping
    the remainder — every sequence is visited at least once per epoch
    (a handful are visited twice; under a fresh permutation per epoch no
    sequence is systematically favoured). Requires ``batch <= len(perm)``
    (the wrap covers at most one full extra pass); callers clamp with
    ``min(cfg.batch, n_seq)``."""
    n_seq = perm.shape[0]
    n_mb = -(-n_seq // batch)
    pad = n_mb * batch - n_seq
    if pad:
        perm = jnp.concatenate([perm, perm[:pad]])
    return perm.reshape(n_mb, batch)


def train_aip(params, dataset, key, cfg: AIPConfig):
    """Minibatch Adam on BCE. dataset: {feats (S, T, F), u (S, T, M),
    resets (S, T)} — S sequences of length T. Returns (params, final_loss)."""
    opt = adamw.init(params)
    n_seq = dataset["feats"].shape[0]
    batch = min(cfg.batch, n_seq)

    def one_mb(carry, idx):
        params, opt = carry
        fb = jnp.take(dataset["feats"], idx, axis=0)
        ub = jnp.take(dataset["u"], idx, axis=0)
        rb = jnp.take(dataset["resets"], idx, axis=0)
        loss, grads = jax.value_and_grad(bce_loss)(params, fb, ub, rb, cfg)
        master, opt = adamw.update(
            grads, opt, cfg.lr, adamw.AdamWConfig(b2=0.999, weight_decay=0.0))
        params = adamw.cast_like(master, params)
        return (params, opt), loss

    def one_epoch(carry, ekey):
        perm = jax.random.permutation(ekey, n_seq)
        return jax.lax.scan(one_mb, carry,
                            epoch_minibatch_indices(perm, batch))

    (params, _), losses = jax.lax.scan(
        one_epoch, (params, opt), jax.random.split(key, cfg.epochs))
    return params, losses[-1].mean()


def eval_ce(params, dataset, cfg: AIPConfig):
    """CE of the AIP on held-out GS trajectories (the paper's Fig. 4 metric).

    Evaluated in fixed-size sequence chunks (``cfg.eval_chunk``) rather
    than one full-dataset batch: the all-at-once forward materialises
    (S, T, hidden) activations, a memory spike that scales with
    collect size × T. Small datasets (S ≤ chunk) take the single-batch
    path, which is exactly the old behaviour.
    """
    feats, u, resets = dataset["feats"], dataset["u"], dataset["resets"]
    n_seq, t_len = feats.shape[0], feats.shape[1]
    chunk = max(1, cfg.eval_chunk)
    if n_seq <= chunk:
        return bce_loss(params, feats, u, resets, cfg)
    n_chunks = -(-n_seq // chunk)
    pad = n_chunks * chunk - n_seq

    def chunked(x):
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    valid = chunked(jnp.ones((n_seq,), jnp.float32))      # (C, chunk)

    def one_chunk(args):
        f, uu, rr, w = args
        logits = aip_sequence(params, f, initial_hidden(cfg, chunk), rr, cfg)
        ce = _bce_elementwise(logits, uu)                 # (chunk, T, M)
        return (ce.sum(axis=(1, 2)) * w).sum()

    sums = jax.lax.map(one_chunk,
                       (chunked(feats), chunked(u), chunked(resets), valid))
    return sums.sum() / (n_seq * t_len * u.shape[-1])
