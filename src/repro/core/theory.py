"""Executable certificates for the paper's Section-4 theory.

* :func:`xi` — the influence-divergence term of Lemma 2.
* :func:`lemma2_certificate` — builds two IALMs differing only in their
  influence distributions, computes exact Q^π for both, and returns
  (max |Q1−Q2|, the Lemma-2 bound R̄·(H−t)(H−t+1)/2·ξ) so tests/benchmarks
  can assert lhs ≤ bound.
* :func:`theorem1_certificate` — checks the action-gap condition and
  whether the two IALMs share an optimal policy (Theorem 1: gap > 2Δ ⇒
  same π*).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.core import ialm as ialm_mod


def _histories(q: Dict[Tuple, np.ndarray]):
    return list(q.keys())


def xi(m1: ialm_mod.TabularIALM, m2: ialm_mod.TabularIALM) -> float:
    """sup over reachable histories of Σ_u |I1(u|l) − I2(u|l)| (with the
    deterministic-observation envs, P(l|h) is a point mass)."""
    # enumerate reachable histories up to horizon via m1's support ∪ m2's
    q1 = ialm_mod.q_values(m1, lambda l: np.full((m1.na,), 1.0 / m1.na))
    q2 = ialm_mod.q_values(m2, lambda l: np.full((m2.na,), 1.0 / m2.na))
    ls = set(_histories(q1)) | set(_histories(q2))
    return max(float(np.abs(m1.influence(l) - m2.influence(l)).sum())
               for l in ls)


def lemma2_certificate(T, R, horizon, influence1, influence2,
                       policy: Callable[[Tuple], np.ndarray]):
    """Returns dict(lhs, xi, bound, holds)."""
    m1 = ialm_mod.TabularIALM(T=T, R=R, horizon=horizon, influence=influence1)
    m2 = ialm_mod.TabularIALM(T=T, R=R, horizon=horizon, influence=influence2)
    q1 = ialm_mod.q_values(m1, policy)
    q2 = ialm_mod.q_values(m2, policy)
    common = set(q1) & set(q2)
    lhs = max(float(np.abs(q1[l] - q2[l]).max()) for l in common)
    x = xi(m1, m2)
    rbar = float(np.abs(R).max())
    bound = rbar * horizon * (horizon + 1) / 2.0 * x
    return {"lhs": lhs, "xi": x, "bound": bound, "holds": lhs <= bound + 1e-9}


def theorem1_certificate(T, R, horizon, influence1, influence2):
    """Returns dict(gap, delta, same_optimal, condition_met).

    Theorem 1: if the action gap of M1 exceeds 2Δ (the max Q-difference
    between the models over all policies — here certified with the two
    greedy policies, a sound lower bound for the test), both models share
    the optimal policy.
    """
    m1 = ialm_mod.TabularIALM(T=T, R=R, horizon=horizon, influence=influence1)
    m2 = ialm_mod.TabularIALM(T=T, R=R, horizon=horizon, influence=influence2)
    pol1, q1 = ialm_mod.optimal_policy(m1)
    pol2, q2 = ialm_mod.optimal_policy(m2)

    # Δ: max |Q1^π − Q2^π| — evaluate under both greedy policies
    delta = 0.0
    for pol in (pol1, pol2):
        qa = ialm_mod.q_values(m1, pol)
        qb = ialm_mod.q_values(m2, pol)
        for l in set(qa) & set(qb):
            delta = max(delta, float(np.abs(qa[l] - qb[l]).max()))

    # action gap of M1 at every history with >1 action
    gap = np.inf
    for l, q in q1.items():
        s = np.sort(q)[::-1]
        if len(s) > 1:
            gap = min(gap, float(s[0] - s[1]))

    same = all(np.argmax(q1[l]) == np.argmax(q2[l])
               for l in set(q1) & set(q2))
    return {"gap": gap, "delta": delta, "same_optimal": same,
            "condition_met": gap > 2 * delta}


def perturbed_influence(base: Callable, eps: float, nu: int):
    """I'(u|l) = (1−eps)·I(u|l) + eps·uniform — the controlled perturbation
    used in the Lemma-2 empirical check (ξ ≤ 2·eps)."""
    def f(l):
        p = base(l)
        return (1.0 - eps) * p + eps / nu
    return f
