"""Region-decomposed global simulator — the GS itself on the mesh.

PR 2–4 sharded the inner loop; the GS collect (Algorithm 2) and the
periodic GS eval still executed fully replicated — the joint rollout
re-centralized exactly the computation the paper decomposes. This module
removes that: the factored-randomness protocol (``repro.envs.base``)
already proves every region's next state depends only on its local
state, its realized influence sources, and its exo slice (Definition 3),
which licenses running the GS as **region blocks that exchange only
boundary influence** — the same locality DARL1N exploits with
one-hop-neighbour training.

One GS step, block-decomposed (``make_block_step``):

1. **halo exchange** — each block sends its (local states, actions)
   slice one hop around the block ring in both directions
   (``repro.distributed.collectives.halo_exchange``, two ``ppermute``s —
   the ONLY collectives a sharded-GS body may contain);
2. **boundary influence** — the env's ``boundary_influence`` evaluates
   on a zero-padded full-size view holding blocks {b-1, b, b+1}; by the
   locality contract of ``region_partition`` the block's own rows of the
   result are exactly the replicated ``u`` (zero rows are inert), so
   equivalence is by construction, not by tolerance;
3. **region transitions** — ``ls_step_given`` (the per-region transition
   shared verbatim with the LS) advances the block's agents with the
   realized ``u`` and their ``exo_locals`` slice. Definition-3 exactness
   (property-tested per env) makes this bit-for-bit the GS restriction.

Exogenous draws, action noise, and reset draws are *replicated*: every
block evaluates the same cheap counter-based RNG from the same key and
slices its rows, so the block-decomposed trajectory reproduces the
replicated ``gs_step`` trajectory bitwise under a shared key stream —
the simulator state, the policy forward, and the region dynamics (the
heavy terms) decompose; the random bits are not worth a collective.

Deliberate trade, worth knowing when scaling further: the boundary
computation itself is evaluated on the zero-padded full-size view, so
its cost per block is O(N)-row, not O(N/blocks)-row. That buys bitwise
equivalence *by construction* (the env's one reference implementation
of ``boundary_influence`` is the code that runs, on identical rows) and
costs little here — influence extraction is elementwise/neighbour work,
dwarfed by the per-region transitions and policy matmuls that do
decompose. An O(B) variant needs offset-aware windowed influence
functions per env (3-block inputs instead of N); do that when a profile
on a real mesh shows the boundary term, not before.

``make_sharded_collector`` / ``make_sharded_evaluator`` are the
``shard_map``'d twins of ``repro.core.gs.make_collector`` and the GS
evaluator of ``repro.marl.runner``: the collector emits the same
``(N, S, T, ...)`` dataset already agent-sharded on the mesh (no
post-collect re-placement), the evaluator reduces per-block returns and
means them outside the mesh body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import collectives
from repro.distributed import runtime as runtime_lib
from repro.marl import policy as policy_mod
from repro.obs import trace as obs_trace


# ---------------------------------------------------------------------------
# partition validation
# ---------------------------------------------------------------------------
def partition(env_mod, env_cfg, n_blocks: int) -> np.ndarray:
    """The env's validated agent→block assignment. The halo ring logic
    below assumes the canonical contiguous equal-size layout (block b
    owns agents [b·B, (b+1)·B)), so anything else is rejected."""
    from repro.envs import base
    n_agents = env_cfg.info().n_agents
    part = np.asarray(env_mod.region_partition(env_cfg, n_blocks))
    canonical = base.contiguous_partition(n_agents, n_blocks)
    if part.shape != (n_agents,) or not np.array_equal(part, canonical):
        raise ValueError(
            f"{env_cfg.info().name}.region_partition({n_blocks}) is not "
            f"the contiguous equal-size layout the sharded GS requires")
    return part


def partition_supported(env_mod, env_cfg, n_blocks: int):
    """(ok, reason): can this env's GS decompose into ``n_blocks``?
    ``False`` for topologies that cannot tile (grid side not divisible)
    and for env modules predating the spatial-decomposition protocol
    (either hook missing — partial implementations must fall back to
    the replicated GS cleanly, not crash at trace time)."""
    if not hasattr(env_mod, "boundary_influence"):
        return False, f"{env_cfg.info().name} has no boundary_influence"
    try:
        partition(env_mod, env_cfg, n_blocks)
        return True, ""
    except (AttributeError, ValueError) as e:
        return False, str(e)


# ---------------------------------------------------------------------------
# the block-decomposed GS step
# ---------------------------------------------------------------------------
def _place_window(own, prev, nxt, blk, n_blocks: int, n_agents: int):
    """Zero-padded full-size view with blocks {b-1, b, b+1} placed at
    their absolute agent rows (mod-ring). Overlapping writes (1- or
    2-block rings) carry identical data, so order is irrelevant."""
    bsz = n_agents // n_blocks

    def one(o, p, x):
        full = jnp.zeros((n_agents,) + o.shape[1:], o.dtype)
        for delta, leaf in ((-1, p), (0, o), (1, x)):
            c = jnp.mod(blk + delta, n_blocks)
            full = jax.lax.dynamic_update_slice_in_dim(
                full, leaf, c * bsz, axis=0)
        return full

    return jax.tree.map(one, own, prev, nxt)


def make_block_step(env_mod, env_cfg, *, n_blocks: int,
                    axis_name: str = runtime_lib.SHARD_AXIS):
    """One GS step for one block of one env stream, to run under a
    ``shard_map`` (or ``vmap`` with ``axis_name`` — how the in-process
    equivalence tests drive it) over the block axis.

    ``block_step(loc, t, actions, exo) ->
        (loc', obs (B, O), rew (B,), u (B, M), done (), t')``

    ``loc``: this block's ``gs_locals``-schema slice (leaves (B, ...));
    ``t``: () int32 step counter (identical on every block);
    ``actions``: (B,) the block's joint-action slice;
    ``exo``: the FULL exogenous draw (replicated — every block holds it).
    """
    info = env_cfg.info()
    n_agents = info.n_agents
    partition(env_mod, env_cfg, n_blocks)
    bsz = n_agents // n_blocks

    def block_step(loc, t, actions, exo):
        blk = jax.lax.axis_index(axis_name)
        # named scopes land in HLO metadata so an XLA profile attributes
        # the ring collectives / boundary term; no primitives are added
        with obs_trace.annotate("halo_exchange"):
            prev, nxt = collectives.halo_exchange(
                (loc, actions), axis_name, axis_size=n_blocks)
        view_loc, view_act = _place_window(
            (loc, actions), prev, nxt, blk, n_blocks, n_agents)
        with obs_trace.annotate("boundary_influence"):
            u_full = env_mod.boundary_influence(
                view_loc, view_act, exo, env_cfg)             # (N, M)
        take = lambda x: jax.lax.dynamic_slice_in_dim(
            x, blk * bsz, bsz, axis=0)
        u = take(u_full)
        exo_blk = jax.tree.map(take, env_mod.exo_locals(exo, env_cfg))
        step = jax.vmap(lambda l, a, uu, e: env_mod.ls_step_given(
            {**l, "t": t}, a, uu, e, env_cfg))
        new, obs, rew, _done = step(loc, actions, u, exo_blk)
        loc2 = {k: v for k, v in new.items() if k != "t"}
        t2 = t + 1
        return loc2, obs, rew, u, t2 >= env_cfg.horizon, t2

    return block_step


# ---------------------------------------------------------------------------
# shared plumbing for the collector / evaluator twins
# ---------------------------------------------------------------------------
def _block_plumbing(env_mod, env_cfg, policy_cfg, mesh):
    info = env_cfg.info()
    n_blocks = mesh.shape[runtime_lib.SHARD_AXIS]
    n_agents = info.n_agents
    if n_agents % n_blocks:
        raise ValueError(
            f"{n_agents} agents cannot tile {n_blocks} GS blocks")
    bsz = n_agents // n_blocks
    block_step = make_block_step(env_mod, env_cfg, n_blocks=n_blocks)

    v_gs_init = jax.vmap(lambda k: env_mod.gs_init(k, env_cfg))
    v_gs_locals = jax.vmap(lambda s: env_mod.gs_locals(s, env_cfg))
    b_ls_obs = jax.vmap(jax.vmap(lambda l: env_mod.ls_obs(l, env_cfg)))
    apply_agents = jax.vmap(
        lambda p, o, h: policy_mod.policy_apply(p, o, h, policy_cfg),
        in_axes=(0, 1, 1), out_axes=(1, 1, 1))

    def init_block_locals(keys, blk):
        """Replicated ``gs_init`` (same keys on every block — cheap,
        counter-based), restricted to this block's agents."""
        states = v_gs_init(keys)
        loc = jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, blk * bsz, bsz, axis=1),
            v_gs_locals(states))                              # (E, B, ...)
        return loc, states["t"]                               # t: (E,)

    return (info, n_blocks, bsz, jax.vmap(block_step), init_block_locals,
            b_ls_obs, apply_agents)


# ---------------------------------------------------------------------------
# Algorithm 2 on the mesh
# ---------------------------------------------------------------------------
def make_sharded_collector(env_mod, env_cfg,
                           policy_cfg: policy_mod.PolicyConfig, *,
                           n_envs: int, steps: int, mesh):
    """``shard_map``'d twin of :func:`repro.core.gs.make_collector`:
    ``collect(policy_params (N, ...) agent-sharded, key) -> dataset``
    with leaves (N, n_envs, steps, ...) already agent-sharded on the
    mesh. Key plumbing mirrors the replicated collector exactly — the
    same per-stream fold-in chains (``env_pool.stream_keys``), evaluated
    replicated on every block — so the emitted dataset is the replicated
    one (bitwise, given bitwise policy forwards), S-prefix invariance
    included."""
    from repro.core import env_pool
    (info, n_blocks, bsz, e_block_step, init_block_locals, b_ls_obs,
     apply_agents) = _block_plumbing(env_mod, env_cfg, policy_cfg, mesh)
    n_agents = info.n_agents
    v_gs_exo = jax.vmap(lambda k: env_mod.gs_exo(k, env_cfg))

    def categorical_block(keys, logits, blk):
        """The replicated collector draws one categorical PER STREAM over
        that stream's full (N, A) logits; the gumbel bits depend only on
        the stream key and the (row, column) position, so evaluating the
        same per-stream draw on a zero-padded full-agent view and
        reading off this block's rows reproduces the sampled actions
        bitwise (garbage rows produce garbage actions that nobody
        reads)."""
        def one(key, lg):                                 # lg (B, A)
            full = jnp.zeros((n_agents,) + lg.shape[1:], lg.dtype)
            full = jax.lax.dynamic_update_slice_in_dim(
                full, lg, blk * bsz, axis=0)
            return jax.lax.dynamic_slice_in_dim(
                jax.random.categorical(key, full), blk * bsz, bsz, axis=0)
        return jax.vmap(one)(keys, logits)

    def body(params, key):
        blk = jax.lax.axis_index(runtime_lib.SHARD_AXIS)
        skeys = env_pool.stream_keys(key, n_envs)
        loc, t = init_block_locals(env_pool.init_keys(skeys), blk)
        obs = b_ls_obs(loc)                                   # (E, B, O)
        h = policy_mod.initial_hidden(policy_cfg, n_envs, bsz)
        prev_a = jnp.zeros((n_envs, bsz), jnp.int32)
        prev_done = jnp.ones((n_envs,), bool)
        bufs = {"feats": jnp.zeros((bsz, n_envs, steps, info.alsh_dim),
                                   jnp.float32),
                "u": jnp.zeros((bsz, n_envs, steps, info.n_influence),
                               jnp.float32),
                "resets": jnp.zeros((bsz, n_envs, steps), jnp.float32)}

        def step(carry, ti):
            loc, t, obs, h, prev_a, prev_done, bufs = carry
            k_act, k_env, k_reset = env_pool.step_keys(skeys, ti, 3)
            feat = jnp.concatenate(
                [obs, jax.nn.one_hot(prev_a, info.n_actions)], axis=-1)
            logits, _, h2 = apply_agents(params, obs, h)
            action = categorical_block(k_act, logits, blk)
            exo = v_gs_exo(k_env)
            loc2, obs2, _rew, u, done, t2 = e_block_step(
                loc, t, action, exo)
            fresh_loc, fresh_t = init_block_locals(k_reset, blk)
            loc3 = env_pool.reset_where(done, fresh_loc, loc2)
            t3 = jnp.where(done, fresh_t, t2)
            obs3 = env_pool.reset_where(done, b_ls_obs(loc3), obs2)
            h3, prev3 = env_pool.zero_on_done(done, (h2, action))
            rec = {"feats": feat, "u": u,
                   "resets": jnp.broadcast_to(
                       prev_done[:, None], (n_envs, bsz))
                   .astype(jnp.float32)}
            # fused transpose, as in the replicated collector: the
            # (B, E, T, ...) buffers ride the scan carry and each step's
            # (E, B, ...) record lands in its time slice in place
            def write(buf, x):
                return jax.lax.dynamic_update_index_in_dim(
                    buf, jnp.moveaxis(x, 0, 1), ti, axis=2)
            bufs = {kk: write(bufs[kk], rec[kk]) for kk in bufs}
            return (loc3, t3, obs3, h3, prev3, done, bufs), None

        carry = (loc, t, obs, h, prev_a, prev_done, bufs)
        carry, _ = jax.lax.scan(step, carry, jnp.arange(steps))
        # with out_specs sharding the leading axis the carried (B, E, T,
        # ...) buffers ARE the (N, E, T, ...) dataset layout.
        return carry[-1]

    from jax.sharding import PartitionSpec as P
    sharded = P(runtime_lib.SHARD_AXIS)
    return jax.jit(runtime_lib.shard_map_nocheck(
        body, mesh, in_specs=(sharded, P()), out_specs=sharded))


# ---------------------------------------------------------------------------
# GS eval on the mesh
# ---------------------------------------------------------------------------
def make_sharded_evaluator(env_mod, env_cfg,
                           policy_cfg: policy_mod.PolicyConfig, *, mesh):
    """``shard_map``'d twin of the GS evaluator in
    ``repro.marl.runner.make_gs_trainer``: deterministic (argmax)
    rollout of full episodes, block-decomposed, per-block mean returns
    reduced outside the mesh body (equal block sizes make the mean of
    block means the global mean)."""
    (info, n_blocks, bsz, e_block_step, init_block_locals, b_ls_obs,
     apply_agents) = _block_plumbing(env_mod, env_cfg, policy_cfg, mesh)
    v_gs_exo = jax.vmap(lambda k: env_mod.gs_exo(k, env_cfg))
    from jax.sharding import PartitionSpec as P
    sharded = P(runtime_lib.SHARD_AXIS)

    @functools.lru_cache(maxsize=None)
    def build(episodes: int):
        def body(params, key):
            blk = jax.lax.axis_index(runtime_lib.SHARD_AXIS)
            ke, kr = jax.random.split(key)
            loc, t = init_block_locals(
                jax.random.split(ke, episodes), blk)
            obs = b_ls_obs(loc)
            h = policy_mod.initial_hidden(policy_cfg, episodes, bsz)

            def step(carry, k):
                loc, t, obs, h = carry
                logits, _, h2 = apply_agents(params, obs, h)
                action = jnp.argmax(logits, axis=-1)
                exo = v_gs_exo(jax.random.split(k, episodes))
                loc2, obs2, rew, _u, _done, t2 = e_block_step(
                    loc, t, action, exo)
                return (loc2, t2, obs2, h2), rew

            _, rews = jax.lax.scan(step, (loc, t, obs, h),
                                   jax.random.split(kr, info.horizon))
            return rews.mean()[None]                      # (1,) per shard

        sm = runtime_lib.shard_map_nocheck(
            body, mesh, in_specs=(sharded, P()), out_specs=sharded)
        return jax.jit(lambda p, k: sm(p, k).mean())

    def eval_fn(params, key, *, episodes: int = 4):
        return build(int(episodes))(params, key)

    return eval_fn


# ---------------------------------------------------------------------------
# Contract audit
# ---------------------------------------------------------------------------
def audit_halo_contract(program, *args, what: str = "sharded GS program"):
    """Trace a sharded-GS callable abstractly and run the engine's
    halo-only rule over every ``shard_map`` body it contains: nothing
    but boundary ``ppermute``s, and at least one of them. Violations
    raise with the emitting source line (``repro.analysis``)."""
    from repro.analysis import contracts

    jx = jax.make_jaxpr(program)(*args)
    bodies = runtime_lib.find_shard_map_jaxprs(jx)
    if not bodies:
        raise AssertionError(
            f"{what} contains no shard_map at all — it is not a mesh "
            f"program")
    contracts.raise_findings(contracts.run_rules(
        [contracts.Program(name=f"{what} body[{i}]", roles=("gs_body",),
                           jaxpr=body)
         for i, body in enumerate(bodies)],
        rules=(contracts.HaloOnly(),)))
