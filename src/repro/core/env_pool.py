"""Batched env pools — S independent simulator streams as ONE wide program.

"Large Batch Simulation for Deep RL" gets its 10-100x simulator
throughput by batching many independent rollouts into one vectorized
program; this module is that layer for the GS and the LS. A *pool* is S
env streams advanced by a single ``vmap``'d step with in-program
auto-reset, so the stream count S is a pure width knob: growing it makes
the device matmuls wider without adding dispatches, host syncs, or
python-loop iterations.

Per-stream PRNG discipline (the load-bearing invariant)
-------------------------------------------------------
Every stream draws from its OWN key chain, derived by folding the
**absolute stream index** into the pool key (:func:`stream_keys`) — the
same discipline PR 2 established for agents in ``repro.core.ials``:

* ``base_s   = fold_in(key, s)``            (stream s's chain root)
* ``init_s   = fold_in(base_s, 0)``         (:func:`init_keys`)
* ``step_s,t = split(fold_in(base_s, t+1), n)``  (:func:`step_keys`)

Stream s's entire draw sequence depends only on ``(key, s, t)`` — never
on how many streams share the batch or how long the rollout is. Growing
S therefore preserves the prefix streams **bitwise** (property-tested:
S=8 equals the first 8 streams of S=1024), which is what makes S an
honest scaling axis: a wide population run contains every narrower run
exactly. It also means per-stream draws (action sampling, env
transitions, resets) vectorize as a ``vmap`` over stream keys instead of
one joint draw whose bits depend on the batch shape.

Auto-reset is in-program: a stream whose episode ends is re-initialized
from its reset key *inside* the step (done flags broadcast by RANK, so
the same logic covers scalar, vector, and grid-shaped leaves), and the
policy-side per-stream state (RNN hidden, previous action) is zeroed
through the same mask. No host involvement at episode boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# per-stream key derivation
# ---------------------------------------------------------------------------
def stream_keys(key, n_streams: int):
    """(S, 2) per-stream base keys: ``fold_in(key, s)`` with the ABSOLUTE
    stream index s. Prefix-invariant in S by construction:
    ``stream_keys(k, 8) == stream_keys(k, 1024)[:8]`` bitwise."""
    return jax.vmap(lambda s: jax.random.fold_in(key, s))(
        jnp.arange(n_streams))


def init_keys(skeys):
    """(S, 2) stream-init keys: step 0 of each stream's chain."""
    return jax.vmap(lambda k: jax.random.fold_in(k, 0))(skeys)


def step_keys(skeys, t, n: int):
    """``n`` per-stream key bundles for step ``t``: leaves (S, 2), stacked
    to (n, S, 2) so call sites unpack ``k_a, k_b, ... = step_keys(...)``.
    ``t`` may be a traced scan counter; the chain position is ``t + 1``
    (0 is the init draw), independent of the rollout length."""
    ks = jax.vmap(lambda k: jax.random.split(jax.random.fold_in(k, t + 1), n))(
        skeys)
    return jnp.moveaxis(ks, 1, 0)


# ---------------------------------------------------------------------------
# auto-reset selectors
# ---------------------------------------------------------------------------
def reset_where(done, fresh, current):
    """Tree-select ``fresh`` over ``current`` on done streams, with the
    (S,) done flag broadcast by RANK — the same reset works for leaves
    shaped (S,), (S, N), (S, N, O), or grid-shaped env state."""
    def sel(f, c):
        mask = done.reshape(done.shape + (1,) * (c.ndim - done.ndim))
        return jnp.where(mask, f, c)
    return jax.tree.map(sel, fresh, current)


def zero_on_done(done, tree):
    """Zero the policy-side per-stream state (RNN hidden, previous
    action) of finished streams: ``reset_where`` against zeros."""
    return reset_where(done, jax.tree.map(jnp.zeros_like, tree), tree)


# ---------------------------------------------------------------------------
# the pools
# ---------------------------------------------------------------------------
class GSPool:
    """S global-simulator streams as one vmapped program.

    ``init`` consumes per-stream base keys; ``step_reset`` advances every
    stream one step with per-stream env keys and re-initializes finished
    streams in-program (auto-reset). All methods are traced — the pool is
    pure plumbing around the env module, not a stateful object.
    """

    def __init__(self, env_mod, env_cfg, n_streams: int):
        self.env_cfg, self.n_streams = env_cfg, n_streams
        self.v_init = jax.vmap(lambda k: env_mod.gs_init(k, env_cfg))
        self.v_step = jax.vmap(
            lambda s, a, k: env_mod.gs_step(s, a, k, env_cfg))
        self.v_obs = jax.vmap(lambda s: env_mod.gs_obs(s, env_cfg))

    def init(self, skeys):
        """Fresh env states from the streams' init keys (chain step 0)."""
        return self.v_init(init_keys(skeys))

    def step_reset(self, env, action, k_env, k_reset):
        """One step + auto-reset. Returns (env', obs', rew, u, done) where
        ``done`` (S,) flags the streams that ended (and were reset)."""
        env2, obs2, rew, u, done = self.v_step(env, action, k_env)
        fresh = self.v_init(k_reset)
        env3 = reset_where(done, fresh, env2)
        obs3 = reset_where(done, self.v_obs(env3), obs2)
        return env3, obs3, rew, u, done


class LSPool:
    """E local-simulator streams of ONE agent as one vmapped program —
    the IALS rollout's pool. Influence sources ``u`` arrive from the
    caller (sampled from the agent's AIP), everything else mirrors
    :class:`GSPool`."""

    def __init__(self, env_mod, env_cfg, n_streams: int):
        self.env_cfg, self.n_streams = env_cfg, n_streams
        self.v_init = jax.vmap(lambda k: env_mod.ls_init(k, env_cfg))
        self.v_step = jax.vmap(
            lambda l, a, u, k: env_mod.ls_step(l, a, u, k, env_cfg))
        self.v_obs = jax.vmap(lambda l: env_mod.ls_obs(l, env_cfg))

    def init(self, skeys):
        return self.v_init(init_keys(skeys))

    def step_reset(self, locals_, action, u, k_env, k_reset):
        """One influence-augmented step + auto-reset. Returns
        (locals', obs', rew, done)."""
        locals2, obs2, rew, done = self.v_step(locals_, action, u, k_env)
        fresh = self.v_init(k_reset)
        locals3 = reset_where(done, fresh, locals2)
        obs3 = reset_where(done, self.v_obs(locals3), obs2)
        return locals3, obs3, rew, done
